//! Bench: regenerate Table 3 — the eight real benchmarks and their
//! kernel-instance counts — and time instance construction.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::report::tables;
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::workloads;

fn main() {
    let dev = DeviceSpec::m2090();
    let b = Bencher::default();
    let mut total = 0usize;
    let r = b.run("table3: build all real-benchmark instances", || {
        total = 0;
        for bench in workloads::all() {
            total += black_box((bench.instances)(&dev).len());
        }
    });
    report_throughput(&r, total as f64, "instances");
    println!("\n{}", tables::table3(&dev));
}
