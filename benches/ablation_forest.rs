//! Ablations over the design choices DESIGN.md calls out (+ the paper's
//! §7 future-work directions):
//!   - number of trees (paper fixes 20)
//!   - mtry (paper fixes 4)
//!   - training fraction (paper fixes 10%)
//!   - alternative learner: k-NN regressor (the "other ML model" probe)
//!   - measurement noise on/off (synthetic-label quality)

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::metrics;
use lmtuner::sim::exec::{MeasureConfig, SpeedupRecord, TuneRecord};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::bench::black_box;
use lmtuner::util::prng::Rng;

fn build(noise: bool) -> Vec<TuneRecord> {
    let dev = DeviceSpec::m2090();
    let mut rng = Rng::new(0xAB1A7E);
    let templates = generator::generate_n(&mut rng, 15);
    let sweep = LaunchSweep::new(2048, 2048);
    let cfg = dataset::BuildConfig {
        configs_per_kernel: 16,
        measure: if noise {
            MeasureConfig::default()
        } else {
            MeasureConfig::deterministic()
        },
        ..Default::default()
    };
    dataset::build(&templates, &sweep, &dev, &cfg)
}

fn eval(records: &[TuneRecord], frac: f64, cfg: &ForestConfig) -> (f64, f64, f64) {
    let (train, test) = dataset::split(records, frac, 7);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let test: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();
    let t0 = std::time::Instant::now();
    let f = Forest::fit_records(&train, cfg).expect("finite records");
    let dt = t0.elapsed().as_secs_f64();
    let acc = metrics::evaluate_model(&test, |x| f.decide(x));
    (acc.count_based, acc.penalty_weighted, dt)
}

/// k-NN regressor over normalized features: the simplest credible
/// "other machine learning model" (paper §7).
fn knn_eval(records: &[TuneRecord], frac: f64, k: usize) -> (f64, f64) {
    let (train, test) = dataset::split(records, frac, 7);
    let train: Vec<&SpeedupRecord> = train.iter().map(|r| &r.base).collect();
    let test: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();
    let nf = train[0].features.len();
    // z-normalize on train stats
    let mut mean = vec![0.0; nf];
    let mut var = vec![0.0; nf];
    for r in &train {
        for (i, &x) in r.features.iter().enumerate() {
            mean[i] += x;
        }
    }
    for m in mean.iter_mut() {
        *m /= train.len() as f64;
    }
    for r in &train {
        for (i, &x) in r.features.iter().enumerate() {
            var[i] += (x - mean[i]) * (x - mean[i]);
        }
    }
    for v in var.iter_mut() {
        *v = (*v / train.len() as f64).sqrt().max(1e-9);
    }
    let norm = |f: &[f64]| -> Vec<f64> {
        f.iter().enumerate().map(|(i, &x)| (x - mean[i]) / var[i]).collect()
    };
    let train_n: Vec<(Vec<f64>, f64)> =
        train.iter().map(|r| (norm(&r.features), r.target())).collect();
    // subsample test for tractability on 1 core
    let test: Vec<_> = test.iter().step_by(10).cloned().collect();
    let decisions: Vec<bool> = test
        .iter()
        .map(|r| {
            let q = norm(&r.features);
            let mut d: Vec<(f64, f64)> = train_n
                .iter()
                .map(|(x, y)| {
                    let dist: f64 =
                        x.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                    (dist, *y)
                })
                .collect();
            d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let pred: f64 = d[..k].iter().map(|(_, y)| y).sum::<f64>() / k as f64;
            pred > 0.0
        })
        .collect();
    let acc = metrics::evaluate(&test, &decisions);
    (acc.count_based, acc.penalty_weighted)
}

fn main() {
    println!("building ablation dataset ...");
    let records = build(true);
    println!("{} instances\n", records.len());

    println!("--- trees (paper: 20) ---");
    for trees in [1, 5, 10, 20, 40] {
        let cfg = ForestConfig { num_trees: trees, ..Default::default() };
        let (c, p, dt) = eval(&records, 0.1, &cfg);
        println!("trees={trees:<3} count={:.3} penalty={:.3} fit={dt:.2}s", c, p);
    }

    println!("\n--- mtry (paper: 4) ---");
    for mtry in [1, 2, 4, 8, 18] {
        let mut cfg = ForestConfig::default();
        cfg.tree.mtry = mtry;
        let (c, p, dt) = eval(&records, 0.1, &cfg);
        println!("mtry={mtry:<3} count={:.3} penalty={:.3} fit={dt:.2}s", c, p);
    }

    println!("\n--- training fraction (paper: 0.10) ---");
    for frac in [0.01, 0.05, 0.10, 0.30] {
        let (c, p, dt) = eval(&records, frac, &ForestConfig::default());
        println!("frac={frac:<5} count={:.3} penalty={:.3} fit={dt:.2}s", c, p);
    }

    println!("\n--- alternative learner: k-NN (paper §7 future work) ---");
    for k in [1, 5, 15] {
        let (c, p) = knn_eval(&records, 0.1, k);
        println!("knn k={k:<3} count={:.3} penalty={:.3}", c, p);
    }

    println!("\n--- measurement noise ---");
    let clean = build(false);
    let (c, p, _) = eval(&clean, 0.1, &ForestConfig::default());
    println!("noise=off count={c:.3} penalty={p:.3}");
    let (c, p, _) = eval(&records, 0.1, &ForestConfig::default());
    println!("noise=2%  count={c:.3} penalty={p:.3}");
    black_box(());
}
