//! Dataset-pipeline throughput (rows/sec): the serial reference build
//! vs the streamed chunk-parallel build, and the per-sink overhead of
//! streaming to sharded CSV or a reservoir sample. The parallel/serial
//! ratio is the headline number: it is what makes paper-scale
//! (`--scale 1.0`, millions of instances) phase-1 runs practical.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::synth::sink::{MemorySink, ReservoirSink, ShardedCsvSink};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::util::prng::Rng;

fn main() {
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("host threads: {threads}");

    // The per-template launch-sampling hot path: `sampled_balanced` runs
    // once per template (11200x at paper scale). It used to clone and
    // fully shuffle every workgroup bucket — the whole {len}-launch sweep
    // — per call; it now draws only the k launches it returns (sparse
    // partial Fisher-Yates), so calls/sec here is the direct measure of
    // that win.
    {
        let bench = Bencher::coarse();
        const CALLS_PER_ITER: usize = 1000;
        for k in [24usize, 48, 200] {
            let mut rng = Rng::new(0x5A3E);
            let r = bench.run(
                &format!("sampled_balanced k={k} (sweep len {})", sweep.len()),
                || {
                    for _ in 0..CALLS_PER_ITER {
                        black_box(sweep.sampled_balanced(&mut rng, k));
                    }
                },
            );
            report_throughput(&r, CALLS_PER_ITER as f64, "calls");
        }
    }

    for tuples in [2usize, 8] {
        let mut rng = Rng::new(0xBE4C4);
        let templates = generator::generate_n(&mut rng, tuples);
        let cfg = dataset::BuildConfig {
            configs_per_kernel: 8,
            ..Default::default()
        };
        let serial_cfg = dataset::BuildConfig { threads: 1, ..cfg.clone() };
        let bench = Bencher::coarse();

        // Serial reference (the old `dataset::build` shape: one thread,
        // one Vec).
        let mut rows = 0usize;
        let r_serial = bench.run(
            &format!("serial reference ({tuples} tuples x 8 cfgs)"),
            || {
                let recs = dataset::build_serial(&templates, &sweep, &dev, &serial_cfg);
                rows = recs.len();
                black_box(recs);
            },
        );
        report_throughput(&r_serial, rows as f64, "rows");

        // Streamed chunk-parallel build into memory.
        let r_mem = bench.run(
            &format!("streamed -> MemorySink ({threads} threads)"),
            || {
                let mut sink = MemorySink::new();
                dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                    .unwrap();
                black_box(sink.records);
            },
        );
        report_throughput(&r_mem, rows as f64, "rows");

        // Streamed to round-robin CSV shards on disk.
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-perf-ds-{}", std::process::id()));
        let r_csv = bench.run("streamed -> ShardedCsvSink (4 shards)", || {
            let mut sink = ShardedCsvSink::create(&dir, 4, dev.key).unwrap();
            dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
            black_box(sink.written());
        });
        report_throughput(&r_csv, rows as f64, "rows");
        std::fs::remove_dir_all(&dir).ok();

        // Streamed through a training-split reservoir.
        let r_res = bench.run("streamed -> ReservoirSink (cap 1000)", || {
            let mut sink = ReservoirSink::new(1000, 7);
            dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
            black_box(sink.records().len());
        });
        report_throughput(&r_res, rows as f64, "rows");

        println!(
            "  parallel/serial speedup: {:.2}x over {} rows ({} threads)\n",
            r_serial.mean.as_secs_f64() / r_mem.mean.as_secs_f64(),
            rows,
            threads
        );
    }
}
