//! Dataset-pipeline throughput (rows/sec): the serial reference build
//! vs the streamed chunk-parallel build, the per-sink overhead of
//! streaming to sharded CSV or a reservoir sample, and the on-disk
//! format shootout — line-oriented CSV vs the binary columnar shard
//! format (`synth::binfmt`) — over a fabricated paper-scale row block.
//!
//! Results land in `BENCH_perf_dataset.json`; the headline notes are
//! `parallel_over_serial` and `bin_over_csv_write_read` (target: >= 5x
//! at >= 100k rows — the CSV encode/parse cost is what the binary
//! format exists to delete).
//!
//! Set LMTUNER_BENCH_SMOKE=1 for a seconds-scale smoke run (CI): same
//! sections, same JSON shape, fewer rows/iterations — the ratios are
//! then indicative, not publishable.

use std::time::Duration;

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::NUM_FEATURES;
use lmtuner::sim::exec::{Schema, TuneRecord};
use lmtuner::synth::binfmt::ShardFormat;
use lmtuner::synth::sink::{
    self, MemorySink, RecordSink, ReservoirSink, ShardedCsvSink, ShardedSink,
};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::bench::{black_box, Bencher, JsonReport};
use lmtuner::util::prng::Rng;

fn smoke() -> bool {
    std::env::var("LMTUNER_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Fabricate a deterministic row block shaped like a real v1 dataset:
/// 18 features + speedup, no simulation cost, so the format shootout
/// times I/O and (de)serialization only.
fn fabricate(rows: usize) -> Vec<TuneRecord> {
    let mut rng = Rng::new(0xB14C);
    (0..rows)
        .map(|i| {
            let mut row = vec![0.0; Schema::V1.columns()];
            for cell in row.iter_mut().take(NUM_FEATURES) {
                *cell = (rng.next_u64() % 100_000) as f64 / 64.0;
            }
            row[NUM_FEATURES] = 0.25 + (rng.next_u64() % 512) as f64 / 128.0;
            TuneRecord::from_csv_row(Schema::V1, format!("r{i}"), &row).unwrap()
        })
        .collect()
}

fn main() {
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    let smoke = smoke();
    if smoke {
        println!("smoke mode: reduced rows/iterations, indicative numbers only");
    }
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("host threads: {threads}");
    let mut rep = JsonReport::new("perf_dataset");
    let profile = || {
        if smoke {
            Bencher {
                warmup_iters: 0,
                min_iters: 1,
                min_time: Duration::ZERO,
                max_iters: 2,
            }
        } else {
            Bencher::coarse()
        }
    };

    // The per-template launch-sampling hot path: `sampled_balanced` runs
    // once per template (11200x at paper scale). It used to clone and
    // fully shuffle every workgroup bucket — the whole {len}-launch sweep
    // — per call; it now draws only the k launches it returns (sparse
    // partial Fisher-Yates), so calls/sec here is the direct measure of
    // that win.
    {
        let bench = profile();
        let calls_per_iter: usize = if smoke { 100 } else { 1000 };
        for k in [24usize, 48, 200] {
            let mut rng = Rng::new(0x5A3E);
            let r = bench.run(
                &format!("sampled_balanced k={k} (sweep len {})", sweep.len()),
                || {
                    for _ in 0..calls_per_iter {
                        black_box(sweep.sampled_balanced(&mut rng, k));
                    }
                },
            );
            rep.record_throughput(&r, calls_per_iter as f64, "calls");
        }
    }

    let mut par_over_serial = 0.0;
    for tuples in [2usize, 8] {
        let mut rng = Rng::new(0xBE4C4);
        let templates = generator::generate_n(&mut rng, tuples);
        let cfg = dataset::BuildConfig {
            configs_per_kernel: 8,
            ..Default::default()
        };
        let serial_cfg = dataset::BuildConfig { threads: 1, ..cfg.clone() };
        let bench = profile();

        // Serial reference (the old `dataset::build` shape: one thread,
        // one Vec).
        let mut rows = 0usize;
        let r_serial = bench.run(
            &format!("serial reference ({tuples} tuples x 8 cfgs)"),
            || {
                let recs = dataset::build_serial(&templates, &sweep, &dev, &serial_cfg);
                rows = recs.len();
                black_box(recs);
            },
        );
        rep.record_throughput(&r_serial, rows as f64, "rows");

        // Streamed chunk-parallel build into memory.
        let r_mem = bench.run(
            &format!("streamed -> MemorySink ({threads} threads)"),
            || {
                let mut sink = MemorySink::new();
                dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                    .unwrap();
                black_box(sink.records);
            },
        );
        rep.record_throughput(&r_mem, rows as f64, "rows");

        // Streamed to round-robin CSV shards on disk.
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-perf-ds-{}", std::process::id()));
        let r_csv = bench.run("streamed -> ShardedCsvSink (4 shards)", || {
            let mut sink = ShardedCsvSink::create(&dir, 4, dev.key).unwrap();
            dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
            black_box(sink.written());
        });
        rep.record_throughput(&r_csv, rows as f64, "rows");
        std::fs::remove_dir_all(&dir).ok();

        // Streamed through a training-split reservoir.
        let r_res = bench.run("streamed -> ReservoirSink (cap 1000)", || {
            let mut sink = ReservoirSink::new(1000, 7);
            dataset::build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
            black_box(sink.records().len());
        });
        rep.record_throughput(&r_res, rows as f64, "rows");

        par_over_serial = r_serial.mean.as_secs_f64() / r_mem.mean.as_secs_f64();
        println!(
            "  parallel/serial speedup: {par_over_serial:.2}x over {rows} rows \
             ({threads} threads)\n"
        );
    }
    rep.note("parallel_over_serial", par_over_serial);

    // Format shootout: write + read a fabricated >= 100k-row block
    // through both shard formats. The generator is out of the loop, so
    // this isolates exactly what `generate --format bin` changes.
    {
        let rows = if smoke { 20_000 } else { 150_000 };
        let recs = fabricate(rows);
        let bench = profile();
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-perf-fmt-{}", std::process::id()));
        let mut means = Vec::new();
        for format in [ShardFormat::Csv, ShardFormat::Bin] {
            let r_w = bench.run(&format!("{format} write ({rows} rows, 4 shards)"), || {
                let mut s =
                    ShardedSink::create(&dir, 4, dev.key, Schema::V1, format)
                        .unwrap();
                for rec in &recs {
                    s.accept(rec).unwrap();
                }
                s.finish().unwrap();
                black_box(s.written());
            });
            rep.record_throughput(&r_w, rows as f64, "rows");
            let r_r = bench.run(&format!("{format} read ({rows} rows, 4 shards)"), || {
                let mut n = 0u64;
                sink::stream_sharded_rows(&dir, |_, _, row| {
                    n += 1;
                    black_box(&row);
                    Ok(())
                })
                .unwrap();
                assert_eq!(n, rows as u64);
            });
            rep.record_throughput(&r_r, rows as f64, "rows");
            means.push(r_w.mean.as_secs_f64() + r_r.mean.as_secs_f64());
            std::fs::remove_dir_all(&dir).ok();
        }
        let ratio = means[0] / means[1];
        println!(
            "  binary over CSV (write+read): {ratio:.2}x over {rows} rows\n"
        );
        rep.note("bin_over_csv_write_read", ratio);
        rep.note("format_shootout_rows", rows as f64);
    }

    let path = rep.write().expect("write BENCH_perf_dataset.json");
    println!("json report: {}", path.display());
}
