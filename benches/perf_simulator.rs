//! Perf bench (L3 substrate): simulator + feature-extraction throughput —
//! the dominant cost of dataset generation at paper scale (5.6M
//! instances), and forest-training throughput.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::sim::exec::{measure, MeasureConfig};
use lmtuner::sim::timing::{simulate, Variant};
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::util::prng::Rng;

fn main() {
    let dev = DeviceSpec::m2090();
    let mut rng = Rng::new(0x51AB);
    let templates = generator::generate_n(&mut rng, 4);
    let sweep = LaunchSweep::new(2048, 2048);
    let launch = sweep.all()[sweep.len() / 2];
    let descriptors: Vec<_> =
        templates.iter().map(|t| t.descriptor(&launch, &dev)).collect();
    let bench = Bencher::default();

    // Raw timing-model evaluations.
    let r = bench.run("simulate: baseline+optimized pair", || {
        for d in &descriptors {
            black_box(simulate(d, &dev, Variant::Baseline));
            black_box(simulate(d, &dev, Variant::Optimized));
        }
    });
    report_throughput(&r, descriptors.len() as f64, "pairs");

    // Full measure (pair + noise + features).
    let mcfg = MeasureConfig::default();
    let r = bench.run("measure: record incl. features", || {
        for d in &descriptors {
            black_box(measure(d, &dev, &mcfg));
        }
    });
    report_throughput(&r, descriptors.len() as f64, "records");

    // End-to-end dataset build (generation + sweep sampling + measure).
    let cfg = dataset::BuildConfig { configs_per_kernel: 16, ..Default::default() };
    let mut n = 0;
    let r = bench.run("dataset: build (4 tuples x 16 cfgs)", || {
        let recs = dataset::build(&templates, &sweep, &dev, &cfg);
        n = recs.len();
        black_box(recs);
    });
    report_throughput(&r, n as f64, "instances");

    // Forest training throughput (joint: all three targets).
    let recs = dataset::build(&templates, &sweep, &dev, &cfg);
    let fcfg = ForestConfig::default();
    let r = Bencher::coarse().run("train: 20-tree forest", || {
        black_box(Forest::fit_tune_records(&recs, &fcfg).expect("finite records"));
    });
    report_throughput(&r, recs.len() as f64, "samples");
}
