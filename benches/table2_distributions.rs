//! Bench: regenerate Table 2 — the compile-time parameter distributions —
//! and time the sampler.

use lmtuner::report::tables;
use lmtuner::synth::sampler;
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::util::prng::Rng;

fn main() {
    let b = Bencher::default();
    let n = 100_000;
    let r = b.run("table2: sample context tuples", || {
        let mut rng = Rng::new(0x7AB1E2);
        black_box(sampler::sample_tuples(&mut rng, n));
    });
    report_throughput(&r, n as f64, "tuples");
    println!("\n{}", tables::table2(0x7AB1E2, n));
}
