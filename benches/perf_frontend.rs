//! Frontend hot-path throughput: lex+parse and full parse+extract
//! kernels/sec over the four shipped `.cl` fixtures.
//!
//! Acceptance (DESIGN.md bench table): parse+extract sustains
//! >= 2000 kernels/sec on the fixture kernels — `lmtuner analyze` must
//! stay interactive, and a batch sweep over thousands of launch
//! configurations must be extraction-bound, not parser-bound.

use lmtuner::frontend::extract::extract_descriptor;
use lmtuner::frontend::{lint_program, parse_program, AnalyzeOptions, Bindings, SemaOptions};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::workloads;

fn fixture(name: &str) -> String {
    let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{}: {e}", p.display()))
}

fn main() {
    let dev = DeviceSpec::m2090();
    let launch = workloads::launch_over((16, 8), (512, 512));
    let conv_bind = Bindings::new().set("width", 512).set("rows_per_thread", 1).set("radius", 2);
    let cases: Vec<(String, AnalyzeOptions)> = vec![
        (
            fixture("convolution_row.cl"),
            AnalyzeOptions {
                target: "input".into(),
                kernel: None,
                launch,
                bindings: conv_bind.clone(),
            },
        ),
        (
            fixture("convolution_col.cl"),
            AnalyzeOptions {
                target: "input".into(),
                kernel: None,
                launch,
                bindings: conv_bind,
            },
        ),
        (
            fixture("matrixmul.cl"),
            AnalyzeOptions {
                target: "b".into(),
                kernel: None,
                launch,
                bindings: Bindings::new().set("size", 512).set("tile_k", 8),
            },
        ),
        (
            fixture("transpose.cl"),
            AnalyzeOptions {
                target: "output".into(),
                kernel: None,
                launch,
                bindings: Bindings::new().set("width", 512).set("height", 512),
            },
        ),
    ];
    let n = cases.len() as f64;
    let b = Bencher::default();

    let r = b.run("frontend: lex+parse fixtures", || {
        for (src, _) in &cases {
            black_box(parse_program(src).expect("fixture parses"));
        }
    });
    report_throughput(&r, n, "kernels");

    let r = b.run("frontend: parse+extract fixtures", || {
        for (src, opts) in &cases {
            let prog = parse_program(src).expect("fixture parses");
            black_box(extract_descriptor(&prog, opts, &dev).expect("fixture extracts"));
        }
    });
    report_throughput(&r, n, "kernels");
    let per_sec = r.throughput(n);
    println!(
        "acceptance: parse+extract {per_sec:.0} kernels/s (bar: >= 2000) {}",
        if per_sec >= 2000.0 { "PASS" } else { "MISS" }
    );

    // Extraction alone, re-analyzing one parse under many launches — the
    // `analyze` sweep shape.
    let parsed: Vec<_> = cases
        .iter()
        .map(|(src, opts)| (parse_program(src).expect("fixture parses"), opts))
        .collect();
    let r = b.run("frontend: extract-only (pre-parsed)", || {
        for (prog, opts) in &parsed {
            black_box(extract_descriptor(prog, opts, &dev).expect("fixture extracts"));
        }
    });
    report_throughput(&r, n, "kernels");

    // The sema gate `analyze` now runs before every extraction, and the
    // full `lint` path (sema + one certificate per accessed array).
    let sema: Vec<(_, SemaOptions)> = parsed
        .iter()
        .map(|(prog, opts)| {
            (
                prog,
                SemaOptions {
                    kernel: None,
                    launch: opts.launch,
                    bindings: opts.bindings.clone(),
                    certificates: false,
                },
            )
        })
        .collect();
    let r = b.run("frontend: lint (sema gate, pre-parsed)", || {
        for (prog, opts) in &sema {
            black_box(lint_program(prog, opts, &dev).expect("fixture lints"));
        }
    });
    report_throughput(&r, n, "kernels");

    let certified: Vec<(_, SemaOptions)> = sema
        .iter()
        .map(|(prog, opts)| (*prog, SemaOptions { certificates: true, ..opts.clone() }))
        .collect();
    let r = b.run("frontend: lint+certify (pre-parsed)", || {
        for (prog, opts) in &certified {
            black_box(lint_program(prog, opts, &dev).expect("fixture lints"));
        }
    });
    report_throughput(&r, n, "kernels");
}
