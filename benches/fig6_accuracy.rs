//! Bench: regenerate Figure 6 — both accuracy metrics on held-out
//! synthetic instances and all eight real benchmarks — timing the
//! train-and-evaluate pipeline.

use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::report::figures;
use lmtuner::util::bench::{black_box, report, Bencher};

fn main() {
    let dev = DeviceSpec::m2090();
    let scale: f64 = std::env::var("LMTUNER_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.2);
    let cfg = TrainConfig { scale, configs_per_kernel: 24, ..Default::default() };

    let b = Bencher { min_iters: 1, max_iters: 3, warmup_iters: 0, ..Default::default() };
    let mut fig = String::new();
    let r = b.run("fig6: generate + train + evaluate", || {
        let out = train::run(&dev, &cfg);
        fig = figures::fig6(&out.synth_accuracy, &out.per_benchmark);
        black_box(&fig);
    });
    report(&r);
    println!("\n{fig}");
    println!("paper: 86% count-based / ~95% penalty-weighted (synthetic),");
    println!("       ~95% penalty-weighted on real kernels with count drops");
    println!("       on SAD, TPACF and MRI-GRIDDING.");
}
