//! Bench: regenerate Figure 1a — the synthetic-kernel speedup histogram —
//! and time the dataset-construction pipeline that produces it.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::report::hist;
use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::util::prng::Rng;

fn main() {
    let dev = DeviceSpec::m2090();
    let sweep = LaunchSweep::new(2048, 2048);
    let mut rng = Rng::new(0xF161A);
    let templates = generator::generate_n(&mut rng, 10);
    let cfg = dataset::BuildConfig { configs_per_kernel: 16, ..Default::default() };

    // Timed: the full generate->simulate pipeline.
    let mut records = Vec::new();
    let b = Bencher::coarse();
    let r = b.run("fig1a: build+measure synthetic instances", || {
        records = dataset::build(&templates, &sweep, &dev, &cfg);
        black_box(records.len());
    });
    report_throughput(&r, records.len() as f64, "instances");

    // The figure itself (histograms read the scalar half of the record).
    let bases: Vec<_> = records.iter().map(|r| r.base.clone()).collect();
    println!("\n{}", hist::render("Figure 1a: synthetic kernels", &bases, 48));
    let (n, ben, geo, max) = dataset::summarize(&records);
    println!(
        "summary: n={n} beneficial={:.1}% geomean={geo:.2}x max={max:.1}x (paper range 0.03x-49.6x)",
        100.0 * ben
    );
}
