//! Perf bench (L3/L2/L1 hot path): forest inference throughput/latency.
//!
//! Compares:
//!   native        — rust recursive-tree traversal (training-time path)
//!   encoded       — rust flat-tensor traversal, one row at a time
//!   encoded-exec  — the reference BatchExecutor over the tensor
//!                   encoding, single thread, per batch size
//!   flat / flat-q — the compiled SoA hot path (runtime/fastexec),
//!                   float and quantized-u8 compares, single thread,
//!                   per batch size — this is what serving runs
//!   joint         — verdict + workgroup planes: the old 3-pass walk,
//!                   the single-pass encoded walk, and the flat
//!                   one-traversal gather
//!   pjrt:bN       — the AOT Pallas/XLA executable at each batch variant
//!                   (skipped when artifacts are absent)
//!
//! This is the §Perf driver for EXPERIMENTS.md. Derived ratios land as
//! `note` entries in BENCH_perf_inference.json; the headline is
//! `flat_over_encoded_exec_b4096` (target: >= 10x single-thread).
//!
//! Set LMTUNER_BENCH_SMOKE=1 for a seconds-scale smoke run (CI): same
//! sections, same JSON shape, fewer iterations — the ratios are then
//! indicative, not publishable.

use std::sync::Arc;
use std::time::Duration;

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::{self, NUM_FEATURES};
use lmtuner::ml::export;
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::obs::metrics::{ExecTelemetry, MetricsRegistry};
use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
use lmtuner::runtime::fastexec::{FlatForest, FlatForestExecutor, FlatMode};
use lmtuner::runtime::forest_exec::ForestExecutor;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::util::bench::{black_box, Bencher, JsonReport};
use lmtuner::util::prng::Rng;
use lmtuner::workloads;

fn smoke() -> bool {
    std::env::var("LMTUNER_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() -> anyhow::Result<()> {
    let dev = DeviceSpec::m2090();
    let smoke = smoke();
    if smoke {
        println!("smoke mode: reduced iterations, indicative numbers only");
    }

    // Realistic model: train on a quick synthetic set.
    let mut rng = Rng::new(0x1FE2);
    let templates =
        lmtuner::synth::generator::generate_n(&mut rng, if smoke { 4 } else { 8 });
    let sweep = lmtuner::synth::sweep::LaunchSweep::new(2048, 2048);
    let recs = lmtuner::synth::dataset::build(
        &templates,
        &sweep,
        &dev,
        &lmtuner::synth::dataset::BuildConfig { configs_per_kernel: 8, ..Default::default() },
    );
    // Joint (schema v2) model: the inference hot path now carries the
    // workgroup planes too, so the bench times what serving actually runs.
    let forest = Forest::fit_tune_records(&recs, &ForestConfig::default())
        .expect("finite, labeled records");

    // Realistic queries: the full real-benchmark feature stream.
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for b in workloads::all() {
        for d in (b.instances)(&dev) {
            rows.push(features::extract(&d).to_vec());
        }
    }
    let n = rows.len();
    println!("{n} query rows, forest: {}", forest.config_summary);

    let bench = if smoke {
        Bencher {
            warmup_iters: 1,
            min_iters: 2,
            min_time: Duration::from_millis(10),
            max_iters: 4,
        }
    } else {
        Bencher::default()
    };
    let batch_sizes = [64usize, 256, 1024, 4096];
    let mut rep = JsonReport::new("perf_inference");

    // L3 native recursive.
    let r = bench.run("native: recursive trees", || {
        for row in &rows {
            black_box(forest.predict(row));
        }
    });
    rep.record_throughput(&r, n as f64, "pred");

    // L3 flat encoded, row at a time.
    let contract = export::ExportContract::default();
    let enc = export::encode(&forest, contract);
    let r = bench.run("encoded: flat arrays", || {
        for row in &rows {
            black_box(enc.predict(row));
        }
    });
    rep.record_throughput(&r, n as f64, "pred");

    // The compiled hot path vs the reference executor, single thread per
    // batch size — an apples-to-apples core-for-core comparison of the
    // two serving backends.
    let flat = Arc::new(FlatForest::compile(&enc)?);
    println!(
        "flat forest: {} live trees, {} nodes, quantized tables {}",
        flat.num_live_trees(),
        flat.num_nodes(),
        if flat.quantized_exact() { "exact" } else { "lossy" }
    );
    let enc_exec = NativeForestExecutor::with_parallelism(enc.clone(), 1, 1 << 20);
    let flat_f = FlatForestExecutor::with_parallelism(flat.clone(), 1, 1 << 20)
        .mode(FlatMode::Float);
    let flat_q = FlatForestExecutor::with_parallelism(flat.clone(), 1, 1 << 20)
        .mode(FlatMode::Quantized);
    let mut ratio_b4096 = (0.0f64, 0.0f64); // (encoded-exec mean, flat-q mean)
    for &bsz in &batch_sizes {
        let chunk: Vec<Vec<f64>> =
            rows.iter().cycle().take(bsz).cloned().collect();
        let re = bench.run(&format!("encoded-exec 1t: batch {bsz}"), || {
            black_box(enc_exec.predict(&chunk).unwrap());
        });
        rep.record_throughput(&re, bsz as f64, "pred");
        let rf = bench.run(&format!("flat 1t: batch {bsz}"), || {
            black_box(flat_f.predict(&chunk).unwrap());
        });
        rep.record_throughput(&rf, bsz as f64, "pred");
        let rq = bench.run(&format!("flat-q 1t: batch {bsz}"), || {
            black_box(flat_q.predict(&chunk).unwrap());
        });
        rep.record_throughput(&rq, bsz as f64, "pred");
        if bsz == 4096 {
            ratio_b4096 = (re.mean.as_secs_f64(), rq.mean.as_secs_f64());
        }
    }
    let flat_speedup = ratio_b4096.0 / ratio_b4096.1;
    println!("  flat-q/encoded-exec speedup at b4096 (1 thread): {flat_speedup:.2}x");
    rep.note("flat_over_encoded_exec_b4096", flat_speedup);

    // Multithreaded flat: the actual per-shard serving configuration.
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1);
    {
        let chunk: Vec<Vec<f64>> = rows.iter().cycle().take(4096).cloned().collect();
        let exec = FlatForestExecutor::with_parallelism(flat.clone(), threads, 256);
        let r = bench.run(&format!("flat {threads}t: batch 4096"), || {
            black_box(exec.predict(&chunk).unwrap());
        });
        rep.record_throughput(&r, chunk.len() as f64, "pred");
    }

    // Telemetry overhead on the serving hot path: the same flat-q b4096
    // run with an ExecTelemetry sink attached. The instrumented path
    // pays one Instant read and one mutex lock per batch — never per
    // row — so it must stay within 3% of the uninstrumented executor.
    {
        let chunk: Vec<Vec<f64>> =
            rows.iter().cycle().take(4096).cloned().collect();
        let plain = FlatForestExecutor::with_parallelism(flat.clone(), 1, 1 << 20)
            .mode(FlatMode::Quantized);
        let sink = Arc::new(ExecTelemetry::new());
        let instrumented =
            FlatForestExecutor::with_parallelism(flat.clone(), 1, 1 << 20)
                .mode(FlatMode::Quantized)
                .with_telemetry(Arc::clone(&sink));
        let rp = bench.run("flat-q 1t uninstrumented: batch 4096", || {
            black_box(plain.predict(&chunk).unwrap());
        });
        rep.record_throughput(&rp, chunk.len() as f64, "pred");
        let ri = bench.run("flat-q 1t telemetry: batch 4096", || {
            black_box(instrumented.predict(&chunk).unwrap());
        });
        rep.record_throughput(&ri, chunk.len() as f64, "pred");
        let overhead = ri.mean.as_secs_f64() / rp.mean.as_secs_f64() - 1.0;
        println!(
            "  telemetry overhead at b4096 (1 thread): {:+.2}% \
             ({} batches, {:.0} rows/s recorded)",
            100.0 * overhead,
            sink.batches(),
            sink.rows_per_second()
        );
        rep.note("telemetry_overhead_frac_b4096", overhead);
        // The recorded registry rides along in the same report — live
        // telemetry and bench snapshots share one JSON format.
        let mut reg = MetricsRegistry::new();
        sink.export("bench.flat_q", &mut reg);
        rep.set_section("metrics", reg.to_json());
        if !smoke {
            assert!(
                overhead <= 0.03,
                "telemetry overhead {overhead:.4} above the 3% budget"
            );
        }
    }

    // Joint recommendation path: verdict + workgroup planes per row.
    // Three generations of the same answer: the original three full
    // walks (predict + two predict_extra passes), the single-pass
    // encoded walk, and the flat one-traversal gather of all K planes.
    if enc.num_outputs() >= 3 {
        let chunk: Vec<Vec<f64>> = rows.iter().cycle().take(4096).cloned().collect();
        let r3 = bench.run("joint 3-pass: batch 4096", || {
            for row in &chunk {
                black_box((
                    enc.predict(row),
                    enc.predict_extra(row, 0),
                    enc.predict_extra(row, 1),
                ));
            }
        });
        rep.record_throughput(&r3, chunk.len() as f64, "pred");
        let r1 = bench.run("joint single-pass encoded: batch 4096", || {
            for row in &chunk {
                black_box(enc.predict_wg_logs(row));
            }
        });
        rep.record_throughput(&r1, chunk.len() as f64, "pred");
        let rf = bench.run("joint flat-q one-traversal: batch 4096", || {
            black_box(flat_q.predict_outputs(&chunk).unwrap());
        });
        rep.record_throughput(&rf, chunk.len() as f64, "pred");
        let joint_speedup = r3.mean.as_secs_f64() / rf.mean.as_secs_f64();
        println!("  flat-q joint / 3-pass speedup at b4096: {joint_speedup:.2}x");
        rep.note("flatq_joint_over_3pass_b4096", joint_speedup);
    }

    // L1/L2 via PJRT, per batch variant.
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(skipping pjrt variants: run `make artifacts`)");
        let out = rep.write()?;
        println!("wrote {}", out.display());
        return Ok(());
    }
    let engine = Arc::new(Engine::new(dir)?);
    let enc2 = export::encode(
        &forest,
        export::ExportContract {
            num_trees: engine.manifest.num_trees,
            max_nodes: engine.manifest.max_nodes,
            max_depth: engine.manifest.max_depth,
            num_features: NUM_FEATURES,
        },
    );
    let variants = engine.manifest.forest_batch_sizes.clone();
    let exec = ForestExecutor::new(engine, &enc2)?;
    for &bsz in variants.iter() {
        let chunk: Vec<Vec<f64>> =
            rows.iter().cycle().take(bsz).cloned().collect();
        let r = bench.run(&format!("pjrt: batch {bsz}"), || {
            black_box(exec.predict(&chunk).unwrap());
        });
        rep.record_throughput(&r, bsz as f64, "pred");
    }
    let out = rep.write()?;
    println!("wrote {}", out.display());
    Ok(())
}
