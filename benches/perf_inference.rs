//! Perf bench (L3/L2/L1 hot path): forest inference throughput/latency.
//!
//! Compares:
//!   native        — rust recursive-tree traversal (training-time path)
//!   encoded       — rust flat-array traversal, one row at a time
//!   native-batch  — the BatchExecutor native backend (chunked parallel
//!                   traversal of the tensor encoding), per batch size
//!   pjrt:bN       — the AOT Pallas/XLA executable at each batch variant
//!                   (skipped when artifacts are absent)
//!
//! This is the §Perf driver for EXPERIMENTS.md.

use std::sync::Arc;

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::{self, NUM_FEATURES};
use lmtuner::ml::export;
use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::runtime::executor::{BatchExecutor, NativeForestExecutor};
use lmtuner::runtime::forest_exec::ForestExecutor;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::util::bench::{black_box, Bencher, JsonReport};
use lmtuner::util::prng::Rng;
use lmtuner::workloads;

fn main() -> anyhow::Result<()> {
    let dev = DeviceSpec::m2090();

    // Realistic model: train on a quick synthetic set.
    let mut rng = Rng::new(0x1FE2);
    let templates = lmtuner::synth::generator::generate_n(&mut rng, 8);
    let sweep = lmtuner::synth::sweep::LaunchSweep::new(2048, 2048);
    let recs = lmtuner::synth::dataset::build(
        &templates,
        &sweep,
        &dev,
        &lmtuner::synth::dataset::BuildConfig { configs_per_kernel: 8, ..Default::default() },
    );
    // Joint (schema v2) model: the inference hot path now carries the
    // workgroup planes too, so the bench times what serving actually runs.
    let forest = Forest::fit_tune_records(&recs, &ForestConfig::default())
        .expect("finite, labeled records");

    // Realistic queries: the full real-benchmark feature stream.
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for b in workloads::all() {
        for d in (b.instances)(&dev) {
            rows.push(features::extract(&d).to_vec());
        }
    }
    let n = rows.len();
    println!("{n} query rows, forest: {}", forest.config_summary);

    let bench = Bencher::default();
    let batch_sizes = [64usize, 256, 1024, 4096];
    let mut rep = JsonReport::new("perf_inference");

    // L3 native recursive.
    let r = bench.run("native: recursive trees", || {
        for row in &rows {
            black_box(forest.predict(row));
        }
    });
    rep.record_throughput(&r, n as f64, "pred");

    // L3 flat encoded, row at a time.
    let contract = export::ExportContract::default();
    let enc = export::encode(&forest, contract);
    let r = bench.run("encoded: flat arrays", || {
        for row in &rows {
            black_box(enc.predict(row));
        }
    });
    rep.record_throughput(&r, n as f64, "pred");

    // The native BatchExecutor backend at each batch size — this is the
    // artifact-free serving hot path, directly comparable to pjrt:bN.
    let native_exec = NativeForestExecutor::new(enc.clone());
    for &bsz in &batch_sizes {
        let chunk: Vec<Vec<f64>> =
            rows.iter().cycle().take(bsz).cloned().collect();
        let r = bench.run(&format!("native-batch: batch {bsz}"), || {
            black_box(native_exec.predict(&chunk).unwrap());
        });
        rep.record_throughput(&r, bsz as f64, "pred");
    }

    // Joint recommendation path: verdict + workgroup planes per row.
    {
        let chunk: Vec<Vec<f64>> = rows.iter().cycle().take(1024).cloned().collect();
        let r = bench.run("native-batch: joint wg, batch 1024", || {
            black_box(native_exec.predict_wg_logs(&chunk).unwrap());
        });
        rep.record_throughput(&r, chunk.len() as f64, "pred");
    }

    // L1/L2 via PJRT, per batch variant.
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(skipping pjrt variants: run `make artifacts`)");
        let out = rep.write()?;
        println!("wrote {}", out.display());
        return Ok(());
    }
    let engine = Arc::new(Engine::new(dir)?);
    let enc2 = export::encode(
        &forest,
        export::ExportContract {
            num_trees: engine.manifest.num_trees,
            max_nodes: engine.manifest.max_nodes,
            max_depth: engine.manifest.max_depth,
            num_features: NUM_FEATURES,
        },
    );
    let variants = engine.manifest.forest_batch_sizes.clone();
    let exec = ForestExecutor::new(engine, &enc2)?;
    for &bsz in variants.iter() {
        let chunk: Vec<Vec<f64>> =
            rows.iter().cycle().take(bsz).cloned().collect();
        let r = bench.run(&format!("pjrt: batch {bsz}"), || {
            black_box(exec.predict(&chunk).unwrap());
        });
        rep.record_throughput(&r, bsz as f64, "pred");
    }
    let out = rep.write()?;
    println!("wrote {}", out.display());
    Ok(())
}
