//! Bench: regenerate Figures 1b-1i — the eight real-benchmark speedup
//! histograms — timing the per-benchmark simulation sweeps.

use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::report::hist;
use lmtuner::sim::exec::{measure, MeasureConfig};
use lmtuner::util::bench::{black_box, report_throughput, Bencher};
use lmtuner::workloads;

fn main() {
    let dev = DeviceSpec::m2090();
    let cfg = MeasureConfig::default();
    let b = Bencher::default();
    for (i, bench) in workloads::all().into_iter().enumerate() {
        let instances = (bench.instances)(&dev);
        let mut records = Vec::new();
        let r = b.run(&format!("fig1{}: {}", (b'b' + i as u8) as char, bench.name), || {
            records = instances.iter().map(|d| measure(d, &dev, &cfg)).collect();
            black_box(records.len());
        });
        report_throughput(&r, records.len() as f64, "instances");
        println!(
            "{}",
            hist::render(
                &format!("Figure 1{}: {}", (b'b' + i as u8) as char, bench.name),
                &records,
                40
            )
        );
    }
}
