//! Forest-training throughput: the v1 exact sort-based split engine vs
//! the ml-v2 pre-binned histogram engine, in rows/sec (rows = samples ×
//! trees). The binned/exact ratio is the headline number — the ml-v2
//! acceptance bar is >= 2x at n >= 50k, which is what makes paper-scale
//! (`--scale 1.0`, millions of instances) forest training tractable.
//!
//! Also reports `predict_batch` throughput at 1 thread vs all host
//! threads (the evaluation half of the training loop).

use std::time::Duration;

use lmtuner::ml::forest::{Forest, ForestConfig};
use lmtuner::ml::tree::SplitEngine;
use lmtuner::util::bench::{black_box, Bencher, JsonReport};
use lmtuner::util::prng::Rng;

const NUM_FEATURES: usize = 18;

/// Synthetic column-major training matrix with a learnable nonlinear
/// signal — cheap to generate, so the bench times the trainer, not the
/// simulator.
fn synth_matrix(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
    let mut rng = Rng::new(seed);
    let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
        .map(|_| (0..n).map(|_| rng.range_f64(-2.0, 2.0)).collect())
        .collect();
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let (a, b, c) = (x[0][i], x[1][i], x[2][i]);
            (a * b).signum() * (1.0 + 0.5 * c.abs()) + 0.1 * rng.normal()
        })
        .collect();
    (x, y)
}

fn main() {
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!("host threads: {threads}");
    // LMTUNER_BENCH_SMOKE=1: one iteration over much smaller matrices —
    // a seconds-scale CI snapshot with the same sections and JSON shape.
    let smoke =
        std::env::var("LMTUNER_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if smoke {
        println!("smoke mode: reduced sizes, indicative numbers only");
    }
    // Few, long iterations: an exact 50k-row fit is seconds, not micros.
    let bench = Bencher {
        warmup_iters: 0,
        min_iters: 1,
        min_time: Duration::from_millis(if smoke { 0 } else { 50 }),
        max_iters: if smoke { 1 } else { 3 },
    };
    let trees = 4;
    let mut rep = JsonReport::new("perf_train");

    let sizes: &[usize] = if smoke { &[5_000] } else { &[10_000, 50_000] };
    for &n in sizes {
        let (x, y) = synth_matrix(n, 0xBEEF ^ n as u64);
        let cfg_for = |engine: SplitEngine| {
            let mut cfg = ForestConfig { num_trees: trees, threads, ..Default::default() };
            cfg.tree.engine = engine;
            cfg.tree.min_samples_leaf = 2;
            cfg
        };

        let exact_cfg = cfg_for(SplitEngine::Exact);
        let r_exact = bench.run(&format!("exact  fit n={n} trees={trees}"), || {
            black_box(Forest::fit(&x, &y, &exact_cfg));
        });
        rep.record_throughput(&r_exact, (n * trees) as f64, "rows");

        let binned_cfg = cfg_for(SplitEngine::Binned);
        let mut forest = None;
        let r_binned = bench.run(&format!("binned fit n={n} trees={trees}"), || {
            forest = Some(Forest::fit(&x, &y, &binned_cfg));
        });
        rep.record_throughput(&r_binned, (n * trees) as f64, "rows");
        let fit_speedup =
            r_exact.mean.as_secs_f64() / r_binned.mean.as_secs_f64();
        println!("  binned/exact fit speedup: {fit_speedup:.2}x at n={n}\n");
        rep.note(&format!("binned_exact_fit_speedup_n{n}"), fit_speedup);

        // Batch prediction: serial vs fanned across the host.
        let forest = forest.expect("bench ran");
        let probes: Vec<Vec<f64>> = (0..if smoke { 4_000 } else { 20_000 })
            .map(|i| (0..NUM_FEATURES).map(|f| x[f][i % n]).collect())
            .collect();
        let refs: Vec<&[f64]> = probes.iter().map(|p| p.as_slice()).collect();
        let pb = Bencher::coarse();
        let r1 = pb.run("predict_batch 1 thread", || {
            black_box(forest.predict_batch_with(&refs, 1));
        });
        rep.record_throughput(&r1, refs.len() as f64, "rows");
        let rn = pb.run(&format!("predict_batch {threads} threads"), || {
            black_box(forest.predict_batch_with(&refs, threads));
        });
        rep.record_throughput(&rn, refs.len() as f64, "rows");
        println!(
            "  parallel/serial predict speedup: {:.2}x ({} threads)\n",
            r1.mean.as_secs_f64() / rn.mean.as_secs_f64(),
            threads
        );
    }
    let out = rep.write().expect("write bench json");
    println!("wrote {}", out.display());
}
