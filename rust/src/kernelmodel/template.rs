//! The synthetic kernel template (paper Fig. 3 + Table 1).
//!
//! A template instance fixes the 13 compile-time/run-time parameters; a
//! `Launch` turns it into a *kernel instance*. Both lower to the unified
//! `KernelDescriptor` the simulator and feature extractor consume.

use super::access::HomePattern;
use super::descriptor::KernelDescriptor;
use super::launch::Launch;
use super::stencil::StencilPattern;
use crate::gpu::spec::DeviceSpec;

/// Table 1: the 13 parameters of the synthetic kernel template.
#[derive(Clone, Debug, PartialEq)]
pub struct Template {
    /// IN_H, IN_W — target array geometry (paper fixes 2048 x 2048).
    pub in_h: u32,
    pub in_w: u32,
    /// HOME_ACCESS_PATTERN — one of the seven of Fig. 4.
    pub home: HomePattern,
    /// N, M — trip counts of loops i and j.
    pub n: u32,
    pub m: u32,
    /// STENCIL_PATTERN, STENCIL_RADIUS — Fig. 5.
    pub stencil: StencilPattern,
    pub radius: u32,
    /// NUM_COMP_ILB / NUM_COMP_EP — fused-multiply-adds in the inner loop
    /// body and the epilogue.
    pub comp_ilb: u32,
    pub comp_ep: u32,
    /// NUM_COAL_ACCESSES_ILB / EP — coalesced contextual accesses (in2).
    pub coal_ilb: u32,
    pub coal_ep: u32,
    /// NUM_UNCOAL_ACCESSES_ILB / EP — non-coalesced contextual accesses.
    pub uncoal_ilb: u32,
    pub uncoal_ep: u32,
}

impl Template {
    /// A neutral default used as a base by tests and samplers.
    pub fn base() -> Template {
        Template {
            in_h: 2048,
            in_w: 2048,
            home: HomePattern::XyReuse,
            n: 16,
            m: 16,
            stencil: StencilPattern::Rectangular,
            radius: 1,
            comp_ilb: 10,
            comp_ep: 10,
            coal_ilb: 1,
            coal_ep: 1,
            uncoal_ilb: 0,
            uncoal_ep: 0,
        }
    }

    /// Stencil taps = accesses to the target array per inner iteration
    /// (paper feature #4).
    pub fn taps(&self) -> u32 {
        self.stencil.taps(self.radius)
    }

    /// Estimated registers per thread of the *unoptimized* kernel (paper
    /// feature #8). A deterministic proxy for what the OpenCL compiler
    /// would allocate: base bookkeeping + address arithmetic per tap +
    /// live temporaries for the FMA chains and contextual accesses.
    pub fn base_regs(&self, dev: &DeviceSpec) -> u32 {
        let r = 12
            + 2 * self.taps().min(10)
            + self.comp_ilb.div_ceil(6)
            + self.comp_ep.div_ceil(10)
            + 2 * (self.coal_ilb + self.uncoal_ilb)
            + (self.coal_ep + self.uncoal_ep);
        r.min(dev.max_regs_per_thread)
    }

    /// Extra registers the local-memory transform needs (staging indices,
    /// cooperative-copy loop, barrier bookkeeping).
    pub fn opt_extra_regs(&self, launch: &Launch, dev: &DeviceSpec) -> u32 {
        let extra = if self.home.fixes_coalescing(launch, dev.warp_size) {
            6
        } else {
            4
        };
        (self.base_regs(dev) + extra).min(dev.max_regs_per_thread)
            - self.base_regs(dev)
    }

    /// Lower the template under a launch configuration to the unified
    /// kernel descriptor.
    pub fn descriptor(&self, launch: &Launch, dev: &DeviceSpec) -> KernelDescriptor {
        assert!(launch.valid(), "invalid launch {launch:?}");
        let taps = self.taps();
        let inner_iters = self.n as u64 * self.m as u64;
        let (rows0, cols0) = self.home.region(launch, self.n, self.m);
        let r = self.radius as u64;
        let (region_rows, region_cols) = (rows0 + 2 * r, cols0 + 2 * r);
        let region_elems = region_rows * region_cols;

        // Paper feature #1 — degree of data reuse: average number of
        // accesses per distinct element of the staged region (combines
        // inter-thread sharing with stencil-overlap reuse).
        let total_accesses =
            launch.wg.size() as f64 * taps as f64 * inner_iters as f64;
        let reuse = total_accesses / region_elems as f64;

        let (wx, wy) = launch.wus_per_wi(self.in_w, self.in_h);

        KernelDescriptor {
            name: format!(
                "synth_{}_{}r{}_n{}m{}",
                self.home, self.stencil, self.radius, self.n, self.m
            ),
            taps,
            inner_iters,
            comp_ilb: self.comp_ilb,
            comp_ep: self.comp_ep,
            coal_ilb: self.coal_ilb,
            coal_ep: self.coal_ep,
            uncoal_ilb: self.uncoal_ilb,
            uncoal_ep: self.uncoal_ep,
            tx_per_target_access: self.home.tx_per_access(launch, dev.warp_size),
            uncoal_ctx_tx: dev.warp_size.min(launch.wg.size()) as f64,
            region_rows,
            region_cols,
            reuse,
            offset_bounds: self.stencil.offset_bounds(self.radius),
            base_regs: self.base_regs(dev),
            opt_extra_regs: self.opt_extra_regs(launch, dev),
            launch: *launch,
            wus_per_wi: wx as u64 * wy as u64,
            elem_bytes: 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::launch::{GridGeom, WgGeom};

    fn launch() -> Launch {
        Launch::new(WgGeom { w: 16, h: 8 }, GridGeom { w: 512, h: 256 })
    }

    #[test]
    fn descriptor_basic_quantities() {
        let t = Template::base();
        let dev = DeviceSpec::m2090();
        let d = t.descriptor(&launch(), &dev);
        assert_eq!(d.taps, 9); // rect radius 1
        assert_eq!(d.inner_iters, 256);
        // xy_reuse region: (16 + 2) x (16 + 2)
        assert_eq!((d.region_rows, d.region_cols), (18, 18));
        // reuse = 128 wi * 9 taps * 256 iters / 324 elems
        let expect = 128.0 * 9.0 * 256.0 / 324.0;
        assert!((d.reuse - expect).abs() < 1e-9);
        assert_eq!(d.wus_per_wi, 4 * 8);
    }

    #[test]
    fn regs_monotone_in_context() {
        let dev = DeviceSpec::m2090();
        let mut t = Template::base();
        let r0 = t.base_regs(&dev);
        t.comp_ilb += 24;
        t.coal_ilb += 3;
        let r1 = t.base_regs(&dev);
        assert!(r1 > r0);
        t.comp_ilb = 10_000; // silly — must cap
        assert_eq!(t.base_regs(&dev), dev.max_regs_per_thread);
    }

    #[test]
    fn opt_extra_regs_capped_at_device_max() {
        let dev = DeviceSpec::m2090();
        let mut t = Template::base();
        t.comp_ilb = 400; // drives base to the 63 cap
        let l = launch();
        assert_eq!(t.opt_extra_regs(&l, &dev), 0);
    }

    #[test]
    fn radius_zero_star_single_tap() {
        let mut t = Template::base();
        t.stencil = StencilPattern::Star;
        t.radius = 0;
        assert_eq!(t.taps(), 1);
        let dev = DeviceSpec::m2090();
        let d = t.descriptor(&launch(), &dev);
        assert_eq!(d.offset_bounds, (0, 0, 0, 0));
    }
}
