//! Home-access patterns of the synthetic kernel template (paper Fig. 4).
//!
//! The home coordinate of the target-array accesses is a linear function
//! (fo, fi) of the work-unit coordinate (wu_x, wu_y) and the loop
//! iterators (i, j). The paper designs 7 function tuples spanning the
//! interesting corners of {data reuse} x {memory coalescing}. The figure
//! itself is not machine-readable, so we fix 7 concrete tuples that honor
//! every constraint the text states (N large for xy-reuse and
//! */-reuse-row; M large for xy-reuse and */-reuse-col; labels WI(x,*) of
//! shared arrows) and span reuse in {1, wg_w, wg_h, wg_size} and warp
//! transactions in {1 (broadcast), 1 (coalesced), 32/wg_w, wg_w, 32}:
//!
//! | pattern      | home (row, col)        | reuse by | baseline warp tx |
//! |--------------|------------------------|----------|------------------|
//! | xy_reuse     | (i, j)                 | whole wg | broadcast: 1     |
//! | x_reuse_row  | (wu_y, i*M + j)        | wi_x     | distinct rows    |
//! | x_reuse_col  | (j, wu_y)              | wi_x     | adjacent cols: 1 |
//! | y_reuse_row  | (wu_x, i*M + j)        | wi_y     | wg_w rows        |
//! | y_reuse_col  | (j, wu_x)              | wi_y     | adjacent cols: 1 |
//! | no_reuse_row | (wu_lin, i*M + j)      | nobody   | 32 rows          |
//! | no_reuse_swap| (wu_x + i, wu_y + j)   | nobody   | wg_w rows        |
//!
//! `wu_lin` is the linearized work-unit id (one row of the target array
//! per work unit). `no_reuse_swap` is the transposed-tile pattern (each
//! work unit touches the (wu_x, wu_y) cell): zero reuse, fully scattered,
//! but a *small* stageable region — the matrix-transpose shape.

use std::fmt;

use super::launch::Launch;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum HomePattern {
    XyReuse,
    XReuseRow,
    XReuseCol,
    YReuseRow,
    YReuseCol,
    NoReuseRow,
    NoReuseSwap,
}

pub use HomePattern::*;

impl HomePattern {
    pub const ALL: [HomePattern; 7] = [
        XyReuse, XReuseRow, XReuseCol, YReuseRow, YReuseCol, NoReuseRow,
        NoReuseSwap,
    ];

    /// Trip-count value set for loop i (paper §5: 8..64 for xy-reuse and
    /// x/y-reuse-row, else 1..8).
    pub fn n_values(&self) -> [u32; 4] {
        match self {
            XyReuse | XReuseRow | YReuseRow => [8, 16, 32, 64],
            _ => [1, 2, 4, 8],
        }
    }

    /// Trip-count value set for loop j (8..64 for xy-reuse and
    /// x/y-reuse-col, else 1..8).
    pub fn m_values(&self) -> [u32; 4] {
        match self {
            XyReuse | XReuseCol | YReuseCol => [8, 16, 32, 64],
            _ => [1, 2, 4, 8],
        }
    }

    /// Average DRAM transactions induced by one warp for one target-array
    /// access in the *unoptimized* kernel (paper feature #3; 1 = fully
    /// coalesced / broadcast, 32 = fully scattered rows).
    pub fn tx_per_access(&self, launch: &Launch, warp_size: u32) -> f64 {
        let (dx, dy) = launch.warp_lanes(warp_size);
        match self {
            // All lanes hit the same element.
            XyReuse => 1.0,
            // Homes differ only through wu_y: `dy` distinct rows, one
            // element each -> one transaction per distinct row.
            XReuseRow => dy as f64,
            // Homes differ only through wu_y but along columns: `dy`
            // *adjacent* columns in one row -> single segment.
            XReuseCol => 1.0,
            // Homes differ through wu_x: `dx` distinct rows.
            YReuseRow => dx as f64,
            // `dx` adjacent columns in one row.
            YReuseCol => 1.0,
            // Every lane owns its own row.
            NoReuseRow => warp_size.min(launch.wg.size()) as f64,
            // Transposed tile: lanes along wi_x land in distinct rows.
            NoReuseSwap => dx as f64,
        }
    }

    /// Workitems of a workgroup that share each home access
    /// (inter-thread sharing component of paper feature #1).
    pub fn sharers(&self, launch: &Launch) -> f64 {
        let wg = launch.wg;
        match self {
            XyReuse => wg.size() as f64,
            XReuseRow | XReuseCol => wg.w as f64,
            YReuseRow | YReuseCol => wg.h as f64,
            NoReuseRow | NoReuseSwap => 1.0,
        }
    }

    /// Footprint (rows, cols) of all home coordinates one workgroup
    /// touches during one work-unit round, *before* the stencil apron —
    /// the grey region of Fig. 4.
    pub fn region(&self, launch: &Launch, n: u32, m: u32) -> (u64, u64) {
        let wg = launch.wg;
        let nm = n as u64 * m as u64;
        match self {
            XyReuse => (n as u64, m as u64),
            XReuseRow => (wg.h as u64, nm),
            XReuseCol => (m as u64, wg.h as u64),
            YReuseRow => (wg.w as u64, nm),
            YReuseCol => (m as u64, wg.w as u64),
            NoReuseRow => (wg.size() as u64, nm),
            NoReuseSwap => (
                (wg.w + n - 1) as u64,
                (wg.h + m - 1) as u64,
            ),
        }
    }

    /// Does the optimized copy of this pattern's region fix non-coalesced
    /// accesses (the paper's §2 second benefit)?
    pub fn fixes_coalescing(&self, launch: &Launch, warp_size: u32) -> bool {
        self.tx_per_access(launch, warp_size) > 1.0
    }

    pub fn parse(s: &str) -> Option<HomePattern> {
        Self::ALL.iter().copied().find(|p| p.to_string() == s)
    }
}

impl fmt::Display for HomePattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            XyReuse => "xy_reuse",
            XReuseRow => "x_reuse_row",
            XReuseCol => "x_reuse_col",
            YReuseRow => "y_reuse_row",
            YReuseCol => "y_reuse_col",
            NoReuseRow => "no_reuse_row",
            NoReuseSwap => "no_reuse_swap",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::launch::{GridGeom, WgGeom};

    fn launch(w: u32, h: u32) -> Launch {
        Launch::new(WgGeom { w, h }, GridGeom { w: 2048, h: 2048 })
    }

    #[test]
    fn n_m_value_sets_match_paper_rules() {
        // N large exactly for xy-reuse and x/y-reuse-row.
        for p in HomePattern::ALL {
            let n_large = p.n_values() == [8, 16, 32, 64];
            let expect = matches!(p, XyReuse | XReuseRow | YReuseRow);
            assert_eq!(n_large, expect, "{p}");
            let m_large = p.m_values() == [8, 16, 32, 64];
            let expect_m = matches!(p, XyReuse | XReuseCol | YReuseCol);
            assert_eq!(m_large, expect_m, "{p}");
        }
    }

    #[test]
    fn transactions_span_coalescing_spectrum() {
        let l = launch(32, 8);
        assert_eq!(XyReuse.tx_per_access(&l, 32), 1.0);
        assert_eq!(XReuseRow.tx_per_access(&l, 32), 1.0); // 32-wide rows
        assert_eq!(YReuseRow.tx_per_access(&l, 32), 32.0);
        assert_eq!(NoReuseRow.tx_per_access(&l, 32), 32.0);
        assert_eq!(NoReuseSwap.tx_per_access(&l, 32), 32.0);

        let narrow = launch(8, 32);
        assert_eq!(XReuseRow.tx_per_access(&narrow, 32), 4.0); // 4 rows/warp
        assert_eq!(YReuseRow.tx_per_access(&narrow, 32), 8.0);
    }

    #[test]
    fn sharers_match_reuse_dimension() {
        let l = launch(16, 8);
        assert_eq!(XyReuse.sharers(&l), 128.0);
        assert_eq!(XReuseRow.sharers(&l), 16.0);
        assert_eq!(YReuseCol.sharers(&l), 8.0);
        assert_eq!(NoReuseRow.sharers(&l), 1.0);
    }

    #[test]
    fn regions_scale_with_wg_and_trip_counts() {
        let l = launch(16, 8);
        assert_eq!(XyReuse.region(&l, 32, 64), (32, 64));
        assert_eq!(XReuseRow.region(&l, 16, 4), (8, 64));
        assert_eq!(YReuseCol.region(&l, 2, 32), (32, 16));
        assert_eq!(NoReuseRow.region(&l, 8, 8), (128, 64));
        assert_eq!(NoReuseSwap.region(&l, 1, 1), (16, 8));
        assert_eq!(NoReuseSwap.region(&l, 4, 8), (19, 15));
    }

    #[test]
    fn only_scattered_patterns_need_coalescing_fix() {
        let l = launch(32, 8);
        assert!(!XyReuse.fixes_coalescing(&l, 32));
        assert!(YReuseRow.fixes_coalescing(&l, 32));
        assert!(NoReuseRow.fixes_coalescing(&l, 32));
        assert!(NoReuseSwap.fixes_coalescing(&l, 32));
    }

    #[test]
    fn parse_roundtrip() {
        for p in HomePattern::ALL {
            assert_eq!(HomePattern::parse(&p.to_string()), Some(p));
        }
    }
}
