//! Launch configuration: 2D workgroup and grid geometry (paper §5).
//!
//! The paper sweeps all power-of-two 2D grid geometries with total size
//! >= 512 and all power-of-two 2D workgroup geometries with total size
//! <= 1024. Work units are distributed blocked across workgroups and
//! cyclic across workitems (paper §4.1).

/// Workgroup (thread-block) geometry, in workitems.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WgGeom {
    pub w: u32,
    pub h: u32,
}

impl WgGeom {
    pub fn size(&self) -> u32 {
        self.w * self.h
    }
}

/// Grid geometry, in *workitems* (total threads), factored 2D.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GridGeom {
    pub w: u32,
    pub h: u32,
}

impl GridGeom {
    pub fn size(&self) -> u64 {
        self.w as u64 * self.h as u64
    }
}

/// A complete launch configuration.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Launch {
    pub wg: WgGeom,
    pub grid: GridGeom,
}

impl Launch {
    pub fn new(wg: WgGeom, grid: GridGeom) -> Launch {
        Launch { wg, grid }
    }

    /// Total workitems.
    pub fn total_threads(&self) -> u64 {
        self.grid.size()
    }

    /// Workgroups in each dimension (grid is in workitems).
    pub fn groups_x(&self) -> u32 {
        self.grid.w / self.wg.w
    }

    pub fn groups_y(&self) -> u32 {
        self.grid.h / self.wg.h
    }

    pub fn total_groups(&self) -> u64 {
        self.groups_x() as u64 * self.groups_y() as u64
    }

    /// Is this launch shape-valid (wg divides grid, nonzero)?
    pub fn valid(&self) -> bool {
        self.wg.w > 0
            && self.wg.h > 0
            && self.grid.w >= self.wg.w
            && self.grid.h >= self.wg.h
            && self.grid.w % self.wg.w == 0
            && self.grid.h % self.wg.h == 0
    }

    /// Work units per workitem for an `out_w x out_h` output (paper
    /// NUM_WUS_X/Y): cyclic distribution, assumes grid divides output.
    pub fn wus_per_wi(&self, out_w: u32, out_h: u32) -> (u32, u32) {
        let x = (out_w / self.grid.w).max(1);
        let y = (out_h / self.grid.h).max(1);
        (x, y)
    }

    /// Distinct `wi_x` lanes covered by one 32-thread warp (row-major
    /// linearization, x fastest) and distinct `wi_y` rows.
    pub fn warp_lanes(&self, warp_size: u32) -> (u32, u32) {
        let distinct_x = self.wg.w.min(warp_size);
        let distinct_y = warp_size.div_ceil(self.wg.w).min(self.wg.h);
        (distinct_x, distinct_y)
    }
}

/// Enumerate power-of-two values in [lo, hi].
pub fn pow2s(lo: u32, hi: u32) -> Vec<u32> {
    let mut v = Vec::new();
    let mut x = lo.max(1).next_power_of_two();
    while x <= hi {
        v.push(x);
        x *= 2;
    }
    v
}

/// All workgroup geometries with power-of-two dims and total size
/// within [1, max_threads] (paper: <= 1024).
pub fn enumerate_wgs(max_threads: u32) -> Vec<WgGeom> {
    let mut out = Vec::new();
    for w in pow2s(1, max_threads) {
        for h in pow2s(1, max_threads / w) {
            out.push(WgGeom { w, h });
        }
    }
    out
}

/// All grid geometries (in workitems) with power-of-two dims, total size
/// >= min_total (paper: 512), covering at most (out_w, out_h) and
/// divisible by the workgroup.
pub fn enumerate_grids(
    wg: WgGeom,
    out_w: u32,
    out_h: u32,
    min_total: u64,
) -> Vec<GridGeom> {
    let mut out = Vec::new();
    for w in pow2s(wg.w, out_w) {
        for h in pow2s(wg.h, out_h) {
            let g = GridGeom { w, h };
            if g.size() >= min_total
                && w % wg.w == 0
                && h % wg.h == 0
                && out_w % w == 0
                && out_h % h == 0
            {
                out.push(g);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_enumeration() {
        assert_eq!(pow2s(1, 8), vec![1, 2, 4, 8]);
        assert_eq!(pow2s(3, 16), vec![4, 8, 16]);
        assert!(pow2s(32, 16).is_empty());
    }

    #[test]
    fn wg_enumeration_respects_cap() {
        let wgs = enumerate_wgs(1024);
        assert!(wgs.iter().all(|g| g.size() <= 1024));
        assert!(wgs.contains(&WgGeom { w: 32, h: 32 }));
        assert!(wgs.contains(&WgGeom { w: 1024, h: 1 }));
        // 11 choices for w (1..1024), sum over w of |pow2s(1,1024/w)| = 66
        assert_eq!(wgs.len(), 66);
    }

    #[test]
    fn grid_enumeration_covers_constraints() {
        let wg = WgGeom { w: 32, h: 8 };
        let grids = enumerate_grids(wg, 2048, 2048, 512);
        assert!(!grids.is_empty());
        for g in &grids {
            assert!(g.size() >= 512);
            assert_eq!(g.w % wg.w, 0);
            assert_eq!(g.h % wg.h, 0);
            assert_eq!(2048 % g.w, 0);
            assert_eq!(2048 % g.h, 0);
        }
    }

    #[test]
    fn launch_derived_quantities() {
        let l = Launch::new(WgGeom { w: 32, h: 8 }, GridGeom { w: 256, h: 64 });
        assert!(l.valid());
        assert_eq!(l.groups_x(), 8);
        assert_eq!(l.groups_y(), 8);
        assert_eq!(l.total_groups(), 64);
        assert_eq!(l.wus_per_wi(2048, 2048), (8, 32));
    }

    #[test]
    fn warp_lane_decomposition() {
        let mk = |w, h| Launch::new(WgGeom { w, h }, GridGeom { w: 1024, h: 1024 });
        assert_eq!(mk(32, 8).warp_lanes(32), (32, 1));
        assert_eq!(mk(16, 16).warp_lanes(32), (16, 2));
        assert_eq!(mk(8, 8).warp_lanes(32), (8, 4));
        assert_eq!(mk(64, 4).warp_lanes(32), (32, 1));
        assert_eq!(mk(1, 64).warp_lanes(32), (1, 32));
        assert_eq!(mk(4, 2).warp_lanes(32), (4, 2)); // wg smaller than warp
    }

    #[test]
    fn invalid_launches_detected() {
        assert!(!Launch::new(WgGeom { w: 32, h: 8 }, GridGeom { w: 100, h: 64 })
            .valid());
        assert!(!Launch::new(WgGeom { w: 64, h: 1 }, GridGeom { w: 32, h: 32 })
            .valid());
    }
}
