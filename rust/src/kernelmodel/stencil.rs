//! Stencil patterns of target-array accesses (paper Fig. 5).
//!
//! Accesses in the template's inner loop body are centered on a *home
//! coordinate* with constant offsets (CO_t, CI_t); the paper uses three
//! common shapes. Mirrors `python/compile/config.py::stencil_offsets`.

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StencilPattern {
    Rectangular,
    Diamond,
    Star,
}

impl StencilPattern {
    pub const ALL: [StencilPattern; 3] =
        [StencilPattern::Rectangular, StencilPattern::Diamond, StencilPattern::Star];

    /// Tap offsets (row, col) relative to the home coordinate.
    pub fn offsets(&self, radius: u32) -> Vec<(i32, i32)> {
        let r = radius as i32;
        if r == 0 {
            return vec![(0, 0)];
        }
        let mut out = Vec::new();
        for dy in -r..=r {
            for dx in -r..=r {
                let keep = match self {
                    StencilPattern::Rectangular => true,
                    StencilPattern::Diamond => dy.abs() + dx.abs() <= r,
                    StencilPattern::Star => dy == 0 || dx == 0,
                };
                if keep {
                    out.push((dy, dx));
                }
            }
        }
        out
    }

    /// Number of taps (accesses to the target array per inner iteration,
    /// paper feature #4).
    pub fn taps(&self, radius: u32) -> u32 {
        let r = radius;
        match self {
            StencilPattern::Rectangular => (2 * r + 1) * (2 * r + 1),
            StencilPattern::Diamond => 2 * r * r + 2 * r + 1,
            StencilPattern::Star => {
                if r == 0 {
                    1
                } else {
                    4 * r + 1
                }
            }
        }
    }

    /// (min_row, max_row, min_col, max_col) offset bounds (features #5).
    pub fn offset_bounds(&self, radius: u32) -> (i32, i32, i32, i32) {
        let r = radius as i32;
        (-r, r, -r, r)
    }

    pub fn parse(s: &str) -> Option<StencilPattern> {
        match s {
            "rect" | "rectangular" => Some(StencilPattern::Rectangular),
            "diamond" => Some(StencilPattern::Diamond),
            "star" => Some(StencilPattern::Star),
            _ => None,
        }
    }
}

impl fmt::Display for StencilPattern {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            StencilPattern::Rectangular => "rect",
            StencilPattern::Diamond => "diamond",
            StencilPattern::Star => "star",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tap_counts_match_formulas() {
        for r in 0..=3 {
            for p in StencilPattern::ALL {
                assert_eq!(
                    p.offsets(r).len() as u32,
                    p.taps(r),
                    "pattern {p} radius {r}"
                );
            }
        }
    }

    #[test]
    fn radius_zero_is_single_home_tap() {
        for p in StencilPattern::ALL {
            assert_eq!(p.offsets(0), vec![(0, 0)]);
        }
    }

    #[test]
    fn star_subset_diamond_subset_rect() {
        use std::collections::HashSet;
        for r in 1..=3 {
            let rect: HashSet<_> =
                StencilPattern::Rectangular.offsets(r).into_iter().collect();
            let dia: HashSet<_> =
                StencilPattern::Diamond.offsets(r).into_iter().collect();
            let star: HashSet<_> =
                StencilPattern::Star.offsets(r).into_iter().collect();
            assert!(star.is_subset(&dia));
            assert!(dia.is_subset(&rect));
            assert!(star.contains(&(0, 0)));
        }
    }

    #[test]
    fn bounds_cover_all_offsets() {
        for r in 0..=3 {
            for p in StencilPattern::ALL {
                let (r0, r1, c0, c1) = p.offset_bounds(r);
                for (dy, dx) in p.offsets(r) {
                    assert!(r0 <= dy && dy <= r1);
                    assert!(c0 <= dx && dx <= c1);
                }
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for p in StencilPattern::ALL {
            assert_eq!(StencilPattern::parse(&p.to_string()), Some(p));
        }
        assert_eq!(StencilPattern::parse("hexagon"), None);
    }
}
