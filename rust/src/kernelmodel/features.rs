//! The 18 model features (paper §4.2).
//!
//! The order here is THE canonical feature order across the system: the
//! rust trainer, the CSV datasets, the tensor export, and the L1 Pallas
//! inference kernel all index features by these positions. NUM_FEATURES
//! must equal `python/compile/config.py::NUM_FEATURES`.
//!
//! Deviation from the paper's exact list (documented in DESIGN.md): the
//! paper spends 4 slots on min/max tap offsets per dimension and 1 on
//! workgroup size. Our stencils (like the paper's, Fig. 5) are symmetric,
//! so min/max carry the same information as the *span*; we fold them into
//! 2 span features and spend the freed slots on the workgroup geometry
//! (wg_w, wg_h) and the staged-region row count. Those are required for
//! the features to be sufficient statistics of the benefit: the
//! cooperative copy of an R-row region costs >= R transactions (paper §2
//! copies row segments), so two kernels with identical region *bytes* but
//! different region *shape* have different staging costs. Total stays 18.

use super::descriptor::KernelDescriptor;

pub const NUM_FEATURES: usize = 18;

/// Canonical feature names (also the dataset CSV header).
pub const FEATURE_NAMES: [&str; NUM_FEATURES] = [
    "reuse",          // 1. degree of data reuse of the staged region
    "lmem_bytes",     // 2. local memory used per workgroup
    "noncoal",        // 3. degree of non-coalescing (tx per warp access)
    "num_accesses",   // 4. accesses to the target array (taps)
    "off_row_span",   // 5a. tap offset span, row dim (max - min)
    "off_col_span",   // 5b. tap offset span, col dim
    "region_rows",    // 5c. staged-region rows (copy-cost shape)
    "comp_ilb",       // 6a. computation in inner loop body
    "comp_ep",        // 6b. computation in epilogue
    "coal_ilb",       // 7a. coalesced ctx accesses, inner loop body
    "uncoal_ilb",     // 7b. non-coalesced ctx accesses, inner loop body
    "coal_ep",        // 7c. coalesced ctx accesses, epilogue
    "uncoal_ep",      // 7d. non-coalesced ctx accesses, epilogue
    "regs",           // 8. registers per thread (unoptimized)
    "grid_size",      // 9a. total workitems
    "wg_w",           // 9b. workgroup width
    "wg_h",           // 9c. workgroup height
    "wus_per_wi",     // 10. work units per workitem
];

/// Extract the 18-feature vector from a kernel descriptor.
pub fn extract(d: &KernelDescriptor) -> [f64; NUM_FEATURES] {
    let (r0, r1, c0, c1) = d.offset_bounds;
    [
        d.reuse,
        d.region_bytes() as f64,
        d.tx_per_target_access,
        d.taps as f64,
        (r1 - r0) as f64,
        (c1 - c0) as f64,
        d.region_rows as f64,
        d.comp_ilb as f64,
        d.comp_ep as f64,
        d.coal_ilb as f64,
        d.uncoal_ilb as f64,
        d.coal_ep as f64,
        d.uncoal_ep as f64,
        d.base_regs as f64,
        d.launch.total_threads() as f64,
        d.launch.wg.w as f64,
        d.launch.wg.h as f64,
        d.wus_per_wi as f64,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::spec::DeviceSpec;
    use crate::kernelmodel::launch::{GridGeom, Launch, WgGeom};
    use crate::kernelmodel::template::Template;

    #[test]
    fn names_and_width_agree() {
        assert_eq!(FEATURE_NAMES.len(), NUM_FEATURES);
        let mut sorted: Vec<&str> = FEATURE_NAMES.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), NUM_FEATURES, "duplicate feature name");
    }

    #[test]
    fn extraction_positions() {
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: 16, h: 8 },
            GridGeom { w: 512, h: 256 },
        );
        let t = Template::base();
        let d = t.descriptor(&launch, &dev);
        let f = extract(&d);
        assert_eq!(f[1], d.region_bytes() as f64);
        assert_eq!(f[3], 9.0);
        assert_eq!(f[4], 2.0); // span of -1..1
        assert_eq!(f[6], d.region_rows as f64);
        assert_eq!(f[14], 512.0 * 256.0);
        assert_eq!(f[15], 16.0);
        assert_eq!(f[16], 8.0);
        assert_eq!(f[17], d.wus_per_wi as f64);
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn shape_disambiguates_equal_bytes() {
        // The motivating case for the region_rows feature: same bytes,
        // different copy cost.
        let dev = DeviceSpec::m2090();
        let launch = Launch::new(
            WgGeom { w: 32, h: 32 },
            GridGeom { w: 512, h: 512 },
        );
        use crate::kernelmodel::access::HomePattern;
        let row = Template {
            home: HomePattern::NoReuseRow,
            n: 1,
            m: 1,
            radius: 0,
            ..Template::base()
        };
        let swap = Template { home: HomePattern::NoReuseSwap, ..row.clone() };
        let dr = row.descriptor(&launch, &dev);
        let ds = swap.descriptor(&launch, &dev);
        assert_eq!(dr.region_bytes(), ds.region_bytes());
        let fr = extract(&dr);
        let fs = extract(&ds);
        assert_ne!(fr[6], fs[6], "region_rows must differ");
    }
}
