//! Kernel structure model: the synthetic template (Fig. 3/Table 1), home
//! access patterns (Fig. 4), stencils (Fig. 5), launch geometry, the
//! unified kernel descriptor and the 18 model features (§4.2).
pub mod access;
pub mod descriptor;
pub mod features;
pub mod launch;
pub mod stencil;
pub mod template;
