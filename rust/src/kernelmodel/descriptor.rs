//! The unified kernel descriptor: everything the simulator and the
//! feature extractor need to know about one kernel instance.
//!
//! Synthetic template instances (kernelmodel::template) and the eight
//! real-world workloads (crate::workloads) both lower to this type, which
//! is what makes train-on-synthetic / predict-on-real possible.

use super::launch::Launch;
use crate::gpu::spec::DeviceSpec;

#[derive(Clone, Debug, PartialEq)]
pub struct KernelDescriptor {
    pub name: String,
    /// Target-array accesses per inner-loop iteration (stencil taps).
    pub taps: u32,
    /// Inner-loop trip count N*M per work-unit round.
    pub inner_iters: u64,
    /// FMA-equivalent computation ops, inner loop body / epilogue.
    pub comp_ilb: u32,
    pub comp_ep: u32,
    /// Contextual (non-target) accesses: coalesced / non-coalesced,
    /// inner loop body / epilogue.
    pub coal_ilb: u32,
    pub coal_ep: u32,
    pub uncoal_ilb: u32,
    pub uncoal_ep: u32,
    /// Average DRAM transactions per warp for one target access in the
    /// unoptimized kernel (1 = coalesced or broadcast).
    pub tx_per_target_access: f64,
    /// Transactions per warp for one non-coalesced contextual access.
    pub uncoal_ctx_tx: f64,
    /// Staged-region geometry including the stencil apron.
    pub region_rows: u64,
    pub region_cols: u64,
    /// Paper feature #1 — average accesses per distinct staged element.
    pub reuse: f64,
    /// (min_row, max_row, min_col, max_col) tap offsets.
    pub offset_bounds: (i32, i32, i32, i32),
    /// Registers per thread, unoptimized kernel.
    pub base_regs: u32,
    /// Additional registers the optimization costs.
    pub opt_extra_regs: u32,
    pub launch: Launch,
    /// Work-unit rounds each workitem executes.
    pub wus_per_wi: u64,
    /// Bytes per target-array element (4 = f32).
    pub elem_bytes: u32,
}

impl KernelDescriptor {
    /// Local memory the optimization uses per workgroup (paper feature #2).
    pub fn region_bytes(&self) -> u64 {
        self.region_rows * self.region_cols * self.elem_bytes as u64
    }

    /// Can the staged region fit in the device's local memory at all?
    pub fn lmem_feasible(&self, dev: &DeviceSpec) -> bool {
        self.region_bytes() <= dev.shared_mem_per_sm as u64
    }

    /// DRAM transactions needed to cooperatively copy the staged region,
    /// fully coalesced (paper §2: row segments of one transaction width,
    /// cyclically distributed over warps).
    pub fn copy_transactions(&self, dev: &DeviceSpec) -> f64 {
        let seg = dev.transaction_bytes as u64 / self.elem_bytes as u64;
        // Each region row is copied as ceil(cols / seg) aligned segments.
        (self.region_rows * self.region_cols.div_ceil(seg)) as f64
    }

    /// Warps per workgroup.
    pub fn warps_per_wg(&self, dev: &DeviceSpec) -> u32 {
        dev.warps_for_threads(self.launch.wg.size())
    }

    /// Total contextual transactions per warp per work-unit round.
    pub fn ctx_tx_per_round(&self) -> f64 {
        let il = self.inner_iters as f64;
        (self.coal_ilb as f64 * il + self.coal_ep as f64)
            + (self.uncoal_ilb as f64 * il + self.uncoal_ep as f64)
                * self.uncoal_ctx_tx
    }

    /// Contextual memory instructions per warp per round.
    pub fn ctx_insts_per_round(&self) -> f64 {
        let il = self.inner_iters as f64;
        (self.coal_ilb + self.uncoal_ilb) as f64 * il
            + (self.coal_ep + self.uncoal_ep) as f64
    }

    /// Computation warp-instructions per round.
    pub fn comp_insts_per_round(&self) -> f64 {
        self.comp_ilb as f64 * self.inner_iters as f64 + self.comp_ep as f64
    }

    /// Target-array accesses per workitem per round.
    pub fn target_insts_per_round(&self) -> f64 {
        self.taps as f64 * self.inner_iters as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::launch::{GridGeom, WgGeom};

    pub fn dummy() -> KernelDescriptor {
        KernelDescriptor {
            name: "dummy".into(),
            taps: 9,
            inner_iters: 64,
            comp_ilb: 10,
            comp_ep: 5,
            coal_ilb: 1,
            coal_ep: 2,
            uncoal_ilb: 1,
            uncoal_ep: 0,
            tx_per_target_access: 4.0,
            uncoal_ctx_tx: 32.0,
            region_rows: 18,
            region_cols: 34,
            reuse: 20.0,
            offset_bounds: (-1, 1, -1, 1),
            base_regs: 30,
            opt_extra_regs: 4,
            launch: Launch::new(
                WgGeom { w: 16, h: 8 },
                GridGeom { w: 512, h: 512 },
            ),
            wus_per_wi: 16,
            elem_bytes: 4,
        }
    }

    #[test]
    fn region_bytes_and_feasibility() {
        let dev = DeviceSpec::m2090();
        let mut d = dummy();
        assert_eq!(d.region_bytes(), 18 * 34 * 4);
        assert!(d.lmem_feasible(&dev));
        d.region_rows = 1024;
        d.region_cols = 1024;
        assert!(!d.lmem_feasible(&dev)); // 4 MB >> 48 KB
    }

    #[test]
    fn copy_transactions_row_segments() {
        let dev = DeviceSpec::m2090();
        let d = dummy();
        // 34 cols of f32 -> ceil(34/32) = 2 segments per row, 18 rows.
        assert_eq!(d.copy_transactions(&dev), 36.0);
    }

    #[test]
    fn per_round_instruction_counts() {
        let d = dummy();
        assert_eq!(d.comp_insts_per_round(), 10.0 * 64.0 + 5.0);
        assert_eq!(d.target_insts_per_round(), 9.0 * 64.0);
        assert_eq!(d.ctx_insts_per_round(), 2.0 * 64.0 + 2.0);
        // coal: 1*64 + 2; uncoal: (1*64 + 0) * 32
        assert_eq!(d.ctx_tx_per_round(), 66.0 + 64.0 * 32.0);
    }

    #[test]
    fn warps_per_wg_rounds_up() {
        let dev = DeviceSpec::m2090();
        let mut d = dummy();
        assert_eq!(d.warps_per_wg(&dev), 4); // 128 threads
        d.launch.wg = WgGeom { w: 5, h: 7 }; // 35 threads
        assert_eq!(d.warps_per_wg(&dev), 2);
    }
}
