//! Compile-time parameter sampling for synthetic kernels (paper Table 2).
//!
//! The paper samples 100 tuples of all compile-time parameters except
//! HOME_ACCESS_PATTERN, with skewed value distributions (the reported
//! averages sit well off the range midpoints). We reproduce each range
//! and mean with a power-law transform of a uniform draw.

use crate::kernelmodel::stencil::StencilPattern;
use crate::util::prng::Rng;

/// Table 2 rows: range + target mean for each context parameter.
#[derive(Clone, Copy, Debug)]
pub struct ParamDist {
    pub lo: u32,
    pub hi: u32,
    pub mean: f64,
}

impl ParamDist {
    /// Draw an integer in [lo, hi] whose expectation is ~mean:
    /// x = lo + (hi - lo) * u^k with k = (hi - mean) / (mean - lo).
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        if self.lo == self.hi {
            return self.lo;
        }
        let lo = self.lo as f64;
        let hi = self.hi as f64;
        let mean = self.mean.clamp(lo + 1e-9, hi - 1e-9);
        let k = (hi - mean) / (mean - lo);
        let x = lo + (hi - lo) * rng.next_f64().powf(k);
        (x.round() as u32).clamp(self.lo, self.hi)
    }
}

/// Table 2 of the paper.
pub mod table2 {
    use super::ParamDist;
    pub const STENCIL_RADIUS: ParamDist = ParamDist { lo: 0, hi: 2, mean: 1.0 };
    pub const NUM_COMP_ILB: ParamDist = ParamDist { lo: 5, hi: 44, mean: 19.0 };
    pub const NUM_COMP_EP: ParamDist = ParamDist { lo: 1, hi: 48, mean: 23.0 };
    pub const NUM_COAL_ILB: ParamDist = ParamDist { lo: 0, hi: 13, mean: 3.0 };
    pub const NUM_COAL_EP: ParamDist = ParamDist { lo: 0, hi: 13, mean: 5.0 };
    pub const NUM_UNCOAL_ILB: ParamDist = ParamDist { lo: 0, hi: 4, mean: 0.8 };
    pub const NUM_UNCOAL_EP: ParamDist = ParamDist { lo: 0, hi: 4, mean: 0.8 };
}

/// One sampled compile-time tuple (everything in Table 2; the home access
/// pattern and N/M are enumerated separately per paper §5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ContextTuple {
    pub stencil: StencilPattern,
    pub radius: u32,
    pub comp_ilb: u32,
    pub comp_ep: u32,
    pub coal_ilb: u32,
    pub coal_ep: u32,
    pub uncoal_ilb: u32,
    pub uncoal_ep: u32,
}

pub fn sample_tuple(rng: &mut Rng) -> ContextTuple {
    ContextTuple {
        stencil: *rng.pick(&StencilPattern::ALL),
        radius: table2::STENCIL_RADIUS.sample(rng),
        comp_ilb: table2::NUM_COMP_ILB.sample(rng),
        comp_ep: table2::NUM_COMP_EP.sample(rng),
        coal_ilb: table2::NUM_COAL_ILB.sample(rng),
        coal_ep: table2::NUM_COAL_EP.sample(rng),
        uncoal_ilb: table2::NUM_UNCOAL_ILB.sample(rng),
        uncoal_ep: table2::NUM_UNCOAL_EP.sample(rng),
    }
}

pub fn sample_tuples(rng: &mut Rng, count: usize) -> Vec<ContextTuple> {
    (0..count).map(|_| sample_tuple(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical_mean(dist: &ParamDist, n: usize) -> f64 {
        let mut rng = Rng::new(0xABCD);
        (0..n).map(|_| dist.sample(&mut rng) as f64).sum::<f64>() / n as f64
    }

    #[test]
    fn samples_stay_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let t = sample_tuple(&mut rng);
            assert!(t.radius <= 2);
            assert!((5..=44).contains(&t.comp_ilb));
            assert!((1..=48).contains(&t.comp_ep));
            assert!(t.coal_ilb <= 13 && t.coal_ep <= 13);
            assert!(t.uncoal_ilb <= 4 && t.uncoal_ep <= 4);
        }
    }

    #[test]
    fn means_match_table2() {
        // Tolerate ~10% relative error from rounding + sampling.
        let cases = [
            (table2::NUM_COMP_ILB, 19.0),
            (table2::NUM_COMP_EP, 23.0),
            (table2::NUM_COAL_ILB, 3.0),
            (table2::NUM_COAL_EP, 5.0),
            (table2::NUM_UNCOAL_ILB, 0.8),
            (table2::NUM_UNCOAL_EP, 0.8),
        ];
        for (dist, want) in cases {
            let got = empirical_mean(&dist, 50_000);
            assert!(
                (got - want).abs() / want < 0.12,
                "mean {got} vs table {want} ({dist:?})"
            );
        }
    }

    #[test]
    fn all_stencils_appear() {
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            seen.insert(sample_tuple(&mut rng).stencil);
        }
        assert_eq!(seen.len(), 3);
    }

    #[test]
    fn degenerate_dist_is_constant() {
        let d = ParamDist { lo: 7, hi: 7, mean: 7.0 };
        let mut rng = Rng::new(4);
        assert_eq!(d.sample(&mut rng), 7);
    }
}
