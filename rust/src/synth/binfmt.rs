//! Binary columnar shard format (`shard-NNNNN.bin`): the paper-scale
//! dataset plane.
//!
//! CSV moves every record through number formatting and parsing; at the
//! ~6.5M-instance scale that is the bottleneck of shard generation and
//! of the two-pass sharded training replay, and the text encoding
//! bloats disk ~4x. This module is the compact alternative behind the
//! same [`super::sink::RecordSink`] contract:
//!
//! * fixed-width little-endian `f32` column planes, written in blocks
//!   of [`BLOCK_ROWS`] rows so a shard streams in bounded memory both
//!   ways (no full-shard column buffer);
//! * a versioned header carrying the device key, the dataset
//!   [`Schema`], the row count, and an FNV-1a checksum over every data
//!   byte — truncation and bit rot surface as the typed
//!   [`CorruptShard`] error, never a panic or silently-wrong floats;
//! * plain `std::io` buffered reads/writes, no new dependencies.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    [u8; 4]  = b"LMTB"
//! version  u16      = 1
//! schema   u8       (1 = v1, 2 = v2)
//! dev_len  u8
//! device   [u8; dev_len]  (UTF-8 device key)
//! columns  u16      (= schema.columns(); rejects a mislabeled header)
//! rows     u64      (patched on finish)
//! checksum u64      (FNV-1a 64 over all bytes after the header;
//!                    patched on finish)
//! blocks*  each: rows_in_block u32 (1..=BLOCK_ROWS), then one f32
//!          plane per column (column-major within the block)
//! ```
//!
//! The row layout is exactly the CSV column order
//! (`dataset::csv_header_for`): 18 features, speedup, and for v2 the
//! workgroup label with its `(0, 0)` unlabeled sentinel. Values are
//! quantized f64 -> f32 on write (features and labels in this dataset
//! are f32-exact; measured speedups lose ~1e-7 relative precision,
//! documented in DESIGN.md §2g). A zero-row shard is a header with
//! `rows = 0` and no blocks — the legitimate trailing shard of a
//! round-robin layout with fewer records than shards.

use std::fmt;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::sim::exec::{Schema, TuneRecord};

use super::sink::{self, RecordSink};

/// File magic of a binary shard; CSV shards start with `#` or a header
/// letter, so the first four bytes discriminate the two formats.
pub const MAGIC: [u8; 4] = *b"LMTB";

/// On-disk format version this build writes and reads.
pub const FORMAT_VERSION: u16 = 1;

/// Rows per block: the streaming granularity of writer and reader.
/// Peak transcoding memory is one block (`BLOCK_ROWS x columns` f64s
/// plus its f32 byte image) per open shard.
pub const BLOCK_ROWS: usize = 4096;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(hash: u64, bytes: &[u8]) -> u64 {
    let mut h = hash;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// On-disk shard encoding: line-oriented CSV with `# key=value` meta
/// lines, or the binary columnar layout of this module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardFormat {
    Csv,
    Bin,
}

impl ShardFormat {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardFormat::Csv => "csv",
            ShardFormat::Bin => "bin",
        }
    }

    /// File extension of shards in this format.
    pub fn ext(&self) -> &'static str {
        self.as_str()
    }
}

impl fmt::Display for ShardFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for ShardFormat {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "csv" => Ok(ShardFormat::Csv),
            "bin" => Ok(ShardFormat::Bin),
            other => Err(format!("unknown shard format {other:?} (csv|bin)")),
        }
    }
}

/// Typed error: a binary shard is structurally unsound — truncated
/// mid-block, a mangled header, a row count that disagrees with the
/// stream, or a checksum mismatch. Readers surface this instead of
/// panicking or returning silently-wrong data.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorruptShard {
    pub path: String,
    pub detail: String,
}

impl CorruptShard {
    fn new(path: &Path, detail: impl Into<String>) -> Self {
        CorruptShard { path: path.display().to_string(), detail: detail.into() }
    }
}

impl fmt::Display for CorruptShard {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "corrupt binary shard {}: {}", self.path, self.detail)
    }
}

impl std::error::Error for CorruptShard {}

/// Sniff a shard file's format from its first four bytes (magic bytes
/// = binary, anything else = CSV; `RowReader` then produces its own
/// errors for files that are neither). An empty file is an error.
pub fn detect_format(path: &Path) -> Result<ShardFormat> {
    let mut f = File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut head = [0u8; 4];
    let mut filled = 0usize;
    while filled < head.len() {
        match f.read(&mut head[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                return Err(e).with_context(|| format!("read {}", path.display()))
            }
        }
    }
    anyhow::ensure!(filled > 0, "{}: empty file", path.display());
    if filled == head.len() && head == MAGIC {
        Ok(ShardFormat::Bin)
    } else {
        Ok(ShardFormat::Csv)
    }
}

fn schema_code(schema: Schema) -> u8 {
    match schema {
        Schema::V1 => 1,
        Schema::V2 => 2,
    }
}

fn schema_from_code(code: u8) -> Option<Schema> {
    match code {
        1 => Some(Schema::V1),
        2 => Some(Schema::V2),
        _ => None,
    }
}

/// Incremental binary shard writer: header on creation (row count and
/// checksum as placeholders), rows staged into one block at a time,
/// both header fields patched in place on [`finish`](Self::finish).
pub struct BinShardWriter {
    w: BufWriter<File>,
    path: PathBuf,
    schema: Schema,
    columns: usize,
    /// Row-major staging area for the current block.
    block: Vec<f64>,
    rows: u64,
    hash: u64,
    /// Byte offset of the `rows` header field (checksum follows it).
    patch_at: u64,
    finished: bool,
}

impl BinShardWriter {
    pub fn create(path: &Path, device: &str, schema: Schema) -> Result<Self> {
        let dev = device.as_bytes();
        anyhow::ensure!(
            !dev.is_empty() && dev.len() <= u8::MAX as usize,
            "{}: device key '{device}' must be 1..=255 bytes",
            path.display()
        );
        let f = File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        let columns = schema.columns();
        w.write_all(&MAGIC)?;
        w.write_all(&FORMAT_VERSION.to_le_bytes())?;
        w.write_all(&[schema_code(schema), dev.len() as u8])?;
        w.write_all(dev)?;
        w.write_all(&(columns as u16).to_le_bytes())?;
        let patch_at = (4 + 2 + 2 + dev.len()) as u64 + 2;
        w.write_all(&0u64.to_le_bytes())?; // rows, patched on finish
        w.write_all(&0u64.to_le_bytes())?; // checksum, patched on finish
        Ok(BinShardWriter {
            w,
            path: path.to_path_buf(),
            schema,
            columns,
            block: Vec::with_capacity(BLOCK_ROWS * columns),
            rows: 0,
            hash: FNV_OFFSET,
            patch_at,
            finished: false,
        })
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    /// Rows written so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Stage one row (CSV column order). Width-checked like
    /// `RowWriter::write_row`; values are quantized to f32.
    pub fn write_row(&mut self, row: &[f64]) -> Result<()> {
        anyhow::ensure!(
            row.len() == self.columns,
            "{}: row width {} != schema {} width {}",
            self.path.display(),
            row.len(),
            self.schema,
            self.columns
        );
        self.block.extend_from_slice(row);
        self.rows += 1;
        if self.block.len() == BLOCK_ROWS * self.columns {
            self.flush_block()?;
        }
        Ok(())
    }

    /// Encode the staged rows as one block: u32 row count, then one f32
    /// plane per column.
    fn flush_block(&mut self) -> Result<()> {
        let rows = self.block.len() / self.columns;
        if rows == 0 {
            return Ok(());
        }
        let mut bytes = Vec::with_capacity(4 + rows * self.columns * 4);
        bytes.extend_from_slice(&(rows as u32).to_le_bytes());
        for c in 0..self.columns {
            for r in 0..rows {
                let v = self.block[r * self.columns + c] as f32;
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        }
        self.hash = fnv1a(self.hash, &bytes);
        self.w
            .write_all(&bytes)
            .with_context(|| format!("write {}", self.path.display()))?;
        self.block.clear();
        Ok(())
    }

    /// Flush the trailing block and patch the header's row count and
    /// checksum in place.
    pub fn finish(&mut self) -> Result<()> {
        if self.finished {
            return Ok(());
        }
        self.flush_block()?;
        self.w
            .flush()
            .with_context(|| format!("flush {}", self.path.display()))?;
        // The buffer is empty after flush, so seeking the inner file and
        // writing the two trailer fields directly is sound.
        let f = self.w.get_mut();
        f.seek(SeekFrom::Start(self.patch_at))
            .with_context(|| format!("seek {}", self.path.display()))?;
        f.write_all(&self.rows.to_le_bytes())?;
        f.write_all(&self.hash.to_le_bytes())?;
        self.finished = true;
        Ok(())
    }
}

/// Streaming binary shard reader: header validated on open, rows
/// decoded block by block; the declared row count and checksum are
/// verified when the stream ends, so a truncated or bit-rotted shard is
/// a [`CorruptShard`] error before its last row is trusted.
pub struct BinShardReader {
    r: BufReader<File>,
    path: PathBuf,
    device: String,
    schema: Schema,
    columns: usize,
    rows_declared: u64,
    checksum_declared: u64,
    hash: u64,
    rows_read: u64,
    /// Decoded rows of the current block, row-major.
    block: Vec<f64>,
    pos: usize,
    done: bool,
}

impl BinShardReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let corrupt = |detail: String| CorruptShard::new(path, detail);
        let mut read_exact = |buf: &mut [u8], what: &str| -> Result<()> {
            r.read_exact(buf).map_err(|e| {
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    anyhow::Error::new(CorruptShard::new(
                        path,
                        format!("truncated header ({what})"),
                    ))
                } else {
                    anyhow::Error::new(e).context(format!("read {}", path.display()))
                }
            })
        };
        let mut magic = [0u8; 4];
        read_exact(&mut magic, "magic")?;
        if magic != MAGIC {
            return Err(corrupt(format!("bad magic {magic:02x?}")).into());
        }
        let mut u16buf = [0u8; 2];
        read_exact(&mut u16buf, "version")?;
        let version = u16::from_le_bytes(u16buf);
        if version != FORMAT_VERSION {
            return Err(corrupt(format!(
                "format version {version} (this build reads {FORMAT_VERSION})"
            ))
            .into());
        }
        let mut pair = [0u8; 2];
        read_exact(&mut pair, "schema/device length")?;
        let schema = schema_from_code(pair[0])
            .ok_or_else(|| corrupt(format!("unknown schema code {}", pair[0])))?;
        let dev_len = pair[1] as usize;
        if dev_len == 0 {
            return Err(corrupt("empty device key".to_string()).into());
        }
        let mut dev = vec![0u8; dev_len];
        read_exact(&mut dev, "device key")?;
        let device = String::from_utf8(dev)
            .map_err(|_| corrupt("device key is not UTF-8".to_string()))?;
        read_exact(&mut u16buf, "column count")?;
        let columns = u16::from_le_bytes(u16buf) as usize;
        if columns != schema.columns() {
            return Err(corrupt(format!(
                "{columns} columns but schema {schema} has {}",
                schema.columns()
            ))
            .into());
        }
        let mut u64buf = [0u8; 8];
        read_exact(&mut u64buf, "row count")?;
        let rows_declared = u64::from_le_bytes(u64buf);
        read_exact(&mut u64buf, "checksum")?;
        let checksum_declared = u64::from_le_bytes(u64buf);
        Ok(BinShardReader {
            r,
            path: path.to_path_buf(),
            device,
            schema,
            columns,
            rows_declared,
            checksum_declared,
            hash: FNV_OFFSET,
            rows_read: 0,
            block: Vec::new(),
            pos: 0,
            done: false,
        })
    }

    /// The device key stamped into the header.
    pub fn device(&self) -> &str {
        &self.device
    }

    pub fn schema(&self) -> Schema {
        self.schema
    }

    pub fn columns(&self) -> usize {
        self.columns
    }

    /// Row count declared by the header (verified against the stream at
    /// EOF).
    pub fn declared_rows(&self) -> u64 {
        self.rows_declared
    }

    /// Checksum declared by the header (verified at EOF).
    pub fn declared_checksum(&self) -> u64 {
        self.checksum_declared
    }

    /// Next row in stream order (CSV column order, f32-quantized
    /// values), or `None` after the last row of a verified stream.
    pub fn next_row(&mut self) -> Result<Option<Vec<f64>>> {
        loop {
            if self.pos < self.block.len() {
                let row = self.block[self.pos..self.pos + self.columns].to_vec();
                self.pos += self.columns;
                self.rows_read += 1;
                return Ok(Some(row));
            }
            if self.done {
                return Ok(None);
            }
            if !self.read_block()? {
                self.done = true;
                self.verify_trailer()?;
                return Ok(None);
            }
        }
    }

    /// Decode the next block into `self.block`; `false` at clean EOF.
    fn read_block(&mut self) -> Result<bool> {
        let mut len = [0u8; 4];
        let mut filled = 0usize;
        while filled < len.len() {
            match self.r.read(&mut len[filled..]) {
                Ok(0) if filled == 0 => return Ok(false),
                Ok(0) => {
                    return Err(CorruptShard::new(
                        &self.path,
                        "truncated block header",
                    )
                    .into())
                }
                Ok(n) => filled += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(anyhow::Error::new(e)
                        .context(format!("read {}", self.path.display())))
                }
            }
        }
        let rows = u32::from_le_bytes(len) as usize;
        if rows == 0 || rows > BLOCK_ROWS {
            return Err(CorruptShard::new(
                &self.path,
                format!("block of {rows} rows (valid: 1..={BLOCK_ROWS})"),
            )
            .into());
        }
        let mut planes = vec![0u8; rows * self.columns * 4];
        self.r.read_exact(&mut planes).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                anyhow::Error::new(CorruptShard::new(
                    &self.path,
                    format!("truncated block ({rows} rows declared)"),
                ))
            } else {
                anyhow::Error::new(e).context(format!("read {}", self.path.display()))
            }
        })?;
        self.hash = fnv1a(self.hash, &len);
        self.hash = fnv1a(self.hash, &planes);
        self.block.clear();
        self.block.resize(rows * self.columns, 0.0);
        for c in 0..self.columns {
            let plane = &planes[c * rows * 4..(c + 1) * rows * 4];
            for (r, chunk) in plane.chunks_exact(4).enumerate() {
                let v = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                self.block[r * self.columns + c] = v as f64;
            }
        }
        self.pos = 0;
        Ok(true)
    }

    /// At EOF: the stream must contain exactly the declared row count
    /// and hash to the declared checksum.
    fn verify_trailer(&self) -> Result<()> {
        if self.rows_read != self.rows_declared {
            return Err(CorruptShard::new(
                &self.path,
                format!(
                    "header declares {} rows but the stream has {}",
                    self.rows_declared, self.rows_read
                ),
            )
            .into());
        }
        if self.hash != self.checksum_declared {
            return Err(CorruptShard::new(
                &self.path,
                format!(
                    "checksum mismatch (header {:#018x}, stream {:#018x})",
                    self.checksum_declared, self.hash
                ),
            )
            .into());
        }
        Ok(())
    }
}

/// Write records round-robin across `shards` binary files in `dir` —
/// the binary twin of `sink::ShardedCsvSink`, same stream-order
/// contract (record `k` lands in shard `k % shards`), same device and
/// schema stamping (in the header instead of `#` meta lines).
pub struct ShardedBinSink {
    writers: Vec<BinShardWriter>,
    device: String,
    schema: Schema,
    next: usize,
    written: u64,
}

impl ShardedBinSink {
    pub fn create(
        dir: &Path,
        shards: usize,
        device: &str,
        schema: Schema,
    ) -> Result<Self> {
        let shards = shards.max(1);
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let writers = (0..shards)
            .map(|i| {
                BinShardWriter::create(
                    &sink::shard_path_for(dir, i, ShardFormat::Bin),
                    device,
                    schema,
                )
            })
            .collect::<Result<Vec<_>>>()?;
        // Any other shard file in the directory — higher-numbered,
        // differently padded, or the other format — would corrupt the
        // round-robin enumeration of a later reader.
        sink::remove_stale_shards(dir, shards, ShardFormat::Bin)?;
        Ok(ShardedBinSink {
            writers,
            device: device.to_string(),
            schema,
            next: 0,
            written: 0,
        })
    }

    pub fn shards(&self) -> usize {
        self.writers.len()
    }

    pub fn written(&self) -> u64 {
        self.written
    }

    /// The device key stamped into every shard header.
    pub fn device(&self) -> &str {
        &self.device
    }

    /// The schema every shard is written under.
    pub fn schema(&self) -> Schema {
        self.schema
    }
}

impl RecordSink for ShardedBinSink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        self.writers[self.next].write_row(&rec.csv_row(self.schema))?;
        self.next = (self.next + 1) % self.writers.len();
        self.written += 1;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        for w in self.writers.iter_mut() {
            w.finish()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("lmtuner-binfmt-{name}-{}", std::process::id()))
    }

    fn row(i: u64, schema: Schema) -> Vec<f64> {
        let mut r = vec![0.0; schema.columns()];
        r[0] = i as f64;
        r[NUM_FEATURES] = 0.5 + (i % 4) as f64; // f32-exact speedup
        if schema == Schema::V2 {
            r[NUM_FEATURES + 1] = (1u32 << (i % 5)) as f64;
            r[NUM_FEATURES + 2] = (1u32 << (i % 3)) as f64;
        }
        r
    }

    fn write_shard(path: &Path, schema: Schema, n: u64) {
        let mut w = BinShardWriter::create(path, "m2090", schema).unwrap();
        for i in 0..n {
            w.write_row(&row(i, schema)).unwrap();
        }
        w.finish().unwrap();
    }

    #[test]
    fn roundtrip_both_schemas_and_block_boundaries() {
        for schema in [Schema::V1, Schema::V2] {
            // 0 rows, under one block, exactly one block, and spilling
            // into a second block.
            for n in [0u64, 7, BLOCK_ROWS as u64, BLOCK_ROWS as u64 + 3] {
                let p = tmp(&format!("rt-{schema}-{n}"));
                write_shard(&p, schema, n);
                let mut r = BinShardReader::open(&p).unwrap();
                assert_eq!(r.device(), "m2090");
                assert_eq!(r.schema(), schema);
                assert_eq!(r.declared_rows(), n);
                let mut i = 0u64;
                while let Some(got) = r.next_row().unwrap() {
                    assert_eq!(got, row(i, schema), "row {i} of {n} ({schema})");
                    i += 1;
                }
                assert_eq!(i, n);
                // after EOF, next_row stays None
                assert!(r.next_row().unwrap().is_none());
                std::fs::remove_file(&p).ok();
            }
        }
    }

    #[test]
    fn detect_format_discriminates() {
        let p = tmp("detect-bin");
        write_shard(&p, Schema::V1, 3);
        assert_eq!(detect_format(&p).unwrap(), ShardFormat::Bin);
        let c = tmp("detect-csv");
        std::fs::write(&c, "# device=m2090\na,b\n1,2\n").unwrap();
        assert_eq!(detect_format(&c).unwrap(), ShardFormat::Csv);
        let short = tmp("detect-short");
        std::fs::write(&short, "ab").unwrap();
        assert_eq!(detect_format(&short).unwrap(), ShardFormat::Csv);
        let empty = tmp("detect-empty");
        std::fs::write(&empty, "").unwrap();
        assert!(detect_format(&empty).is_err());
        for p in [p, c, short, empty] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn truncated_shard_is_a_typed_error() {
        let p = tmp("trunc");
        write_shard(&p, Schema::V2, 100);
        let body = std::fs::read(&p).unwrap();
        // cut mid-block
        std::fs::write(&p, &body[..body.len() - 37]).unwrap();
        let mut r = BinShardReader::open(&p).unwrap();
        let err = loop {
            match r.next_row() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("truncated shard read to EOF cleanly"),
                Err(e) => break e,
            }
        };
        assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_checksum_is_a_typed_error() {
        let p = tmp("cksum");
        write_shard(&p, Schema::V1, 50);
        let mut body = std::fs::read(&p).unwrap();
        // flip one bit in the last data byte (past the header)
        let last = body.len() - 1;
        body[last] ^= 0x40;
        std::fs::write(&p, &body).unwrap();
        let mut r = BinShardReader::open(&p).unwrap();
        let err = loop {
            match r.next_row() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("corrupt shard verified clean"),
                Err(e) => break e,
            }
        };
        assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
        assert!(format!("{err:#}").contains("checksum"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn header_row_count_mismatch_is_detected() {
        let p = tmp("rowcount");
        write_shard(&p, Schema::V1, 10);
        let mut body = std::fs::read(&p).unwrap();
        // header rows field sits after magic+version+schema+len+dev+cols
        let patch = 4 + 2 + 2 + "m2090".len() + 2;
        body[patch..patch + 8].copy_from_slice(&11u64.to_le_bytes());
        std::fs::write(&p, &body).unwrap();
        let mut r = BinShardReader::open(&p).unwrap();
        let err = loop {
            match r.next_row() {
                Ok(Some(_)) => continue,
                Ok(None) => panic!("row-count mismatch verified clean"),
                Err(e) => break e,
            }
        };
        assert!(format!("{err:#}").contains("11 rows"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_headers_are_typed_errors() {
        // wrong magic
        let p = tmp("hdr-magic");
        std::fs::write(&p, b"NOPE\x01\x00").unwrap();
        // detect_format routes this to CSV; opening as bin is still typed
        let err = BinShardReader::open(&p).unwrap_err();
        assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
        // truncated header
        let t = tmp("hdr-short");
        std::fs::write(&t, b"LMTB\x01").unwrap();
        let err = BinShardReader::open(&t).unwrap_err();
        assert!(format!("{err:#}").contains("truncated header"), "{err:#}");
        // unknown version
        let v = tmp("hdr-version");
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC);
        body.extend_from_slice(&9u16.to_le_bytes());
        body.extend_from_slice(&[1, 1, b'x', 19, 0]);
        body.extend_from_slice(&[0u8; 16]);
        std::fs::write(&v, &body).unwrap();
        let err = BinShardReader::open(&v).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        for p in [p, t, v] {
            std::fs::remove_file(&p).ok();
        }
    }

    #[test]
    fn column_count_must_match_schema() {
        let p = tmp("hdr-cols");
        write_shard(&p, Schema::V1, 1);
        let mut body = std::fs::read(&p).unwrap();
        let cols_at = 4 + 2 + 2 + "m2090".len();
        body[cols_at..cols_at + 2].copy_from_slice(&21u16.to_le_bytes());
        std::fs::write(&p, &body).unwrap();
        let err = BinShardReader::open(&p).unwrap_err();
        assert!(err.downcast_ref::<CorruptShard>().is_some(), "{err:#}");
        assert!(format!("{err:#}").contains("columns"), "{err:#}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn writer_rejects_wrong_width_rows() {
        let p = tmp("width");
        let mut w = BinShardWriter::create(&p, "m2090", Schema::V1).unwrap();
        assert!(w.write_row(&[1.0, 2.0]).is_err());
        assert!(w.write_row(&row(0, Schema::V1)).is_ok());
        w.finish().unwrap();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn finish_is_idempotent() {
        let p = tmp("idem");
        let mut w = BinShardWriter::create(&p, "m2090", Schema::V1).unwrap();
        for i in 0..5 {
            w.write_row(&row(i, Schema::V1)).unwrap();
        }
        w.finish().unwrap();
        w.finish().unwrap();
        let mut r = BinShardReader::open(&p).unwrap();
        let mut n = 0;
        while r.next_row().unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 5);
        std::fs::remove_file(&p).ok();
    }
}
