//! Record sinks: where the streaming dataset builder puts its rows.
//!
//! `dataset::build_streaming` produces `SpeedupRecord`s in a canonical
//! deterministic order and hands each one to a [`RecordSink`]. The sink
//! decides what "keeping" a record means, which is what makes
//! paper-scale (millions of instances) runs practical:
//!
//! * [`MemorySink`] — collect everything in a `Vec` (the old
//!   `dataset::build` behavior; fine at toy scale).
//! * [`ShardedCsvSink`] — append records round-robin across N CSV
//!   shards on disk; peak memory is one row. [`load_sharded`] restores
//!   the exact stream order, [`stream_sharded`] replays it row-by-row
//!   without materializing anything. Every shard is stamped with the
//!   simulated device it was measured on (`# device=<key>`); readers
//!   refuse to mix shards from different devices ([`DeviceMismatch`]).
//! * [`ReservoirSink`] — uniform reservoir sample of K records (with
//!   their global stream indices), used to draw the training split
//!   from a stream of unknown length.
//! * [`Tee`] — feed two sinks from one stream (e.g. shard to disk
//!   *and* reservoir-sample the train split in a single pass).
//!
//! [`DatasetSummary`] accumulates the report statistics (count,
//! beneficial fraction, geomean/max speedup) incrementally so nothing
//! needs the full record set.

use std::collections::HashSet;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::kernelmodel::features::NUM_FEATURES;
use crate::sim::exec::SpeedupRecord;
use crate::util::csv::{RowReader, RowWriter};
use crate::util::prng::Rng;

use super::dataset::csv_header;

/// Metadata key under which shard/dataset CSVs carry the simulated
/// device they were measured on (see `util::csv` `# key=value` lines).
pub const DEVICE_META_KEY: &str = "device";

/// Typed error: data measured on different simulated devices was mixed,
/// or a dataset's stamped device does not match the one requested.
/// Training a model on rows from two devices would silently blend two
/// different feature→label maps, so every reader enforces this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceMismatch {
    pub expected: String,
    pub found: String,
    /// Where the mismatch was detected (a path or pipeline stage).
    pub at: String,
}

impl fmt::Display for DeviceMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "device mismatch at {}: expected '{}', found '{}'",
            self.at, self.expected, self.found
        )
    }
}

impl std::error::Error for DeviceMismatch {}

/// Enforce that `found` names the `expected` device; the `Err` is the
/// typed [`DeviceMismatch`] (convertible into `anyhow::Error` with `?`).
pub fn ensure_same_device(
    expected: &str,
    found: &str,
    at: impl Into<String>,
) -> std::result::Result<(), DeviceMismatch> {
    if expected == found {
        Ok(())
    } else {
        Err(DeviceMismatch {
            expected: expected.to_string(),
            found: found.to_string(),
            at: at.into(),
        })
    }
}

/// What a sharded-dataset replay saw: the row count and the device the
/// shards were stamped with (`None` for legacy shards written before
/// device stamping).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStream {
    pub rows: u64,
    pub device: Option<String>,
}

/// Consumer of the streaming dataset build. `accept` is called once
/// per record in stream order; `finish` once after the last record.
/// Records arrive by reference so implementations clone only what they
/// keep — at paper scale most sinks keep almost nothing (the CSV sink
/// serializes without owning, the reservoir discards nearly all rows).
pub trait RecordSink {
    fn accept(&mut self, rec: &SpeedupRecord) -> Result<()>;
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Collect every record in memory (the classic behavior).
#[derive(Default)]
pub struct MemorySink {
    pub records: Vec<SpeedupRecord>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RecordSink for MemorySink {
    fn accept(&mut self, rec: &SpeedupRecord) -> Result<()> {
        self.records.push(rec.clone());
        Ok(())
    }
}

/// Path of shard `i` under `dir`.
pub fn shard_path(dir: &Path, i: usize) -> PathBuf {
    dir.join(format!("shard-{i:03}.csv"))
}

/// List the shard files under `dir` in index order.
pub fn shard_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    loop {
        let p = shard_path(dir, out.len());
        if !p.is_file() {
            break;
        }
        out.push(p);
    }
    anyhow::ensure!(
        !out.is_empty(),
        "{}: no shard-NNN.csv files",
        dir.display()
    );
    Ok(out)
}

/// Write records round-robin across `shards` CSV files in `dir`: the
/// record with global stream index `k` lands in shard `k % shards`.
/// That assignment is what lets readers reconstruct the exact stream
/// order by popping shards in rotation. Every shard is stamped with the
/// simulated device the records were measured on; readers refuse to
/// interleave shards stamped with different devices.
pub struct ShardedCsvSink {
    writers: Vec<RowWriter>,
    device: String,
    next: usize,
    written: u64,
}

impl ShardedCsvSink {
    pub fn create(dir: &Path, shards: usize, device: &str) -> Result<Self> {
        let shards = shards.max(1);
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let header = csv_header();
        let meta = [(DEVICE_META_KEY, device)];
        let writers = (0..shards)
            .map(|i| RowWriter::create_with_meta(&shard_path(dir, i), &header, &meta))
            .collect::<Result<Vec<_>>>()?;
        // Remove stale higher-numbered shards from a previous run with
        // a larger shard count — readers enumerate shard-NNN.csv
        // contiguously and would otherwise interleave old rows.
        let mut i = shards;
        loop {
            let stale = shard_path(dir, i);
            if !stale.is_file() {
                break;
            }
            std::fs::remove_file(&stale)
                .with_context(|| format!("remove stale {}", stale.display()))?;
            i += 1;
        }
        Ok(ShardedCsvSink {
            writers,
            device: device.to_string(),
            next: 0,
            written: 0,
        })
    }

    pub fn shards(&self) -> usize {
        self.writers.len()
    }

    pub fn written(&self) -> u64 {
        self.written
    }

    /// The device key stamped into every shard.
    pub fn device(&self) -> &str {
        &self.device
    }
}

impl RecordSink for ShardedCsvSink {
    fn accept(&mut self, rec: &SpeedupRecord) -> Result<()> {
        self.writers[self.next].write_row(&rec.csv_row())?;
        self.next = (self.next + 1) % self.writers.len();
        self.written += 1;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        for w in self.writers.iter_mut() {
            w.finish()?;
        }
        Ok(())
    }
}

/// Replay a sharded dataset's raw rows (`dataset::csv_header` layout:
/// features then speedup) in original stream order, one row at a time
/// (peak memory: one buffered line per shard). The callback gets the
/// global stream index of each row. Returns the row count and the
/// shards' stamped device. Errors on ragged shards (an interrupted
/// writer) instead of silently truncating, and on shards stamped with
/// different devices (the typed [`DeviceMismatch`]) instead of
/// interleaving two testbeds' measurements.
pub fn stream_sharded_rows(
    dir: &Path,
    mut f: impl FnMut(u64, Vec<f64>) -> Result<()>,
) -> Result<ShardStream> {
    let files = shard_files(dir)?;
    let mut readers = files
        .iter()
        .map(|p| {
            let r = RowReader::open(p)?;
            anyhow::ensure!(
                r.header().len() == NUM_FEATURES + 1,
                "{}: expected {} columns, got {}",
                p.display(),
                NUM_FEATURES + 1,
                r.header().len()
            );
            Ok(r)
        })
        .collect::<Result<Vec<_>>>()?;
    // All shards must agree on the device they were measured on. The
    // first shard sets the expectation; any deviation (including a mix
    // of stamped and unstamped files) is the typed error.
    let device = readers[0].meta().get(DEVICE_META_KEY).cloned();
    for (p, r) in files.iter().zip(&readers).skip(1) {
        let found = r.meta().get(DEVICE_META_KEY).cloned();
        if found != device {
            let fmt_dev = |d: &Option<String>| {
                d.clone().unwrap_or_else(|| "<unstamped>".to_string())
            };
            return Err(DeviceMismatch {
                expected: fmt_dev(&device),
                found: fmt_dev(&found),
                at: p.display().to_string(),
            }
            .into());
        }
    }
    let mut idx = 0u64;
    // Round-robin pop: shard k%n holds record k, so one rotation over
    // the readers yields records idx, idx+1, ... in stream order. The
    // first exhausted shard in rotation order ends the stream.
    'outer: loop {
        for r in readers.iter_mut() {
            match r.next_row()? {
                Some(row) => {
                    f(idx, row)?;
                    idx += 1;
                }
                None => break 'outer,
            }
        }
    }
    // In a coherent round-robin layout, once one shard is exhausted at
    // its rotation slot every shard is empty. Trailing rows mean a
    // writer died mid-stream and the files are not a consistent
    // prefix — reject rather than return truncated data.
    for (s, r) in readers.iter_mut().enumerate() {
        anyhow::ensure!(
            r.next_row()?.is_none(),
            "{}: shard {s} has trailing rows after record {idx} — \
             ragged shards from an interrupted write?",
            dir.display()
        );
    }
    Ok(ShardStream { rows: idx, device })
}

/// Replay a sharded dataset as `SpeedupRecord`s in original stream
/// order (see [`stream_sharded_rows`]). The callback gets the global
/// stream index of each record. Returns the row count and stamped
/// device.
pub fn stream_sharded(
    dir: &Path,
    mut f: impl FnMut(u64, SpeedupRecord) -> Result<()>,
) -> Result<ShardStream> {
    stream_sharded_rows(dir, |idx, row| {
        f(idx, SpeedupRecord::from_csv_row(format!("row{idx}"), &row)?)
    })
}

/// Load a sharded dataset back into memory in original stream order.
pub fn load_sharded(dir: &Path) -> Result<Vec<SpeedupRecord>> {
    Ok(load_sharded_tagged(dir)?.0)
}

/// Load a sharded dataset plus the device it was measured on.
pub fn load_sharded_tagged(
    dir: &Path,
) -> Result<(Vec<SpeedupRecord>, Option<String>)> {
    let mut out = Vec::new();
    let stream = stream_sharded(dir, |_, rec| {
        out.push(rec);
        Ok(())
    })?;
    Ok((out, stream.device))
}

/// Uniform reservoir sample (algorithm R) of `capacity` records from a
/// stream of unknown length, deterministic given the seed. Keeps each
/// kept record's global stream index so a later pass can exclude the
/// sampled rows (train/test separation).
pub struct ReservoirSink {
    capacity: usize,
    rng: Rng,
    records: Vec<SpeedupRecord>,
    indices: Vec<u64>,
    seen: u64,
}

impl ReservoirSink {
    pub fn new(capacity: usize, seed: u64) -> Self {
        ReservoirSink {
            capacity: capacity.max(1),
            rng: Rng::new(seed),
            records: Vec::new(),
            indices: Vec::new(),
            seen: 0,
        }
    }

    /// Records seen (not kept) so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn records(&self) -> &[SpeedupRecord] {
        &self.records
    }

    /// Global stream indices of the kept records.
    pub fn selected_indices(&self) -> HashSet<u64> {
        self.indices.iter().copied().collect()
    }

    /// Consume the sink, returning (records, their stream indices).
    pub fn into_sample(self) -> (Vec<SpeedupRecord>, Vec<u64>) {
        (self.records, self.indices)
    }
}

impl RecordSink for ReservoirSink {
    fn accept(&mut self, rec: &SpeedupRecord) -> Result<()> {
        let k = self.seen;
        self.seen += 1;
        if self.records.len() < self.capacity {
            self.records.push(rec.clone());
            self.indices.push(k);
        } else {
            let j = self.rng.below(k + 1);
            if (j as usize) < self.capacity {
                self.records[j as usize] = rec.clone();
                self.indices[j as usize] = k;
            }
        }
        Ok(())
    }
}

/// Feed one stream into two sinks.
pub struct Tee<'a, A: RecordSink, B: RecordSink>(pub &'a mut A, pub &'a mut B);

impl<A: RecordSink, B: RecordSink> RecordSink for Tee<'_, A, B> {
    fn accept(&mut self, rec: &SpeedupRecord) -> Result<()> {
        self.0.accept(rec)?;
        self.1.accept(rec)
    }

    fn finish(&mut self) -> Result<()> {
        self.0.finish()?;
        self.1.finish()
    }
}

/// Streaming dataset statistics: everything `dataset::summarize`
/// reports, accumulated record-by-record in O(1) memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct DatasetSummary {
    pub records: u64,
    pub beneficial: u64,
    log_speedup_sum: f64,
    pub max_speedup: f64,
}

impl DatasetSummary {
    pub fn observe(&mut self, rec: &SpeedupRecord) {
        self.records += 1;
        self.beneficial += rec.beneficial() as u64;
        self.log_speedup_sum += rec.speedup.ln();
        self.max_speedup = self.max_speedup.max(rec.speedup);
    }

    pub fn beneficial_fraction(&self) -> f64 {
        self.beneficial as f64 / (self.records.max(1)) as f64
    }

    pub fn geomean_speedup(&self) -> f64 {
        if self.records == 0 {
            return 0.0;
        }
        (self.log_speedup_sum / self.records as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64) -> SpeedupRecord {
        let mut features = [0.0; NUM_FEATURES];
        features[0] = i as f64;
        SpeedupRecord {
            name: format!("r{i}"),
            features,
            speedup: 0.5 + (i % 4) as f64,
            baseline_time: 1.0,
            optimized_time: 1.0,
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("lmtuner-sink-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn sharded_roundtrip_preserves_stream_order() {
        for shards in [1usize, 3, 4] {
            let dir = tmpdir(&format!("rt{shards}"));
            let mut sink = ShardedCsvSink::create(&dir, shards, "m2090").unwrap();
            // 10 records: not a multiple of 3, so shard lengths
            // differ by one (a valid round-robin layout).
            for i in 0..10 {
                sink.accept(&rec(i)).unwrap();
            }
            sink.finish().unwrap();
            assert_eq!(sink.written(), 10);
            let back = load_sharded(&dir).unwrap();
            assert_eq!(back.len(), 10);
            for (i, r) in back.iter().enumerate() {
                assert_eq!(r.features[0], i as f64, "shards={shards}");
                assert_eq!(r.speedup, rec(i as u64).speedup);
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn stream_sharded_reports_global_indices_and_device() {
        let dir = tmpdir("idx");
        let mut sink = ShardedCsvSink::create(&dir, 2, "gtx480").unwrap();
        assert_eq!(sink.device(), "gtx480");
        for i in 0..7 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        let mut seen = Vec::new();
        let stream = stream_sharded(&dir, |idx, r| {
            assert_eq!(r.features[0], idx as f64);
            seen.push(idx);
            Ok(())
        })
        .unwrap();
        assert_eq!(stream.rows, 7);
        assert_eq!(stream.device.as_deref(), Some("gtx480"));
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
        let (back, dev) = load_sharded_tagged(&dir).unwrap();
        assert_eq!(back.len(), 7);
        assert_eq!(dev.as_deref(), Some("gtx480"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_device_shards_are_a_typed_error() {
        // Two shards written by runs on different devices must never
        // interleave into one stream.
        let dir = tmpdir("mix");
        let mut sink = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Restamp shard 1 as if it came from a K20 run.
        let p = shard_path(&dir, 1);
        let body = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, body.replace("# device=m2090", "# device=k20")).unwrap();

        let err = load_sharded(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("device mismatch"), "{msg}");
        assert!(msg.contains("m2090") && msg.contains("k20"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unstamped_legacy_shards_still_load() {
        // Shards written before device stamping (no `# device=` line)
        // must load with device=None, but mixing stamped and unstamped
        // files is rejected.
        let dir = tmpdir("legacy");
        let mut sink = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        for i in 0..2 {
            let p = shard_path(&dir, i);
            let body = std::fs::read_to_string(&p).unwrap();
            std::fs::write(&p, body.replace("# device=m2090\n", "")).unwrap();
        }
        let stream = stream_sharded_rows(&dir, |_, _| Ok(())).unwrap();
        assert_eq!(stream.rows, 4);
        assert_eq!(stream.device, None);

        // restore the stamp on shard 0 only -> mixed -> typed error
        let p = shard_path(&dir, 1);
        let body = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, format!("# device=m2090\n{body}")).unwrap();
        let err = stream_sharded_rows(&dir, |_, _| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("device mismatch"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ensure_same_device_returns_the_typed_error() {
        assert!(ensure_same_device("m2090", "m2090", "x").is_ok());
        let err = ensure_same_device("m2090", "k20", "data/shards").unwrap_err();
        assert_eq!(
            err,
            DeviceMismatch {
                expected: "m2090".into(),
                found: "k20".into(),
                at: "data/shards".into(),
            }
        );
        // and it converts into anyhow with the message intact
        let any: anyhow::Error = err.into();
        assert!(format!("{any}").contains("device mismatch"));
    }

    #[test]
    fn ragged_shards_are_rejected_not_truncated() {
        let dir = tmpdir("ragged");
        let mut sink = ShardedCsvSink::create(&dir, 3, "m2090").unwrap();
        for i in 0..5 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Simulate an interrupted later writer: shard 0 gains an extra
        // row the other shards never matched.
        use std::io::Write;
        let mut fh = std::fs::OpenOptions::new()
            .append(true)
            .open(shard_path(&dir, 0))
            .unwrap();
        let row: Vec<String> =
            rec(9).csv_row().iter().map(|x| x.to_string()).collect();
        writeln!(fh, "{}", row.join(",")).unwrap();
        drop(fh);
        let err = load_sharded(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("ragged"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recreating_with_fewer_shards_removes_stale_files() {
        let dir = tmpdir("stale");
        let mut first = ShardedCsvSink::create(&dir, 4, "m2090").unwrap();
        for i in 0..10 {
            first.accept(&rec(i)).unwrap();
        }
        first.finish().unwrap();

        // Re-run into the same directory with fewer shards: the old
        // shard-002/003 files must not leak into the new stream.
        let mut second = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 100..106 {
            second.accept(&rec(i)).unwrap();
        }
        second.finish().unwrap();

        let back = load_sharded(&dir).unwrap();
        assert_eq!(back.len(), 6);
        for (i, r) in back.iter().enumerate() {
            assert_eq!(r.features[0], (100 + i) as f64);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_shards_is_an_error() {
        let dir = tmpdir("empty");
        assert!(load_sharded(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reservoir_keeps_capacity_and_is_deterministic() {
        let mut a = ReservoirSink::new(16, 99);
        let mut b = ReservoirSink::new(16, 99);
        for i in 0..1000 {
            a.accept(&rec(i)).unwrap();
            b.accept(&rec(i)).unwrap();
        }
        assert_eq!(a.seen(), 1000);
        assert_eq!(a.records().len(), 16);
        let (ra, ia) = a.into_sample();
        let (rb, ib) = b.into_sample();
        assert_eq!(ia, ib);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.features, y.features);
        }
        // indices actually identify the kept records
        for (r, &i) in rb.iter().zip(&ib) {
            assert_eq!(r.features[0], i as f64);
        }
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // Over many seeds, late and early records are kept about
        // equally often.
        let mut early = 0usize;
        let mut late = 0usize;
        for seed in 0..200 {
            let mut s = ReservoirSink::new(10, seed);
            for i in 0..100 {
                s.accept(&rec(i)).unwrap();
            }
            for &i in &s.indices {
                if i < 50 {
                    early += 1;
                } else {
                    late += 1;
                }
            }
        }
        let frac = early as f64 / (early + late) as f64;
        assert!((frac - 0.5).abs() < 0.1, "early fraction {frac}");
    }

    #[test]
    fn reservoir_short_stream_keeps_everything() {
        let mut s = ReservoirSink::new(100, 1);
        for i in 0..5 {
            s.accept(&rec(i)).unwrap();
        }
        assert_eq!(s.records().len(), 5);
        assert_eq!(s.selected_indices().len(), 5);
    }

    #[test]
    fn tee_feeds_both_sinks() {
        let mut m = MemorySink::new();
        let mut r = ReservoirSink::new(4, 7);
        let mut tee = Tee(&mut m, &mut r);
        for i in 0..20 {
            tee.accept(&rec(i)).unwrap();
        }
        tee.finish().unwrap();
        assert_eq!(m.records.len(), 20);
        assert_eq!(r.records().len(), 4);
        assert_eq!(r.seen(), 20);
    }

    #[test]
    fn summary_matches_batch_stats() {
        let recs: Vec<SpeedupRecord> = (0..50).map(rec).collect();
        let mut s = DatasetSummary::default();
        for r in &recs {
            s.observe(r);
        }
        assert_eq!(s.records, 50);
        let ben = recs.iter().filter(|r| r.beneficial()).count();
        assert_eq!(s.beneficial, ben as u64);
        let geo = crate::util::stats::geomean(
            &recs.iter().map(|r| r.speedup).collect::<Vec<_>>(),
        );
        assert!((s.geomean_speedup() - geo).abs() < 1e-12);
        assert_eq!(s.max_speedup, 3.5);
    }
}
