//! Record sinks: where the streaming dataset builder puts its rows.
//!
//! `dataset::build_streaming` produces `TuneRecord`s in a canonical
//! deterministic order and hands each one to a [`RecordSink`]. The sink
//! decides what "keeping" a record means, which is what makes
//! paper-scale (millions of instances) runs practical:
//!
//! * [`MemorySink`] — collect everything in a `Vec` (the old
//!   `dataset::build` behavior; fine at toy scale).
//! * [`ShardedCsvSink`] / [`super::binfmt::ShardedBinSink`] — append
//!   records round-robin across N shards on disk (line-oriented CSV or
//!   the binary columnar format of `super::binfmt`); peak memory is
//!   one row. [`ShardedSink`] is the format-parametric handle over
//!   both. [`load_sharded`] restores the exact stream order,
//!   [`stream_sharded`] replays it row-by-row without materializing
//!   anything; both sniff each shard's format from its leading bytes
//!   (`LMTB` magic = binary, anything else = CSV), so CSV dirs written
//!   by older builds load unchanged. Every shard is stamped with the
//!   simulated device it was measured on (`# device=<key>` meta line,
//!   or the binary header) and its schema; readers refuse to mix
//!   shards from different devices ([`DeviceMismatch`]), different
//!   schemas ([`SchemaMismatch`]), or different formats
//!   ([`FormatMismatch`]).
//! * [`ReservoirSink`] — uniform reservoir sample of K records (with
//!   their global stream indices), used to draw the training split
//!   from a stream of unknown length.
//! * [`Tee`] — feed two sinks from one stream (e.g. shard to disk
//!   *and* reservoir-sample the train split in a single pass).
//!
//! [`DatasetSummary`] accumulates the report statistics (count,
//! beneficial fraction, geomean/max speedup) incrementally so nothing
//! needs the full record set. [`inspect_shard`] reads one shard's
//! self-description (format, device, schema, row count, checksum) for
//! the `lmtuner shards` inspector.

use std::collections::{BTreeMap, HashSet};
use std::ffi::OsString;
use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::sim::exec::{Schema, SpeedupRecord, TuneRecord};
use crate::util::csv::{RowReader, RowWriter};
use crate::util::prng::Rng;

use super::binfmt::{self, BinShardReader, ShardFormat};
use super::dataset::csv_header_for;

/// Metadata key under which shard/dataset CSVs carry the simulated
/// device they were measured on (see `util::csv` `# key=value` lines).
pub const DEVICE_META_KEY: &str = "device";

/// Metadata key under which shard/dataset CSVs carry their schema
/// version. Absent means schema v1 (the single-label layout every file
/// written before schema versioning uses).
pub const SCHEMA_META_KEY: &str = "schema";

/// Resolve a CSV file's schema from its parsed `# key=value` metadata:
/// absent = v1 (legacy single-label files), otherwise the stamp must
/// parse as a known schema.
pub fn schema_from_meta(meta: &BTreeMap<String, String>) -> Result<Schema> {
    match meta.get(SCHEMA_META_KEY) {
        None => Ok(Schema::V1),
        Some(s) => s.parse::<Schema>().map_err(|e| anyhow::anyhow!(e)),
    }
}

/// Typed error: shards written under different dataset schemas were
/// mixed. A v1 shard's rows have no workgroup label while a v2 shard's
/// do, so interleaving them would silently corrupt the label plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchemaMismatch {
    pub expected: Schema,
    pub found: Schema,
    /// Where the mismatch was detected (a path or pipeline stage).
    pub at: String,
}

impl fmt::Display for SchemaMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "schema mismatch at {}: expected '{}', found '{}'",
            self.at, self.expected, self.found
        )
    }
}

impl std::error::Error for SchemaMismatch {}

/// Typed error: data measured on different simulated devices was mixed,
/// or a dataset's stamped device does not match the one requested.
/// Training a model on rows from two devices would silently blend two
/// different feature→label maps, so every reader enforces this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceMismatch {
    pub expected: String,
    pub found: String,
    /// Where the mismatch was detected (a path or pipeline stage).
    pub at: String,
}

impl fmt::Display for DeviceMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "device mismatch at {}: expected '{}', found '{}'",
            self.at, self.expected, self.found
        )
    }
}

impl std::error::Error for DeviceMismatch {}

/// Typed error: shards of different on-disk formats were mixed in one
/// directory. A coherent round-robin layout is written by one run in
/// one format; a CSV shard next to a binary shard means two runs'
/// leftovers, so interleaving them would corrupt stream order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FormatMismatch {
    pub expected: ShardFormat,
    pub found: ShardFormat,
    /// Where the mismatch was detected (a path).
    pub at: String,
}

impl fmt::Display for FormatMismatch {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "shard format mismatch at {}: expected '{}', found '{}'",
            self.at, self.expected, self.found
        )
    }
}

impl std::error::Error for FormatMismatch {}

/// Enforce that `found` names the `expected` device; the `Err` is the
/// typed [`DeviceMismatch`] (convertible into `anyhow::Error` with `?`).
pub fn ensure_same_device(
    expected: &str,
    found: &str,
    at: impl Into<String>,
) -> std::result::Result<(), DeviceMismatch> {
    if expected == found {
        Ok(())
    } else {
        Err(DeviceMismatch {
            expected: expected.to_string(),
            found: found.to_string(),
            at: at.into(),
        })
    }
}

/// What a sharded-dataset replay saw: the row count, the device the
/// shards were stamped with (`None` for legacy shards written before
/// device stamping), their schema (v1 for unstamped files), and their
/// on-disk format.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardStream {
    pub rows: u64,
    pub device: Option<String>,
    pub schema: Schema,
    pub format: ShardFormat,
}

/// Consumer of the streaming dataset build. `accept` is called once
/// per record in stream order; `finish` once after the last record.
/// Records arrive by reference so implementations clone only what they
/// keep — at paper scale most sinks keep almost nothing (the CSV sink
/// serializes without owning, the reservoir discards nearly all rows).
pub trait RecordSink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()>;
    fn finish(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Collect every record in memory (the classic behavior).
#[derive(Default)]
pub struct MemorySink {
    pub records: Vec<TuneRecord>,
}

impl MemorySink {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RecordSink for MemorySink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        self.records.push(rec.clone());
        Ok(())
    }
}

/// Path of CSV shard `i` under `dir` (back-compat alias for
/// [`shard_path_for`] with [`ShardFormat::Csv`]).
pub fn shard_path(dir: &Path, i: usize) -> PathBuf {
    shard_path_for(dir, i, ShardFormat::Csv)
}

/// Canonical path of shard `i` under `dir` in the given format. The
/// index is zero-padded to five digits so up to 100k shards list in
/// order even lexically; [`shard_files`] nevertheless sorts the parsed
/// indices numerically, so differently padded legacy names (`shard-000`)
/// keep their stream position too.
pub fn shard_path_for(dir: &Path, i: usize, format: ShardFormat) -> PathBuf {
    dir.join(format!("shard-{i:05}.{}", format.ext()))
}

/// Parse a shard file name (`shard-<digits>.<csv|bin>`, any pad width)
/// into its stream index and format; `None` for anything else.
pub fn parse_shard_name(name: &str) -> Option<(u64, ShardFormat)> {
    let rest = name.strip_prefix("shard-")?;
    let (digits, ext) = rest.split_once('.')?;
    if digits.is_empty()
        || digits.len() > 10
        || !digits.bytes().all(|b| b.is_ascii_digit())
    {
        return None;
    }
    let format = match ext {
        "csv" => ShardFormat::Csv,
        "bin" => ShardFormat::Bin,
        _ => return None,
    };
    Some((digits.parse().ok()?, format))
}

/// Enumerate the shard files under `dir` with their parsed indices,
/// sorted numerically. The indices must form a contiguous `0..n` run
/// with no duplicates — a gap or a doubled index (e.g. `shard-003.csv`
/// next to `shard-00003.bin` from an earlier run) cannot reconstruct
/// stream order, so it is an error rather than a silent misorder.
pub fn shard_listing(dir: &Path) -> Result<Vec<(u64, ShardFormat, PathBuf)>> {
    let mut entries: Vec<(u64, ShardFormat, PathBuf)> = Vec::new();
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("read {}", dir.display()))?;
    for entry in rd {
        let entry = entry.with_context(|| format!("read {}", dir.display()))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some((idx, format)) = parse_shard_name(name) {
            entries.push((idx, format, entry.path()));
        }
    }
    anyhow::ensure!(
        !entries.is_empty(),
        "{}: no shard-NNNNN.csv/.bin files",
        dir.display()
    );
    entries.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.2.cmp(&b.2)));
    for (want, e) in entries.iter().enumerate() {
        if e.0 == want as u64 {
            continue;
        }
        if want > 0 && entries[want - 1].0 == e.0 {
            anyhow::bail!(
                "{}: shard index {} appears more than once ({} and {}) — \
                 stale files from an earlier run?",
                dir.display(),
                e.0,
                entries[want - 1].2.display(),
                e.2.display()
            );
        }
        anyhow::bail!(
            "{}: shard indices are not contiguous (expected shard {want}, \
             found {})",
            dir.display(),
            e.2.display()
        );
    }
    Ok(entries)
}

/// List the shard files under `dir` in stream (numeric index) order.
pub fn shard_files(dir: &Path) -> Result<Vec<PathBuf>> {
    Ok(shard_listing(dir)?.into_iter().map(|(_, _, p)| p).collect())
}

/// Remove every shard file in `dir` that is not one of the `keep`
/// canonical paths of the given format. Sharded sinks call this after
/// creating their own files so leftovers from a previous run — a
/// larger shard count, a different pad width, or the other format —
/// never interleave into a later reader's stream.
pub fn remove_stale_shards(
    dir: &Path,
    keep: usize,
    format: ShardFormat,
) -> Result<()> {
    let keep_names: HashSet<OsString> = (0..keep)
        .filter_map(|i| {
            shard_path_for(dir, i, format)
                .file_name()
                .map(|n| n.to_os_string())
        })
        .collect();
    let rd = std::fs::read_dir(dir)
        .with_context(|| format!("read {}", dir.display()))?;
    for entry in rd {
        let entry = entry.with_context(|| format!("read {}", dir.display()))?;
        let name = entry.file_name();
        let is_shard =
            name.to_str().map(|n| parse_shard_name(n).is_some()).unwrap_or(false);
        if is_shard && !keep_names.contains(&name) {
            let p = entry.path();
            std::fs::remove_file(&p)
                .with_context(|| format!("remove stale {}", p.display()))?;
        }
    }
    Ok(())
}

/// Write records round-robin across `shards` CSV files in `dir`: the
/// record with global stream index `k` lands in shard `k % shards`.
/// That assignment is what lets readers reconstruct the exact stream
/// order by popping shards in rotation. Every shard is stamped with the
/// simulated device the records were measured on; readers refuse to
/// interleave shards stamped with different devices.
pub struct ShardedCsvSink {
    writers: Vec<RowWriter>,
    device: String,
    schema: Schema,
    next: usize,
    written: u64,
}

impl ShardedCsvSink {
    /// Create a v1 (single-label) sharded sink — byte-identical output
    /// to the pre-schema-versioning writer.
    pub fn create(dir: &Path, shards: usize, device: &str) -> Result<Self> {
        Self::create_schema(dir, shards, device, Schema::V1)
    }

    /// Create a sharded sink writing rows under `schema`. v2 shards
    /// carry a `# schema=v2` metadata line next to the device stamp;
    /// v1 shards are written exactly as before (no schema line).
    pub fn create_schema(
        dir: &Path,
        shards: usize,
        device: &str,
        schema: Schema,
    ) -> Result<Self> {
        let shards = shards.max(1);
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create {}", dir.display()))?;
        let header = csv_header_for(schema);
        let mut meta = vec![(DEVICE_META_KEY, device)];
        if schema == Schema::V2 {
            meta.push((SCHEMA_META_KEY, schema.as_str()));
        }
        let writers = (0..shards)
            .map(|i| RowWriter::create_with_meta(&shard_path(dir, i), &header, &meta))
            .collect::<Result<Vec<_>>>()?;
        // Remove any other shard file left by a previous run — a larger
        // shard count, an old pad width, or the binary format — since
        // readers enumerate the directory and would otherwise reject or
        // interleave the stale files.
        remove_stale_shards(dir, shards, ShardFormat::Csv)?;
        Ok(ShardedCsvSink {
            writers,
            device: device.to_string(),
            schema,
            next: 0,
            written: 0,
        })
    }

    pub fn shards(&self) -> usize {
        self.writers.len()
    }

    pub fn written(&self) -> u64 {
        self.written
    }

    /// The device key stamped into every shard.
    pub fn device(&self) -> &str {
        &self.device
    }

    /// The schema every shard is written under.
    pub fn schema(&self) -> Schema {
        self.schema
    }
}

impl RecordSink for ShardedCsvSink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        self.writers[self.next].write_row(&rec.csv_row(self.schema))?;
        self.next = (self.next + 1) % self.writers.len();
        self.written += 1;
        Ok(())
    }

    fn finish(&mut self) -> Result<()> {
        for w in self.writers.iter_mut() {
            w.finish()?;
        }
        Ok(())
    }
}

/// Format-parametric sharded sink: the one handle `train`/`generate`
/// thread through when the shard format is a runtime flag. Same
/// round-robin stream-order contract in both arms.
pub enum ShardedSink {
    Csv(ShardedCsvSink),
    Bin(binfmt::ShardedBinSink),
}

impl ShardedSink {
    pub fn create(
        dir: &Path,
        shards: usize,
        device: &str,
        schema: Schema,
        format: ShardFormat,
    ) -> Result<Self> {
        Ok(match format {
            ShardFormat::Csv => ShardedSink::Csv(ShardedCsvSink::create_schema(
                dir, shards, device, schema,
            )?),
            ShardFormat::Bin => ShardedSink::Bin(binfmt::ShardedBinSink::create(
                dir, shards, device, schema,
            )?),
        })
    }

    pub fn format(&self) -> ShardFormat {
        match self {
            ShardedSink::Csv(_) => ShardFormat::Csv,
            ShardedSink::Bin(_) => ShardFormat::Bin,
        }
    }

    pub fn shards(&self) -> usize {
        match self {
            ShardedSink::Csv(s) => s.shards(),
            ShardedSink::Bin(s) => s.shards(),
        }
    }

    pub fn written(&self) -> u64 {
        match self {
            ShardedSink::Csv(s) => s.written(),
            ShardedSink::Bin(s) => s.written(),
        }
    }

    pub fn device(&self) -> &str {
        match self {
            ShardedSink::Csv(s) => s.device(),
            ShardedSink::Bin(s) => s.device(),
        }
    }

    pub fn schema(&self) -> Schema {
        match self {
            ShardedSink::Csv(s) => s.schema(),
            ShardedSink::Bin(s) => s.schema(),
        }
    }
}

impl RecordSink for ShardedSink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        match self {
            ShardedSink::Csv(s) => s.accept(rec),
            ShardedSink::Bin(s) => s.accept(rec),
        }
    }

    fn finish(&mut self) -> Result<()> {
        match self {
            ShardedSink::Csv(s) => s.finish(),
            ShardedSink::Bin(s) => s.finish(),
        }
    }
}

/// One shard's self-description, as read (and for binary shards,
/// verified) from the file itself — what `lmtuner shards` prints.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardInfo {
    pub path: PathBuf,
    pub format: ShardFormat,
    /// `None` for legacy CSV shards written before device stamping.
    pub device: Option<String>,
    pub schema: Schema,
    pub rows: u64,
    /// Binary shards carry a verified FNV-1a checksum; CSV shards none.
    pub checksum: Option<u64>,
}

/// Read one shard end to end and report its self-description. For a
/// binary shard this verifies the declared row count and checksum
/// against the stream (a corrupt file is the typed
/// [`binfmt::CorruptShard`] error); for CSV it counts and parses every
/// row.
pub fn inspect_shard(path: &Path) -> Result<ShardInfo> {
    match binfmt::detect_format(path)? {
        ShardFormat::Csv => {
            let mut r = RowReader::open(path)?;
            let schema = schema_from_meta(r.meta())
                .with_context(|| path.display().to_string())?;
            anyhow::ensure!(
                r.header().len() == schema.columns(),
                "{}: expected {} columns for schema {schema}, got {}",
                path.display(),
                schema.columns(),
                r.header().len()
            );
            let device = r.meta().get(DEVICE_META_KEY).cloned();
            let mut rows = 0u64;
            while r.next_row()?.is_some() {
                rows += 1;
            }
            Ok(ShardInfo {
                path: path.to_path_buf(),
                format: ShardFormat::Csv,
                device,
                schema,
                rows,
                checksum: None,
            })
        }
        ShardFormat::Bin => {
            let mut r = BinShardReader::open(path)?;
            let device = Some(r.device().to_string());
            let schema = r.schema();
            let checksum = r.declared_checksum();
            // Reading to EOF verifies the declared row count and
            // checksum against the stream.
            let mut rows = 0u64;
            while r.next_row()?.is_some() {
                rows += 1;
            }
            Ok(ShardInfo {
                path: path.to_path_buf(),
                format: ShardFormat::Bin,
                device,
                schema,
                rows,
                checksum: Some(checksum),
            })
        }
    }
}

/// Replay a sharded dataset's raw rows (`dataset::csv_header_for`
/// layout: features, speedup, then for v2 the workgroup label) in
/// original stream order, one row at a time (peak memory: one buffered
/// line per shard). The callback gets the global stream index of each
/// row plus the shards' schema. Returns the row count, the shards'
/// stamped device, and their schema. Errors on ragged shards (an
/// interrupted writer) instead of silently truncating, on shards
/// stamped with different devices (the typed [`DeviceMismatch`])
/// instead of interleaving two testbeds' measurements, and on shards
/// written under different schemas (the typed [`SchemaMismatch`])
/// instead of corrupting the label plane.
pub fn stream_sharded_rows(
    dir: &Path,
    mut f: impl FnMut(u64, Schema, Vec<f64>) -> Result<()>,
) -> Result<ShardStream> {
    let files = shard_files(dir)?;
    // Each shard's format is sniffed from its leading bytes (the
    // `LMTB` magic = binary, anything else = CSV), so the extension
    // never decides how bytes are parsed. Shard 0 sets the format,
    // schema (absent CSV stamp = v1), and device expectations; every
    // other shard must agree — the typed [`FormatMismatch`],
    // [`SchemaMismatch`], and [`DeviceMismatch`] errors instead of an
    // interleaved mixture. Every CSV header must also have the
    // schema's column count so a v2 file with a stripped stamp is
    // rejected instead of misparsed (binary headers carry the check
    // internally).
    enum ShardReader {
        Csv(RowReader),
        Bin(BinShardReader),
    }
    impl ShardReader {
        fn next_row(&mut self) -> Result<Option<Vec<f64>>> {
            match self {
                ShardReader::Csv(r) => r.next_row(),
                ShardReader::Bin(r) => r.next_row(),
            }
        }
    }
    let mut readers: Vec<ShardReader> = Vec::with_capacity(files.len());
    let mut format = ShardFormat::Csv;
    let mut schema = Schema::V1;
    let mut device: Option<String> = None;
    for (i, p) in files.iter().enumerate() {
        let found_format = binfmt::detect_format(p)?;
        if i == 0 {
            format = found_format;
        } else if found_format != format {
            return Err(FormatMismatch {
                expected: format,
                found: found_format,
                at: p.display().to_string(),
            }
            .into());
        }
        let (reader, found_schema, found_device) = match found_format {
            ShardFormat::Csv => {
                let r = RowReader::open(p)?;
                let s = schema_from_meta(r.meta())
                    .with_context(|| p.display().to_string())?;
                anyhow::ensure!(
                    r.header().len() == s.columns(),
                    "{}: expected {} columns for schema {s}, got {}",
                    p.display(),
                    s.columns(),
                    r.header().len()
                );
                let d = r.meta().get(DEVICE_META_KEY).cloned();
                (ShardReader::Csv(r), s, d)
            }
            ShardFormat::Bin => {
                let r = BinShardReader::open(p)?;
                let s = r.schema();
                let d = Some(r.device().to_string());
                (ShardReader::Bin(r), s, d)
            }
        };
        if i == 0 {
            schema = found_schema;
        } else if found_schema != schema {
            return Err(SchemaMismatch {
                expected: schema,
                found: found_schema,
                at: p.display().to_string(),
            }
            .into());
        }
        if i == 0 {
            device = found_device;
        } else if found_device != device {
            let fmt_dev = |d: &Option<String>| {
                d.clone().unwrap_or_else(|| "<unstamped>".to_string())
            };
            return Err(DeviceMismatch {
                expected: fmt_dev(&device),
                found: fmt_dev(&found_device),
                at: p.display().to_string(),
            }
            .into());
        }
        readers.push(reader);
    }
    let mut idx = 0u64;
    // Round-robin pop: shard k%n holds record k, so one rotation over
    // the readers yields records idx, idx+1, ... in stream order. The
    // first exhausted shard in rotation order ends the stream.
    'outer: loop {
        for r in readers.iter_mut() {
            match r.next_row()? {
                Some(row) => {
                    f(idx, schema, row)?;
                    idx += 1;
                }
                None => break 'outer,
            }
        }
    }
    // In a coherent round-robin layout, once one shard is exhausted at
    // its rotation slot every shard is empty. Trailing rows mean a
    // writer died mid-stream and the files are not a consistent
    // prefix — reject rather than return truncated data.
    for (s, r) in readers.iter_mut().enumerate() {
        anyhow::ensure!(
            r.next_row()?.is_none(),
            "{}: shard {s} has trailing rows after record {idx} — \
             ragged shards from an interrupted write?",
            dir.display()
        );
    }
    Ok(ShardStream { rows: idx, device, schema, format })
}

/// Replay a sharded dataset as `TuneRecord`s in original stream order
/// (see [`stream_sharded_rows`]). The callback gets the global stream
/// index of each record. Returns the row count, stamped device, and
/// schema.
pub fn stream_sharded(
    dir: &Path,
    mut f: impl FnMut(u64, TuneRecord) -> Result<()>,
) -> Result<ShardStream> {
    stream_sharded_rows(dir, |idx, schema, row| {
        f(idx, TuneRecord::from_csv_row(schema, format!("row{idx}"), &row)?)
    })
}

/// Load a sharded dataset back into memory in original stream order.
pub fn load_sharded(dir: &Path) -> Result<Vec<TuneRecord>> {
    Ok(load_sharded_tagged(dir)?.0)
}

/// Load a sharded dataset plus its stream stamp (row count, device,
/// schema).
pub fn load_sharded_tagged(dir: &Path) -> Result<(Vec<TuneRecord>, ShardStream)> {
    let mut out = Vec::new();
    let stream = stream_sharded(dir, |_, rec| {
        out.push(rec);
        Ok(())
    })?;
    Ok((out, stream))
}

/// Uniform reservoir sample (algorithm R) of `capacity` records from a
/// stream of unknown length, deterministic given the seed. Keeps each
/// kept record's global stream index so a later pass can exclude the
/// sampled rows (train/test separation).
pub struct ReservoirSink {
    capacity: usize,
    rng: Rng,
    records: Vec<TuneRecord>,
    indices: Vec<u64>,
    seen: u64,
}

impl ReservoirSink {
    pub fn new(capacity: usize, seed: u64) -> Self {
        ReservoirSink {
            capacity: capacity.max(1),
            rng: Rng::new(seed),
            records: Vec::new(),
            indices: Vec::new(),
            seen: 0,
        }
    }

    /// Records seen (not kept) so far.
    pub fn seen(&self) -> u64 {
        self.seen
    }

    pub fn records(&self) -> &[TuneRecord] {
        &self.records
    }

    /// Global stream indices of the kept records.
    pub fn selected_indices(&self) -> HashSet<u64> {
        self.indices.iter().copied().collect()
    }

    /// Consume the sink, returning (records, their stream indices).
    pub fn into_sample(self) -> (Vec<TuneRecord>, Vec<u64>) {
        (self.records, self.indices)
    }
}

impl RecordSink for ReservoirSink {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        let k = self.seen;
        self.seen += 1;
        if self.records.len() < self.capacity {
            self.records.push(rec.clone());
            self.indices.push(k);
        } else {
            let j = self.rng.below(k + 1);
            if (j as usize) < self.capacity {
                self.records[j as usize] = rec.clone();
                self.indices[j as usize] = k;
            }
        }
        Ok(())
    }
}

/// Feed one stream into two sinks.
pub struct Tee<'a, A: RecordSink, B: RecordSink>(pub &'a mut A, pub &'a mut B);

impl<A: RecordSink, B: RecordSink> RecordSink for Tee<'_, A, B> {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        self.0.accept(rec)?;
        self.1.accept(rec)
    }

    fn finish(&mut self) -> Result<()> {
        self.0.finish()?;
        self.1.finish()
    }
}

/// Streaming dataset statistics: everything `dataset::summarize`
/// reports, accumulated record-by-record in O(1) memory.
#[derive(Clone, Copy, Debug, Default)]
pub struct DatasetSummary {
    pub records: u64,
    pub beneficial: u64,
    log_speedup_sum: f64,
    pub max_speedup: f64,
}

impl DatasetSummary {
    pub fn observe(&mut self, rec: &SpeedupRecord) {
        self.records += 1;
        self.beneficial += rec.beneficial() as u64;
        self.log_speedup_sum += rec.speedup.ln();
        self.max_speedup = self.max_speedup.max(rec.speedup);
    }

    pub fn beneficial_fraction(&self) -> f64 {
        self.beneficial as f64 / (self.records.max(1)) as f64
    }

    pub fn geomean_speedup(&self) -> f64 {
        if self.records == 0 {
            return 0.0;
        }
        (self.log_speedup_sum / self.records as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;

    fn rec(i: u64) -> TuneRecord {
        let mut features = [0.0; NUM_FEATURES];
        features[0] = i as f64;
        TuneRecord {
            base: SpeedupRecord {
                name: format!("r{i}"),
                features,
                speedup: 0.5 + (i % 4) as f64,
                baseline_time: 1.0,
                optimized_time: 1.0,
            },
            best_wg: Some((1 << (i % 5), 1 << (i % 3))),
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("lmtuner-sink-{name}-{}", std::process::id()));
        std::fs::create_dir_all(&p).unwrap();
        p
    }

    #[test]
    fn sharded_roundtrip_preserves_stream_order() {
        for shards in [1usize, 3, 4] {
            let dir = tmpdir(&format!("rt{shards}"));
            let mut sink = ShardedCsvSink::create(&dir, shards, "m2090").unwrap();
            // 10 records: not a multiple of 3, so shard lengths
            // differ by one (a valid round-robin layout).
            for i in 0..10 {
                sink.accept(&rec(i)).unwrap();
            }
            sink.finish().unwrap();
            assert_eq!(sink.written(), 10);
            let back = load_sharded(&dir).unwrap();
            assert_eq!(back.len(), 10);
            for (i, r) in back.iter().enumerate() {
                assert_eq!(r.base.features[0], i as f64, "shards={shards}");
                assert_eq!(r.base.speedup, rec(i as u64).base.speedup);
                // v1 shards drop the joint label by design
                assert_eq!(r.best_wg, None);
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn v2_shards_roundtrip_the_joint_label() {
        let dir = tmpdir("v2rt");
        let mut sink =
            ShardedCsvSink::create_schema(&dir, 3, "m2090", Schema::V2).unwrap();
        assert_eq!(sink.schema(), Schema::V2);
        for i in 0..10 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        let (back, stream) = load_sharded_tagged(&dir).unwrap();
        assert_eq!(stream.schema, Schema::V2);
        assert_eq!(stream.device.as_deref(), Some("m2090"));
        assert_eq!(back.len(), 10);
        for (i, r) in back.iter().enumerate() {
            let want = rec(i as u64);
            assert_eq!(r.base.features[0], i as f64);
            assert_eq!(r.best_wg, want.best_wg);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_schema_shards_are_a_typed_error() {
        // A v1 shard next to a v2 shard must never interleave: the v1
        // rows have no label plane.
        let dir = tmpdir("mixschema");
        let mut sink =
            ShardedCsvSink::create_schema(&dir, 2, "m2090", Schema::V2).unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Strip shard 1's schema stamp and label columns so it reads as
        // a (well-formed) v1 shard.
        let p = shard_path(&dir, 1);
        let body = std::fs::read_to_string(&p).unwrap();
        let v1_body: String = body
            .lines()
            .map(|l| {
                if l.starts_with('#') {
                    l.to_string()
                } else {
                    let cols: Vec<&str> = l.split(',').collect();
                    cols[..cols.len() - 2].join(",")
                }
            })
            .filter(|l| l != "# schema=v2")
            .map(|l| format!("{l}\n"))
            .collect();
        std::fs::write(&p, v1_body).unwrap();

        let err = load_sharded(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("schema mismatch"), "{msg}");
        assert!(msg.contains("v2") && msg.contains("v1"), "{msg}");
        assert!(err.downcast_ref::<SchemaMismatch>().is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stream_sharded_reports_global_indices_and_device() {
        let dir = tmpdir("idx");
        let mut sink = ShardedCsvSink::create(&dir, 2, "gtx480").unwrap();
        assert_eq!(sink.device(), "gtx480");
        for i in 0..7 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        let mut seen = Vec::new();
        let stream = stream_sharded(&dir, |idx, r| {
            assert_eq!(r.base.features[0], idx as f64);
            seen.push(idx);
            Ok(())
        })
        .unwrap();
        assert_eq!(stream.rows, 7);
        assert_eq!(stream.device.as_deref(), Some("gtx480"));
        assert_eq!(stream.schema, Schema::V1);
        assert_eq!(seen, (0..7).collect::<Vec<_>>());
        let (back, stamp) = load_sharded_tagged(&dir).unwrap();
        assert_eq!(back.len(), 7);
        assert_eq!(stamp.device.as_deref(), Some("gtx480"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_device_shards_are_a_typed_error() {
        // Two shards written by runs on different devices must never
        // interleave into one stream.
        let dir = tmpdir("mix");
        let mut sink = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Restamp shard 1 as if it came from a K20 run.
        let p = shard_path(&dir, 1);
        let body = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, body.replace("# device=m2090", "# device=k20")).unwrap();

        let err = load_sharded(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("device mismatch"), "{msg}");
        assert!(msg.contains("m2090") && msg.contains("k20"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unstamped_legacy_shards_still_load() {
        // Shards written before device stamping (no `# device=` line)
        // must load with device=None, but mixing stamped and unstamped
        // files is rejected.
        let dir = tmpdir("legacy");
        let mut sink = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        for i in 0..2 {
            let p = shard_path(&dir, i);
            let body = std::fs::read_to_string(&p).unwrap();
            std::fs::write(&p, body.replace("# device=m2090\n", "")).unwrap();
        }
        let stream = stream_sharded_rows(&dir, |_, _, _| Ok(())).unwrap();
        assert_eq!(stream.rows, 4);
        assert_eq!(stream.device, None);
        assert_eq!(stream.schema, Schema::V1);

        // restore the stamp on shard 0 only -> mixed -> typed error
        let p = shard_path(&dir, 1);
        let body = std::fs::read_to_string(&p).unwrap();
        std::fs::write(&p, format!("# device=m2090\n{body}")).unwrap();
        let err = stream_sharded_rows(&dir, |_, _, _| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("device mismatch"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ensure_same_device_returns_the_typed_error() {
        assert!(ensure_same_device("m2090", "m2090", "x").is_ok());
        let err = ensure_same_device("m2090", "k20", "data/shards").unwrap_err();
        assert_eq!(
            err,
            DeviceMismatch {
                expected: "m2090".into(),
                found: "k20".into(),
                at: "data/shards".into(),
            }
        );
        // and it converts into anyhow with the message intact
        let any: anyhow::Error = err.into();
        assert!(format!("{any}").contains("device mismatch"));
    }

    #[test]
    fn ragged_shards_are_rejected_not_truncated() {
        let dir = tmpdir("ragged");
        let mut sink = ShardedCsvSink::create(&dir, 3, "m2090").unwrap();
        for i in 0..5 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Simulate an interrupted later writer: shard 0 gains an extra
        // row the other shards never matched.
        use std::io::Write;
        let mut fh = std::fs::OpenOptions::new()
            .append(true)
            .open(shard_path(&dir, 0))
            .unwrap();
        let row: Vec<String> =
            rec(9).csv_row(Schema::V1).iter().map(|x| x.to_string()).collect();
        writeln!(fh, "{}", row.join(",")).unwrap();
        drop(fh);
        let err = load_sharded(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("ragged"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recreating_with_fewer_shards_removes_stale_files() {
        let dir = tmpdir("stale");
        let mut first = ShardedCsvSink::create(&dir, 4, "m2090").unwrap();
        for i in 0..10 {
            first.accept(&rec(i)).unwrap();
        }
        first.finish().unwrap();

        // Re-run into the same directory with fewer shards: the old
        // shard-002/003 files must not leak into the new stream.
        let mut second = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 100..106 {
            second.accept(&rec(i)).unwrap();
        }
        second.finish().unwrap();

        let back = load_sharded(&dir).unwrap();
        assert_eq!(back.len(), 6);
        for (i, r) in back.iter().enumerate() {
            assert_eq!(r.base.features[0], (100 + i) as f64);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_shards_is_an_error() {
        let dir = tmpdir("empty");
        assert!(load_sharded(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reservoir_keeps_capacity_and_is_deterministic() {
        let mut a = ReservoirSink::new(16, 99);
        let mut b = ReservoirSink::new(16, 99);
        for i in 0..1000 {
            a.accept(&rec(i)).unwrap();
            b.accept(&rec(i)).unwrap();
        }
        assert_eq!(a.seen(), 1000);
        assert_eq!(a.records().len(), 16);
        let (ra, ia) = a.into_sample();
        let (rb, ib) = b.into_sample();
        assert_eq!(ia, ib);
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.base.features, y.base.features);
        }
        // indices actually identify the kept records
        for (r, &i) in rb.iter().zip(&ib) {
            assert_eq!(r.base.features[0], i as f64);
        }
    }

    #[test]
    fn reservoir_sample_is_roughly_uniform() {
        // Over many seeds, late and early records are kept about
        // equally often.
        let mut early = 0usize;
        let mut late = 0usize;
        for seed in 0..200 {
            let mut s = ReservoirSink::new(10, seed);
            for i in 0..100 {
                s.accept(&rec(i)).unwrap();
            }
            for &i in &s.indices {
                if i < 50 {
                    early += 1;
                } else {
                    late += 1;
                }
            }
        }
        let frac = early as f64 / (early + late) as f64;
        assert!((frac - 0.5).abs() < 0.1, "early fraction {frac}");
    }

    #[test]
    fn reservoir_short_stream_keeps_everything() {
        let mut s = ReservoirSink::new(100, 1);
        for i in 0..5 {
            s.accept(&rec(i)).unwrap();
        }
        assert_eq!(s.records().len(), 5);
        assert_eq!(s.selected_indices().len(), 5);
    }

    #[test]
    fn tee_feeds_both_sinks() {
        let mut m = MemorySink::new();
        let mut r = ReservoirSink::new(4, 7);
        let mut tee = Tee(&mut m, &mut r);
        for i in 0..20 {
            tee.accept(&rec(i)).unwrap();
        }
        tee.finish().unwrap();
        assert_eq!(m.records.len(), 20);
        assert_eq!(r.records().len(), 4);
        assert_eq!(r.seen(), 20);
    }

    #[test]
    fn parse_shard_name_accepts_any_pad_and_both_formats() {
        assert_eq!(parse_shard_name("shard-000.csv"), Some((0, ShardFormat::Csv)));
        assert_eq!(
            parse_shard_name("shard-00042.bin"),
            Some((42, ShardFormat::Bin))
        );
        assert_eq!(
            parse_shard_name("shard-1199.csv"),
            Some((1199, ShardFormat::Csv))
        );
        assert_eq!(parse_shard_name("shard-.csv"), None);
        assert_eq!(parse_shard_name("shard-12.txt"), None);
        assert_eq!(parse_shard_name("shard-1x2.csv"), None);
        assert_eq!(parse_shard_name("notashard-1.csv"), None);
        assert_eq!(parse_shard_name("shard-00000000000.csv"), None); // >10 digits
    }

    #[test]
    fn shard_files_sorts_numerically_over_1200_shards() {
        // A 1200-shard dir: lexical order of 3-digit legacy names would
        // interleave shard-1000 before shard-200 and scramble stream
        // order. The listing must come back in numeric index order.
        let dir = tmpdir("numsort");
        for i in 0..1200usize {
            // legacy 3-digit pad, the worst case for lexical sorting
            std::fs::write(dir.join(format!("shard-{i:03}.csv")), "").unwrap();
        }
        // plus a non-shard file that must be ignored
        std::fs::write(dir.join("notes.txt"), "x").unwrap();
        let files = shard_files(&dir).unwrap();
        assert_eq!(files.len(), 1200);
        for (i, p) in files.iter().enumerate() {
            let name = p.file_name().unwrap().to_str().unwrap();
            assert_eq!(
                parse_shard_name(name).unwrap().0,
                i as u64,
                "position {i} got {name}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_and_gapped_shard_indices_are_errors() {
        let dir = tmpdir("dupidx");
        // same index under two pad widths
        std::fs::write(dir.join("shard-003.csv"), "").unwrap();
        std::fs::write(dir.join("shard-00003.csv"), "").unwrap();
        for i in 0..3 {
            std::fs::write(dir.join(format!("shard-{i:05}.csv")), "").unwrap();
        }
        let err = shard_files(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("more than once"), "{err:#}");
        std::fs::remove_file(dir.join("shard-003.csv")).unwrap();
        std::fs::remove_file(dir.join("shard-00003.csv")).unwrap();
        // now a gap: 0,1,2 then 5
        std::fs::write(dir.join("shard-00005.csv"), "").unwrap();
        let err = shard_files(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("not contiguous"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mixed_format_shards_are_a_typed_error() {
        let dir = tmpdir("mixfmt");
        let mut sink =
            ShardedCsvSink::create_schema(&dir, 2, "m2090", Schema::V2).unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        // Replace shard 1 with a binary shard holding the same records.
        std::fs::remove_file(shard_path(&dir, 1)).unwrap();
        let mut w = binfmt::BinShardWriter::create(
            &shard_path_for(&dir, 1, ShardFormat::Bin),
            "m2090",
            Schema::V2,
        )
        .unwrap();
        w.write_row(&rec(1).csv_row(Schema::V2)).unwrap();
        w.write_row(&rec(3).csv_row(Schema::V2)).unwrap();
        w.finish().unwrap();
        let err = load_sharded(&dir).unwrap_err();
        let m = err.downcast_ref::<FormatMismatch>().expect("typed error");
        assert_eq!(m.expected, ShardFormat::Csv);
        assert_eq!(m.found, ShardFormat::Bin);
        assert!(format!("{err:#}").contains("format mismatch"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_detection_trusts_bytes_not_extensions() {
        // A binary shard renamed .csv must still be read as binary —
        // and then rejected for mixing with a real CSV shard.
        let dir = tmpdir("sniff");
        let mut sink = ShardedCsvSink::create(&dir, 2, "m2090").unwrap();
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        let mut w = binfmt::BinShardWriter::create(
            &dir.join("shard-tmp.binwrite"),
            "m2090",
            Schema::V1,
        )
        .unwrap();
        w.write_row(&rec(1).csv_row(Schema::V1)).unwrap();
        w.finish().unwrap();
        std::fs::rename(dir.join("shard-tmp.binwrite"), shard_path(&dir, 1))
            .unwrap();
        let err = load_sharded(&dir).unwrap_err();
        assert!(err.downcast_ref::<FormatMismatch>().is_some(), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recreating_with_other_format_removes_stale_files() {
        let dir = tmpdir("stalefmt");
        let mut csv = ShardedCsvSink::create_schema(&dir, 3, "m2090", Schema::V2)
            .unwrap();
        for i in 0..6 {
            csv.accept(&rec(i)).unwrap();
        }
        csv.finish().unwrap();
        // plus an old-pad leftover that parse-based cleanup must catch
        std::fs::write(dir.join("shard-007.csv"), "").unwrap();

        let mut bin = ShardedSink::create(
            &dir,
            2,
            "m2090",
            Schema::V2,
            ShardFormat::Bin,
        )
        .unwrap();
        assert_eq!(bin.format(), ShardFormat::Bin);
        for i in 100..105 {
            bin.accept(&rec(i)).unwrap();
        }
        bin.finish().unwrap();
        assert_eq!(bin.written(), 5);

        let (back, stream) = load_sharded_tagged(&dir).unwrap();
        assert_eq!(stream.format, ShardFormat::Bin);
        assert_eq!(stream.schema, Schema::V2);
        assert_eq!(stream.device.as_deref(), Some("m2090"));
        assert_eq!(back.len(), 5);
        for (i, r) in back.iter().enumerate() {
            assert_eq!(r.base.features[0], (100 + i) as f64);
            assert_eq!(r.best_wg, rec((100 + i) as u64).best_wg);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inspect_shard_reports_both_formats() {
        let dir = tmpdir("inspect");
        let mut sink = ShardedSink::create(
            &dir,
            2,
            "gtx480",
            Schema::V2,
            ShardFormat::Bin,
        )
        .unwrap();
        for i in 0..5 {
            sink.accept(&rec(i)).unwrap();
        }
        sink.finish().unwrap();
        let info = inspect_shard(&shard_path_for(&dir, 0, ShardFormat::Bin))
            .unwrap();
        assert_eq!(info.format, ShardFormat::Bin);
        assert_eq!(info.device.as_deref(), Some("gtx480"));
        assert_eq!(info.schema, Schema::V2);
        assert_eq!(info.rows, 3); // records 0, 2, 4
        assert!(info.checksum.is_some());

        let mut csv = ShardedCsvSink::create(&dir, 1, "gtx480").unwrap();
        for i in 0..4 {
            csv.accept(&rec(i)).unwrap();
        }
        csv.finish().unwrap();
        let info = inspect_shard(&shard_path(&dir, 0)).unwrap();
        assert_eq!(info.format, ShardFormat::Csv);
        assert_eq!(info.rows, 4);
        assert_eq!(info.checksum, None);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn zero_row_trailing_shards_load_in_both_formats() {
        // 2 records over 4 shards: shards 2 and 3 are header-only. The
        // replay and `ReservoirSink` paths both see exactly 2 records.
        for format in [ShardFormat::Csv, ShardFormat::Bin] {
            let dir = tmpdir(&format!("zerorow-{format}"));
            let mut sink =
                ShardedSink::create(&dir, 4, "m2090", Schema::V2, format).unwrap();
            for i in 0..2 {
                sink.accept(&rec(i)).unwrap();
            }
            sink.finish().unwrap();
            let (back, stream) = load_sharded_tagged(&dir).unwrap();
            assert_eq!(stream.rows, 2, "{format}");
            assert_eq!(stream.format, format);
            assert_eq!(back.len(), 2);
            for (i, r) in back.iter().enumerate() {
                assert_eq!(r.base.features[0], i as f64);
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn summary_matches_batch_stats() {
        let recs: Vec<SpeedupRecord> = (0..50).map(|i| rec(i).base).collect();
        let mut s = DatasetSummary::default();
        for r in &recs {
            s.observe(r);
        }
        assert_eq!(s.records, 50);
        let ben = recs.iter().filter(|r| r.beneficial()).count();
        assert_eq!(s.beneficial, ben as u64);
        let geo = crate::util::stats::geomean(
            &recs.iter().map(|r| r.speedup).collect::<Vec<_>>(),
        );
        assert!((s.geomean_speedup() - geo).abs() < 1e-12);
        assert_eq!(s.max_speedup, 3.5);
    }
}
