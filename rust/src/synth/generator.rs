//! Synthetic-kernel enumeration (paper §5).
//!
//! Step 1: sample context tuples (Table 2). Step 2: for each tuple,
//! enumerate all 7 home access patterns and the 4x4 N/M value sets that
//! pattern prescribes. At the paper's 100 tuples this yields 100 x 7 x 16
//! = 11200 templates (the paper reports 9600; its counting excludes some
//! N/M combinations it "perceives as common" — we document the delta in
//! EXPERIMENTS.md and keep the full cross product, scaled by `scale`).

use crate::kernelmodel::access::HomePattern;
use crate::kernelmodel::template::Template;
use crate::util::prng::Rng;

use super::sampler::{sample_tuples, ContextTuple};

/// Target-array geometry the paper fixes for all synthetic kernels.
pub const IN_H: u32 = 2048;
pub const IN_W: u32 = 2048;

/// Paper-scale tuple count.
pub const PAPER_TUPLES: usize = 100;

/// Templates enumerated per context tuple (7 home patterns x 4x4 N/M).
pub const TEMPLATES_PER_TUPLE: usize = 7 * 16;

/// Context tuples generated at `scale` (1.0 = the paper's 100).
pub fn tuple_count(scale: f64) -> usize {
    ((PAPER_TUPLES as f64 * scale).round() as usize).max(1)
}

/// Templates generated at `scale` — lets callers size progress totals
/// and chunking before generating anything.
pub fn template_count(scale: f64) -> usize {
    tuple_count(scale) * TEMPLATES_PER_TUPLE
}

pub fn template_from(tuple: &ContextTuple, home: HomePattern, n: u32, m: u32) -> Template {
    Template {
        in_h: IN_H,
        in_w: IN_W,
        home,
        n,
        m,
        stencil: tuple.stencil,
        radius: tuple.radius,
        comp_ilb: tuple.comp_ilb,
        comp_ep: tuple.comp_ep,
        coal_ilb: tuple.coal_ilb,
        coal_ep: tuple.coal_ep,
        uncoal_ilb: tuple.uncoal_ilb,
        uncoal_ep: tuple.uncoal_ep,
    }
}

/// Generate the synthetic kernel population. `scale` in (0, 1] scales the
/// number of context tuples (1.0 = the paper's 100).
pub fn generate(rng: &mut Rng, scale: f64) -> Vec<Template> {
    generate_n(rng, tuple_count(scale))
}

pub fn generate_n(rng: &mut Rng, num_tuples: usize) -> Vec<Template> {
    let tuples = sample_tuples(rng, num_tuples);
    let mut out = Vec::with_capacity(num_tuples * TEMPLATES_PER_TUPLE);
    for tuple in &tuples {
        for home in HomePattern::ALL {
            for &n in &home.n_values() {
                for &m in &home.m_values() {
                    out.push(template_from(tuple, home, n, m));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_counts() {
        let mut rng = Rng::new(42);
        let ts = generate(&mut rng, 1.0);
        assert_eq!(ts.len(), 100 * 7 * 16);
    }

    #[test]
    fn scaled_generation() {
        let mut rng = Rng::new(42);
        assert_eq!(generate(&mut rng, 0.1).len(), 10 * 7 * 16);
        let mut rng2 = Rng::new(42);
        assert_eq!(generate(&mut rng2, 0.001).len(), 7 * 16); // >= 1 tuple
    }

    #[test]
    fn n_m_respect_pattern_value_sets() {
        let mut rng = Rng::new(7);
        for t in generate(&mut rng, 0.05) {
            assert!(t.home.n_values().contains(&t.n), "{t:?}");
            assert!(t.home.m_values().contains(&t.m), "{t:?}");
            assert_eq!((t.in_h, t.in_w), (2048, 2048));
        }
    }

    #[test]
    fn all_patterns_covered() {
        let mut rng = Rng::new(9);
        let ts = generate(&mut rng, 0.02);
        for home in HomePattern::ALL {
            assert!(ts.iter().any(|t| t.home == home), "{home} missing");
        }
    }

    #[test]
    fn count_helpers_match_generation() {
        assert_eq!(template_count(1.0), 100 * 7 * 16);
        for scale in [0.001, 0.03, 0.2, 1.0] {
            let mut rng = Rng::new(3);
            assert_eq!(generate(&mut rng, scale).len(), template_count(scale));
        }
    }

    #[test]
    fn deterministic_from_seed() {
        let a = generate(&mut Rng::new(5), 0.03);
        let b = generate(&mut Rng::new(5), 0.03);
        assert_eq!(a, b);
    }
}
