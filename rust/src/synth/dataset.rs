//! Kernel-instance dataset: build (template x launch) instances, measure
//! them on the simulated testbed, persist/reload as CSV.
//!
//! Instances whose *baseline* cannot launch (register file overflow with
//! huge workgroups) are skipped — the paper's sweep likewise only contains
//! configurations the original kernel can run.

use std::path::Path;

use anyhow::Result;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::features::{FEATURE_NAMES, NUM_FEATURES};
use crate::kernelmodel::template::Template;
use crate::sim::exec::{measure, MeasureConfig, SpeedupRecord};
use crate::sim::timing::{simulate, Variant};
use crate::util::pool::parallel_map;
use crate::util::prng::Rng;
use crate::util::{csv, stats};

use super::sweep::LaunchSweep;

/// Dataset build options.
#[derive(Clone, Debug)]
pub struct BuildConfig {
    /// Launch configurations sampled per kernel template.
    pub configs_per_kernel: usize,
    pub measure: MeasureConfig,
    pub seed: u64,
    pub threads: usize,
}

impl Default for BuildConfig {
    fn default() -> Self {
        BuildConfig {
            configs_per_kernel: 48,
            measure: MeasureConfig::default(),
            seed: 0xDA7A5E7,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }
}

/// Build speedup records for every (template, sampled launch) instance.
pub fn build(
    templates: &[Template],
    sweep: &LaunchSweep,
    dev: &DeviceSpec,
    cfg: &BuildConfig,
) -> Vec<SpeedupRecord> {
    // Pre-draw per-template launch samples (deterministic from seed).
    let mut rng = Rng::new(cfg.seed);
    let jobs: Vec<(usize, Vec<crate::kernelmodel::launch::Launch>)> = templates
        .iter()
        .enumerate()
        .map(|(i, _)| {
            let mut trng = rng.fork(i as u64);
            (i, sweep.sampled_balanced(&mut trng, cfg.configs_per_kernel))
        })
        .collect();

    let nested = parallel_map(&jobs, cfg.threads, |(i, launches)| {
        let t = &templates[*i];
        let mut recs = Vec::with_capacity(launches.len());
        for launch in launches {
            let d = t.descriptor(launch, dev);
            // Skip instances whose baseline can't even launch.
            if !simulate(&d, dev, Variant::Baseline).feasible() {
                continue;
            }
            recs.push(measure(&d, dev, &cfg.measure));
        }
        recs
    });
    nested.into_iter().flatten().collect()
}

/// CSV header: the 18 features + the measured speedup.
pub fn csv_header() -> Vec<&'static str> {
    let mut h: Vec<&'static str> = FEATURE_NAMES.to_vec();
    h.push("speedup");
    h
}

pub fn save(records: &[SpeedupRecord], path: &Path) -> Result<()> {
    let rows: Vec<Vec<f64>> = records
        .iter()
        .map(|r| {
            let mut row = r.features.to_vec();
            row.push(r.speedup);
            row
        })
        .collect();
    csv::write_table(path, &csv_header(), &rows)
}

pub fn load(path: &Path) -> Result<Vec<SpeedupRecord>> {
    let (header, rows) = csv::read_table(path)?;
    anyhow::ensure!(
        header.len() == NUM_FEATURES + 1,
        "{}: expected {} columns, got {}",
        path.display(),
        NUM_FEATURES + 1,
        header.len()
    );
    let mut out = Vec::with_capacity(rows.len());
    for (i, row) in rows.into_iter().enumerate() {
        // Validate each row independently of the reader's invariants so
        // short/ragged rows are an Err, never a copy_from_slice panic.
        anyhow::ensure!(
            row.len() == NUM_FEATURES + 1,
            "{}:{}: row has {} columns, expected {}",
            path.display(),
            i + 2,
            row.len(),
            NUM_FEATURES + 1
        );
        let mut features = [0.0; NUM_FEATURES];
        features.copy_from_slice(&row[..NUM_FEATURES]);
        out.push(SpeedupRecord {
            name: format!("row{i}"),
            features,
            speedup: row[NUM_FEATURES],
            baseline_time: f64::NAN,
            optimized_time: f64::NAN,
        });
    }
    Ok(out)
}

/// Split records into train/test by random permutation (paper: train on
/// a random 10%, evaluate on the rest).
pub fn split<'a>(
    records: &'a [SpeedupRecord],
    train_fraction: f64,
    seed: u64,
) -> (Vec<&'a SpeedupRecord>, Vec<&'a SpeedupRecord>) {
    let mut idx: Vec<usize> = (0..records.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_train = ((records.len() as f64 * train_fraction).round() as usize)
        .clamp(1, records.len().saturating_sub(1).max(1));
    let train = idx[..n_train].iter().map(|&i| &records[i]).collect();
    let test = idx[n_train..].iter().map(|&i| &records[i]).collect();
    (train, test)
}

/// Summary used by reports: count, beneficial fraction, speedup range.
pub fn summarize(records: &[SpeedupRecord]) -> (usize, f64, f64, f64) {
    let n = records.len();
    let beneficial =
        records.iter().filter(|r| r.beneficial()).count() as f64 / n.max(1) as f64;
    let speedups: Vec<f64> = records.iter().map(|r| r.speedup).collect();
    let geo = stats::geomean(&speedups);
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    (n, beneficial, geo, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::generator;

    fn small_dataset() -> Vec<SpeedupRecord> {
        let mut rng = Rng::new(1234);
        let templates = generator::generate_n(&mut rng, 2); // 2*7*16 kernels
        let sweep = LaunchSweep::new(2048, 2048);
        let dev = DeviceSpec::m2090();
        let cfg = BuildConfig {
            configs_per_kernel: 4,
            threads: 2,
            ..BuildConfig::default()
        };
        build(&templates, &sweep, &dev, &cfg)
    }

    #[test]
    fn build_produces_instances() {
        let recs = small_dataset();
        assert!(recs.len() > 500, "{} records", recs.len());
        for r in &recs {
            assert!(r.features.iter().all(|x| x.is_finite()));
            assert!(r.speedup > 0.0);
        }
    }

    #[test]
    fn dataset_contains_both_classes() {
        let recs = small_dataset();
        let pos = recs.iter().filter(|r| r.beneficial()).count();
        assert!(pos > 0, "no beneficial instances");
        assert!(pos < recs.len(), "every instance beneficial");
    }

    #[test]
    fn save_load_roundtrip() {
        let recs = small_dataset();
        let path = std::env::temp_dir()
            .join(format!("lmtuner-ds-{}.csv", std::process::id()));
        save(&recs, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), recs.len());
        for (a, b) in recs.iter().zip(&back) {
            assert_eq!(a.features, b.features);
            assert!((a.speedup - b.speedup).abs() < 1e-9);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_malformed_files_without_panicking() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();

        // Short data row under a correct header. Today the CSV layer
        // already rejects this (load's own per-row ensure is a second
        // line of defense against reader changes); either way the
        // contract under test is `Err`, never a copy_from_slice panic.
        let short_row = dir.join(format!("lmtuner-ds-short-{pid}.csv"));
        std::fs::write(
            &short_row,
            format!("{}\n1,2,3\n", csv_header().join(",")),
        )
        .unwrap();
        assert!(load(&short_row).is_err());
        std::fs::remove_file(&short_row).ok();

        // Header with too few columns.
        let short_header = dir.join(format!("lmtuner-ds-hdr-{pid}.csv"));
        std::fs::write(&short_header, "a,b\n1,2\n").unwrap();
        assert!(load(&short_header).is_err());
        std::fs::remove_file(&short_header).ok();

        // Non-numeric cell.
        let bad_cell = dir.join(format!("lmtuner-ds-bad-{pid}.csv"));
        let row: Vec<String> =
            (0..NUM_FEATURES + 1).map(|_| "oops".to_string()).collect();
        std::fs::write(
            &bad_cell,
            format!("{}\n{}\n", csv_header().join(","), row.join(",")),
        )
        .unwrap();
        assert!(load(&bad_cell).is_err());
        std::fs::remove_file(&bad_cell).ok();
    }

    #[test]
    fn split_fractions() {
        let recs = small_dataset();
        let (train, test) = split(&recs, 0.1, 99);
        assert_eq!(train.len() + test.len(), recs.len());
        let frac = train.len() as f64 / recs.len() as f64;
        assert!((frac - 0.1).abs() < 0.02, "train fraction {frac}");
    }

    #[test]
    fn build_is_deterministic() {
        let a = small_dataset();
        let b = small_dataset();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.speedup, y.speedup);
        }
    }
}
