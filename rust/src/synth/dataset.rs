//! Kernel-instance dataset: build (template x launch) instances, measure
//! them on the simulated testbed, persist/reload as CSV.
//!
//! Two build paths share one deterministic record order:
//!
//! * [`build_serial`] — the reference implementation: one thread, one
//!   `Vec`. Kept as the equivalence baseline and the bench yardstick.
//! * [`build_streaming`] — the paper-scale path: templates are
//!   processed in chunks, each chunk fanned across the thread pool,
//!   and every record streamed to a [`sink::RecordSink`] in the same
//!   order `build_serial` would produce it. Peak memory is ~two chunks
//!   of records regardless of dataset size.
//!
//! [`build`] is `build_streaming` into a [`sink::MemorySink`].
//!
//! Instances whose *baseline* cannot launch (register file overflow with
//! huge workgroups) are skipped — the paper's sweep likewise only contains
//! configurations the original kernel can run.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::features::{FEATURE_NAMES, NUM_FEATURES};
use crate::kernelmodel::template::Template;
use crate::sim::exec::{measure, MeasureConfig, Schema, TuneRecord};
use crate::sim::timing::{simulate, Variant};
use crate::util::pool::parallel_map_streamed;
use crate::util::prng::Rng;
use crate::util::{csv, stats};

use super::binfmt::{self, ShardFormat};
use super::sink::{self, DatasetSummary, MemorySink, RecordSink};
use super::sweep::{argmax_wg, LaunchSweep};

/// Dataset build options.
#[derive(Clone, Debug)]
pub struct BuildConfig {
    /// Launch configurations sampled per kernel template.
    pub configs_per_kernel: usize,
    pub measure: MeasureConfig,
    pub seed: u64,
    pub threads: usize,
    /// Templates simulated per streaming chunk (0 = auto: 8 x threads).
    /// Peak memory of a streaming build is ~two chunks of records (one
    /// being consumed, one lookahead), so this is the
    /// memory/parallelism-grain trade-off.
    pub chunk_templates: usize,
}

impl Default for BuildConfig {
    fn default() -> Self {
        BuildConfig {
            configs_per_kernel: 48,
            measure: MeasureConfig::default(),
            seed: 0xDA7A5E7,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            chunk_templates: 0,
        }
    }
}

impl BuildConfig {
    fn chunk(&self) -> usize {
        if self.chunk_templates > 0 {
            self.chunk_templates
        } else {
            8 * self.threads.max(1)
        }
    }
}

/// Progress snapshot handed to the streaming build's callback after
/// every chunk.
#[derive(Clone, Copy, Debug)]
pub struct BuildProgress {
    pub templates_done: usize,
    pub templates_total: usize,
    pub records: u64,
    pub elapsed_seconds: f64,
}

impl BuildProgress {
    pub fn rows_per_second(&self) -> f64 {
        self.records as f64 / self.elapsed_seconds.max(1e-9)
    }
}

/// Per-template fork of the build RNG. Drawn sequentially from the
/// root seed so every build path sees the identical stream, whatever
/// its chunking or thread count.
fn template_rngs(seed: u64, n: usize) -> Vec<Rng> {
    let mut rng = Rng::new(seed);
    (0..n).map(|i| rng.fork(i as u64)).collect()
}

/// Measure every feasible (template, sampled launch) instance and
/// derive the template's joint argmax-workgroup label from the same
/// sweep (no second measurement pass): each instance's best achieved
/// time is min(baseline, optimized), and the label is the workgroup
/// shape of the fastest measured launch (`sweep::argmax_wg`).
fn measure_template(
    t: &Template,
    mut trng: Rng,
    sweep: &LaunchSweep,
    dev: &DeviceSpec,
    cfg: &BuildConfig,
) -> Vec<TuneRecord> {
    let launches = sweep.sampled_balanced(&mut trng, cfg.configs_per_kernel);
    let mut measured = Vec::with_capacity(launches.len());
    for launch in &launches {
        let d = t.descriptor(launch, dev);
        // Skip instances whose baseline can't even launch.
        if !simulate(&d, dev, Variant::Baseline).feasible() {
            continue;
        }
        measured.push((*launch, measure(&d, dev, &cfg.measure)));
    }
    let timed: Vec<_> = measured
        .iter()
        .map(|(l, r)| (*l, r.baseline_time.min(r.optimized_time)))
        .collect();
    let best_wg = argmax_wg(&timed);
    measured
        .into_iter()
        .map(|(_, base)| TuneRecord { base, best_wg })
        .collect()
}

/// Reference single-threaded build: the canonical record order every
/// other build path must reproduce bit-for-bit.
pub fn build_serial(
    templates: &[Template],
    sweep: &LaunchSweep,
    dev: &DeviceSpec,
    cfg: &BuildConfig,
) -> Vec<TuneRecord> {
    let rngs = template_rngs(cfg.seed, templates.len());
    let mut out = Vec::new();
    for (t, trng) in templates.iter().zip(rngs) {
        out.extend(measure_template(t, trng, sweep, dev, cfg));
    }
    out
}

/// Streaming chunk-parallel build: fans template work across the
/// thread pool one chunk at a time and pushes every record to `sink`
/// in canonical order. Returns the incrementally-accumulated summary.
/// `progress` (if given) is invoked after every chunk.
pub fn build_streaming<S: RecordSink>(
    templates: &[Template],
    sweep: &LaunchSweep,
    dev: &DeviceSpec,
    cfg: &BuildConfig,
    sink: &mut S,
    mut progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> Result<DatasetSummary> {
    let _span = crate::span!("dataset.build");
    let t0 = Instant::now();
    let rngs = template_rngs(cfg.seed, templates.len());
    let jobs: Vec<(usize, Rng)> = rngs.into_iter().enumerate().collect();
    let mut summary = DatasetSummary::default();
    parallel_map_streamed(
        &jobs,
        cfg.threads,
        cfg.chunk(),
        |(i, trng)| measure_template(&templates[*i], trng.clone(), sweep, dev, cfg),
        |base, chunk| -> Result<()> {
            let done = base + chunk.len();
            for recs in chunk {
                for rec in recs {
                    summary.observe(&rec.base);
                    sink.accept(&rec)?;
                }
            }
            if let Some(p) = progress.as_deref_mut() {
                p(&BuildProgress {
                    templates_done: done,
                    templates_total: templates.len(),
                    records: summary.records,
                    elapsed_seconds: t0.elapsed().as_secs_f64(),
                });
            }
            Ok(())
        },
    )?;
    sink.finish()?;
    Ok(summary)
}

/// One-pass multi-device build: measure every template on each of
/// `devices`, fanning each device's records to its own sink in the
/// same canonical order a single-device [`build_streaming`] for that
/// device would produce (each device gets a clone of the template's
/// forked RNG, so the per-device streams are bit-identical to
/// single-device builds at any thread count or chunking). One
/// generation pass replaces N — the cross-device portfolio no longer
/// regenerates identical templates per device — and peak memory stays
/// ~two chunks of records per device regardless of dataset size.
/// Returns one [`DatasetSummary`] per device, in `devices` order.
/// `progress.records` counts records across all devices.
pub fn build_multi_device<S: RecordSink>(
    templates: &[Template],
    sweep: &LaunchSweep,
    devices: &[DeviceSpec],
    cfg: &BuildConfig,
    sinks: &mut [S],
    mut progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> Result<Vec<DatasetSummary>> {
    anyhow::ensure!(!devices.is_empty(), "build_multi_device: no devices");
    anyhow::ensure!(
        devices.len() == sinks.len(),
        "build_multi_device: {} devices but {} sinks",
        devices.len(),
        sinks.len()
    );
    let _span = crate::span!("dataset.build_multi_device");
    let t0 = Instant::now();
    let rngs = template_rngs(cfg.seed, templates.len());
    let jobs: Vec<(usize, Rng)> = rngs.into_iter().enumerate().collect();
    let mut summaries = vec![DatasetSummary::default(); devices.len()];
    parallel_map_streamed(
        &jobs,
        cfg.threads,
        cfg.chunk(),
        |(i, trng)| {
            devices
                .iter()
                .map(|dev| {
                    measure_template(&templates[*i], trng.clone(), sweep, dev, cfg)
                })
                .collect::<Vec<_>>()
        },
        |base, chunk| -> Result<()> {
            let done = base + chunk.len();
            for per_dev in chunk {
                for (d, recs) in per_dev.into_iter().enumerate() {
                    for rec in recs {
                        summaries[d].observe(&rec.base);
                        sinks[d].accept(&rec)?;
                    }
                }
            }
            if let Some(p) = progress.as_deref_mut() {
                p(&BuildProgress {
                    templates_done: done,
                    templates_total: templates.len(),
                    records: summaries.iter().map(|s| s.records).sum(),
                    elapsed_seconds: t0.elapsed().as_secs_f64(),
                });
            }
            Ok(())
        },
    )?;
    for s in sinks.iter_mut() {
        s.finish()?;
    }
    Ok(summaries)
}

/// Build speedup records for every (template, sampled launch) instance
/// in memory (streaming build into a `MemorySink`).
pub fn build(
    templates: &[Template],
    sweep: &LaunchSweep,
    dev: &DeviceSpec,
    cfg: &BuildConfig,
) -> Vec<TuneRecord> {
    let mut sink = MemorySink::new();
    build_streaming(templates, sweep, dev, cfg, &mut sink, None)
        .expect("in-memory sink cannot fail");
    sink.records
}

/// CSV header: the 18 features + the measured speedup (schema v1).
pub fn csv_header() -> Vec<&'static str> {
    let mut h: Vec<&'static str> = FEATURE_NAMES.to_vec();
    h.push("speedup");
    h
}

/// CSV header for `schema` (v2 appends the joint workgroup label).
pub fn csv_header_for(schema: Schema) -> Vec<&'static str> {
    let mut h = csv_header();
    if schema == Schema::V2 {
        h.push("best_wg_w");
        h.push("best_wg_h");
    }
    h
}

/// What a dataset file is stamped with: the simulated device it was
/// measured on (`None` for legacy files) and its schema (`V1` for
/// files written before schema stamping).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetTag {
    pub device: Option<String>,
    pub schema: Schema,
}

/// Persist records as CSV in the v1 (single-label) layout, stamped with
/// the simulated device they were measured on. Byte-identical to the
/// pre-schema-v2 writer for the same records.
pub fn save(records: &[TuneRecord], path: &Path, device: &str) -> Result<()> {
    save_schema(records, path, device, Schema::V1)
}

/// Persist records as CSV under `schema`. v2 files additionally carry a
/// `# schema=v2` metadata line next to the `# device=` stamp; v1 files
/// are written exactly as before (no schema line), so old readers keep
/// working.
pub fn save_schema(
    records: &[TuneRecord],
    path: &Path,
    device: &str,
    schema: Schema,
) -> Result<()> {
    let header = csv_header_for(schema);
    let mut meta = vec![(sink::DEVICE_META_KEY, device)];
    if schema == Schema::V2 {
        meta.push((sink::SCHEMA_META_KEY, schema.as_str()));
    }
    let mut w = csv::RowWriter::create_with_meta(path, &header, &meta)?;
    for r in records {
        w.write_row(&r.csv_row(schema))?;
    }
    w.finish()
}

pub fn load(path: &Path) -> Result<Vec<TuneRecord>> {
    Ok(load_tagged(path)?.0)
}

/// Load a dataset plus its stamp ([`DatasetTag`]). The schema comes
/// from the `# schema=` metadata line (absent = v1); the header width
/// must match the stamped schema, so a v2 file with its metadata
/// stripped is rejected instead of silently misparsed.
pub fn load_tagged(path: &Path) -> Result<(Vec<TuneRecord>, DatasetTag)> {
    let mut reader = csv::RowReader::open(path)?;
    let schema = sink::schema_from_meta(reader.meta())
        .with_context(|| path.display().to_string())?;
    anyhow::ensure!(
        reader.header().len() == schema.columns(),
        "{}: expected {} columns for schema {schema}, got {}",
        path.display(),
        schema.columns(),
        reader.header().len()
    );
    let device = reader.meta().get(sink::DEVICE_META_KEY).cloned();
    let mut out = Vec::new();
    let mut i = 0usize;
    while let Some(row) = reader.next_row()? {
        // from_csv_row re-validates the width, so short/ragged rows are
        // an Err whatever the reader's own invariants — never a
        // copy_from_slice panic.
        out.push(
            TuneRecord::from_csv_row(schema, format!("row{i}"), &row)
                .with_context(|| path.display().to_string())?,
        );
        i += 1;
    }
    Ok((out, DatasetTag { device, schema }))
}

/// Load a dataset from wherever it lives — a sharded directory (CSV or
/// binary, auto-detected), a CSV file, or a single binary shard file —
/// plus its tag and on-disk format. The `eval` CLI goes through this,
/// so any artifact `generate` can produce is evaluable.
pub fn load_any(path: &Path) -> Result<(Vec<TuneRecord>, DatasetTag, ShardFormat)> {
    if path.is_dir() {
        let (recs, stream) = sink::load_sharded_tagged(path)?;
        let tag = DatasetTag { device: stream.device, schema: stream.schema };
        return Ok((recs, tag, stream.format));
    }
    match binfmt::detect_format(path)? {
        ShardFormat::Csv => {
            let (recs, tag) = load_tagged(path)?;
            Ok((recs, tag, ShardFormat::Csv))
        }
        ShardFormat::Bin => {
            let mut r = binfmt::BinShardReader::open(path)?;
            let schema = r.schema();
            let device = Some(r.device().to_string());
            let mut out = Vec::new();
            let mut i = 0usize;
            while let Some(row) = r.next_row()? {
                out.push(
                    TuneRecord::from_csv_row(schema, format!("row{i}"), &row)
                        .with_context(|| path.display().to_string())?,
                );
                i += 1;
            }
            Ok((out, DatasetTag { device, schema }, ShardFormat::Bin))
        }
    }
}

/// Split records into train/test by random permutation (paper: train on
/// a random 10%, evaluate on the rest).
pub fn split<'a>(
    records: &'a [TuneRecord],
    train_fraction: f64,
    seed: u64,
) -> (Vec<&'a TuneRecord>, Vec<&'a TuneRecord>) {
    let mut idx: Vec<usize> = (0..records.len()).collect();
    let mut rng = Rng::new(seed);
    rng.shuffle(&mut idx);
    let n_train = ((records.len() as f64 * train_fraction).round() as usize)
        .clamp(1, records.len().saturating_sub(1).max(1));
    let train = idx[..n_train].iter().map(|&i| &records[i]).collect();
    let test = idx[n_train..].iter().map(|&i| &records[i]).collect();
    (train, test)
}

/// Summary used by reports: count, beneficial fraction, speedup range.
pub fn summarize(records: &[TuneRecord]) -> (usize, f64, f64, f64) {
    let n = records.len();
    let beneficial = records.iter().filter(|r| r.base.beneficial()).count() as f64
        / n.max(1) as f64;
    let speedups: Vec<f64> = records.iter().map(|r| r.base.speedup).collect();
    let geo = stats::geomean(&speedups);
    let max = speedups.iter().cloned().fold(0.0, f64::max);
    (n, beneficial, geo, max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::generator;

    fn small_setup() -> (Vec<Template>, LaunchSweep, DeviceSpec, BuildConfig) {
        let mut rng = Rng::new(1234);
        let templates = generator::generate_n(&mut rng, 2); // 2*7*16 kernels
        let sweep = LaunchSweep::new(2048, 2048);
        let dev = DeviceSpec::m2090();
        let cfg = BuildConfig {
            configs_per_kernel: 4,
            threads: 2,
            ..BuildConfig::default()
        };
        (templates, sweep, dev, cfg)
    }

    fn small_dataset() -> Vec<TuneRecord> {
        let (templates, sweep, dev, cfg) = small_setup();
        build(&templates, &sweep, &dev, &cfg)
    }

    #[test]
    fn build_produces_instances() {
        let recs = small_dataset();
        assert!(recs.len() > 500, "{} records", recs.len());
        for r in &recs {
            assert!(r.base.features.iter().all(|x| x.is_finite()));
            assert!(r.base.speedup > 0.0);
        }
    }

    #[test]
    fn dataset_contains_both_classes() {
        let recs = small_dataset();
        let pos = recs.iter().filter(|r| r.base.beneficial()).count();
        assert!(pos > 0, "no beneficial instances");
        assert!(pos < recs.len(), "every instance beneficial");
    }

    #[test]
    fn every_record_gets_a_valid_joint_label() {
        let recs = small_dataset();
        // Every record carries a label (each template measures at least
        // one feasible launch) and the label is a valid workgroup shape.
        let mut distinct = std::collections::HashSet::new();
        for r in &recs {
            let wg = r.best_wg.expect("joint label missing");
            assert!(wg.0.is_power_of_two() && wg.1.is_power_of_two(), "{wg:?}");
            assert!(wg.0 * wg.1 <= 1024, "{wg:?}");
            distinct.insert(wg);
        }
        // labels are not one degenerate constant across the dataset
        assert!(distinct.len() > 1, "all templates share one wg label");
    }

    #[test]
    fn parallel_build_equals_serial_reference() {
        let (templates, sweep, dev, cfg) = small_setup();
        let serial = build_serial(&templates, &sweep, &dev, &cfg);
        // several chunkings and thread counts, all bit-for-bit equal
        for (threads, chunk) in [(1, 3), (2, 0), (4, 7), (3, 1000)] {
            let c = BuildConfig { threads, chunk_templates: chunk, ..cfg.clone() };
            let par = build(&templates, &sweep, &dev, &c);
            assert_eq!(par.len(), serial.len(), "t={threads} c={chunk}");
            for (a, b) in par.iter().zip(&serial) {
                assert_eq!(a.base.features, b.base.features);
                assert_eq!(a.base.speedup, b.base.speedup);
                assert_eq!(a.base.name, b.base.name);
                assert_eq!(a.best_wg, b.best_wg);
            }
        }
    }

    #[test]
    fn streaming_summary_matches_batch_summarize() {
        let (templates, sweep, dev, cfg) = small_setup();
        let mut sink = MemorySink::new();
        let summary =
            build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, None)
                .unwrap();
        let (n, ben, geo, max) = summarize(&sink.records);
        assert_eq!(summary.records as usize, n);
        assert!((summary.beneficial_fraction() - ben).abs() < 1e-12);
        assert!((summary.geomean_speedup() - geo).abs() < 1e-9);
        assert_eq!(summary.max_speedup, max);
    }

    #[test]
    fn streaming_progress_reaches_total() {
        let (templates, sweep, dev, cfg) = small_setup();
        let mut sink = MemorySink::new();
        let mut last = None;
        let mut calls = 0usize;
        let mut cb = |p: &BuildProgress| {
            calls += 1;
            last = Some(*p);
        };
        build_streaming(&templates, &sweep, &dev, &cfg, &mut sink, Some(&mut cb))
            .unwrap();
        let last = last.unwrap();
        assert!(calls >= 1);
        assert_eq!(last.templates_done, templates.len());
        assert_eq!(last.templates_total, templates.len());
        assert_eq!(last.records as usize, sink.records.len());
        assert!(last.rows_per_second() > 0.0);
    }

    #[test]
    fn save_load_roundtrip_with_device_tag() {
        let recs = small_dataset();
        let path = std::env::temp_dir()
            .join(format!("lmtuner-ds-{}.csv", std::process::id()));
        save(&recs, &path, "m2090").unwrap();
        let (back, tag) = load_tagged(&path).unwrap();
        assert_eq!(tag.device.as_deref(), Some("m2090"));
        assert_eq!(tag.schema, Schema::V1);
        assert_eq!(back.len(), recs.len());
        for (a, b) in recs.iter().zip(&back) {
            assert_eq!(a.base.features, b.base.features);
            assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
            // v1 persistence drops the joint label by design
            assert_eq!(b.best_wg, None);
        }
        // plain load still works and untagged legacy files load as None
        assert_eq!(load(&path).unwrap().len(), recs.len());
        let body = std::fs::read_to_string(&path).unwrap();
        let untagged = std::env::temp_dir()
            .join(format!("lmtuner-ds-untagged-{}.csv", std::process::id()));
        std::fs::write(&untagged, body.replace("# device=m2090\n", "")).unwrap();
        let (_, tag) = load_tagged(&untagged).unwrap();
        assert_eq!(tag.device, None);
        assert_eq!(tag.schema, Schema::V1);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&untagged).ok();
    }

    #[test]
    fn v2_save_load_roundtrips_the_joint_label() {
        let recs = small_dataset();
        let path = std::env::temp_dir()
            .join(format!("lmtuner-ds-v2-{}.csv", std::process::id()));
        save_schema(&recs, &path, "m2090", Schema::V2).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("# device=m2090\n# schema=v2\n"));
        let (back, tag) = load_tagged(&path).unwrap();
        assert_eq!(tag.device.as_deref(), Some("m2090"));
        assert_eq!(tag.schema, Schema::V2);
        assert_eq!(back.len(), recs.len());
        for (a, b) in recs.iter().zip(&back) {
            assert_eq!(a.base.features, b.base.features);
            assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
            assert_eq!(a.best_wg, b.best_wg);
        }
        // a v2 file with its schema stamp stripped must be rejected
        // (21-column header under an implied-v1 read), not misparsed
        let stripped = std::env::temp_dir()
            .join(format!("lmtuner-ds-v2strip-{}.csv", std::process::id()));
        std::fs::write(&stripped, body.replace("# schema=v2\n", "")).unwrap();
        assert!(load(&stripped).is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&stripped).ok();
    }

    #[test]
    fn load_rejects_malformed_files_without_panicking() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();

        // Short data row under a correct header. Today the CSV layer
        // already rejects this (load's own per-row ensure is a second
        // line of defense against reader changes); either way the
        // contract under test is `Err`, never a copy_from_slice panic.
        let short_row = dir.join(format!("lmtuner-ds-short-{pid}.csv"));
        std::fs::write(
            &short_row,
            format!("{}\n1,2,3\n", csv_header().join(",")),
        )
        .unwrap();
        assert!(load(&short_row).is_err());
        std::fs::remove_file(&short_row).ok();

        // Header with too few columns.
        let short_header = dir.join(format!("lmtuner-ds-hdr-{pid}.csv"));
        std::fs::write(&short_header, "a,b\n1,2\n").unwrap();
        assert!(load(&short_header).is_err());
        std::fs::remove_file(&short_header).ok();

        // Non-numeric cell.
        let bad_cell = dir.join(format!("lmtuner-ds-bad-{pid}.csv"));
        let row: Vec<String> =
            (0..NUM_FEATURES + 1).map(|_| "oops".to_string()).collect();
        std::fs::write(
            &bad_cell,
            format!("{}\n{}\n", csv_header().join(","), row.join(",")),
        )
        .unwrap();
        assert!(load(&bad_cell).is_err());
        std::fs::remove_file(&bad_cell).ok();
    }

    #[test]
    fn split_fractions() {
        let recs = small_dataset();
        let (train, test) = split(&recs, 0.1, 99);
        assert_eq!(train.len() + test.len(), recs.len());
        let frac = train.len() as f64 / recs.len() as f64;
        assert!((frac - 0.1).abs() < 0.02, "train fraction {frac}");
    }

    #[test]
    fn build_is_deterministic() {
        let a = small_dataset();
        let b = small_dataset();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.base.speedup, y.base.speedup);
        }
    }

    #[test]
    fn multi_device_build_matches_per_device_builds() {
        let (templates, sweep, _, cfg) = small_setup();
        let devices = [DeviceSpec::m2090(), DeviceSpec::gtx480()];
        for threads in [1usize, 3] {
            let c = BuildConfig { threads, ..cfg.clone() };
            let mut sinks = vec![MemorySink::new(), MemorySink::new()];
            let summaries = build_multi_device(
                &templates,
                &sweep,
                &devices,
                &c,
                &mut sinks,
                None,
            )
            .unwrap();
            assert_eq!(summaries.len(), 2);
            for (dev, (sink, summary)) in
                devices.iter().zip(sinks.iter().zip(&summaries))
            {
                let reference = build(&templates, &sweep, dev, &c);
                assert_eq!(
                    sink.records.len(),
                    reference.len(),
                    "{} t={threads}",
                    dev.key
                );
                assert_eq!(summary.records as usize, reference.len());
                for (a, b) in sink.records.iter().zip(&reference) {
                    assert_eq!(a.base.features, b.base.features);
                    assert_eq!(a.base.speedup, b.base.speedup);
                    assert_eq!(a.best_wg, b.best_wg);
                }
            }
        }
    }

    #[test]
    fn multi_device_requires_matching_sinks() {
        let (templates, sweep, dev, cfg) = small_setup();
        let devices = [dev];
        let mut sinks: Vec<MemorySink> = vec![];
        assert!(build_multi_device(
            &templates,
            &sweep,
            &devices,
            &cfg,
            &mut sinks,
            None
        )
        .is_err());
    }

    #[test]
    fn load_any_handles_file_and_both_shard_formats() {
        let recs: Vec<TuneRecord> = small_dataset().into_iter().take(20).collect();
        let pid = std::process::id();

        // plain CSV file
        let f = std::env::temp_dir().join(format!("lmtuner-any-{pid}.csv"));
        save_schema(&recs, &f, "m2090", Schema::V2).unwrap();
        let (back, tag, format) = load_any(&f).unwrap();
        assert_eq!(format, ShardFormat::Csv);
        assert_eq!(tag.schema, Schema::V2);
        assert_eq!(back.len(), recs.len());
        std::fs::remove_file(&f).ok();

        for shard_format in [ShardFormat::Csv, ShardFormat::Bin] {
            let dir = std::env::temp_dir()
                .join(format!("lmtuner-any-{shard_format}-{pid}"));
            let mut s = sink::ShardedSink::create(
                &dir,
                3,
                "m2090",
                Schema::V2,
                shard_format,
            )
            .unwrap();
            for r in &recs {
                s.accept(r).unwrap();
            }
            s.finish().unwrap();
            let (back, tag, format) = load_any(&dir).unwrap();
            assert_eq!(format, shard_format);
            assert_eq!(tag.device.as_deref(), Some("m2090"));
            assert_eq!(back.len(), recs.len());
            for (a, b) in back.iter().zip(&recs) {
                // binary storage quantizes to f32; CSV is exact here
                assert!(
                    (a.base.speedup - b.base.speedup).abs() < 1e-4,
                    "{} vs {}",
                    a.base.speedup,
                    b.base.speedup
                );
                assert_eq!(a.best_wg, b.best_wg);
            }
            // a single binary shard file also loads directly
            if shard_format == ShardFormat::Bin {
                let one = sink::shard_path_for(&dir, 0, ShardFormat::Bin);
                let (part, tag, format) = load_any(&one).unwrap();
                assert_eq!(format, ShardFormat::Bin);
                assert_eq!(tag.device.as_deref(), Some("m2090"));
                assert_eq!(part.len(), (recs.len() + 2) / 3);
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn sharded_build_reloads_identically() {
        let (templates, sweep, dev, cfg) = small_setup();
        let reference = build(&templates, &sweep, &dev, &cfg);
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-ds-shards-{}", std::process::id()));
        let mut s = sink::ShardedCsvSink::create(&dir, 3, dev.key).unwrap();
        build_streaming(&templates, &sweep, &dev, &cfg, &mut s, None).unwrap();
        assert_eq!(s.written() as usize, reference.len());
        let back = sink::load_sharded(&dir).unwrap();
        assert_eq!(back.len(), reference.len());
        for (a, b) in back.iter().zip(&reference) {
            assert_eq!(a.base.features, b.base.features);
            assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
