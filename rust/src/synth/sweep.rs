//! Launch-configuration sweep (paper §5): all power-of-two 2D workgroup
//! geometries with <= 1024 workitems crossed with all power-of-two 2D
//! grids with >= 512 total workitems that tile the 2048x2048 output.
//!
//! The full cross product is large; `LaunchSweep::sampled` draws a
//! per-kernel random subset so dataset size can be scaled (the paper's
//! 5.6M instances / 9600 kernels ~ 583 configs per kernel).

use crate::kernelmodel::launch::{enumerate_grids, enumerate_wgs, Launch};
use crate::util::prng::Rng;

pub const MIN_GRID_TOTAL: u64 = 512;
pub const MAX_WG_THREADS: u32 = 1024;

/// Enumerate every valid launch for an out_w x out_h output.
pub fn full_sweep(out_w: u32, out_h: u32) -> Vec<Launch> {
    let mut out = Vec::new();
    for wg in enumerate_wgs(MAX_WG_THREADS) {
        for grid in enumerate_grids(wg, out_w, out_h, MIN_GRID_TOTAL) {
            out.push(Launch::new(wg, grid));
        }
    }
    out
}

/// A reusable sweep with per-kernel sampling.
pub struct LaunchSweep {
    all: Vec<Launch>,
    /// Launches grouped by workgroup shape, in ascending (w, h) order.
    /// Precomputed once: `sampled_balanced` runs once per template
    /// (11200 times at paper scale), so rebuilding the grouping per
    /// call was a measurable slice of dataset-build time.
    wg_buckets: Vec<Vec<Launch>>,
}

impl LaunchSweep {
    pub fn new(out_w: u32, out_h: u32) -> Self {
        let all = full_sweep(out_w, out_h);
        let mut by_wg: std::collections::BTreeMap<(u32, u32), Vec<Launch>> =
            std::collections::BTreeMap::new();
        for l in &all {
            by_wg.entry((l.wg.w, l.wg.h)).or_default().push(*l);
        }
        LaunchSweep { all, wg_buckets: by_wg.into_values().collect() }
    }

    pub fn len(&self) -> usize {
        self.all.len()
    }

    pub fn is_empty(&self) -> bool {
        self.all.is_empty()
    }

    pub fn all(&self) -> &[Launch] {
        &self.all
    }

    /// Draw `k` distinct launches (all of them if k >= len).
    pub fn sampled(&self, rng: &mut Rng, k: usize) -> Vec<Launch> {
        if k >= self.all.len() {
            return self.all.clone();
        }
        rng.sample_indices(self.all.len(), k)
            .into_iter()
            .map(|i| self.all[i])
            .collect()
    }

    /// Workgroup-balanced sample: `k` launches spread across distinct
    /// workgroup shapes first (so small samples still span the
    /// occupancy-relevant axis).
    ///
    /// Runs once per template (11200 times at paper scale), so it must
    /// not touch the whole sweep: instead of cloning and fully shuffling
    /// every bucket (the old implementation — O(sweep) clones + RNG
    /// draws per call) it first computes how many launches each bucket
    /// contributes, then draws exactly that many indices per bucket via
    /// the sparse partial Fisher–Yates (`Rng::sample_indices_sparse`).
    /// Total work is O(k + #buckets) per call. Deterministic for a fixed
    /// seed: same RNG state, same sample.
    pub fn sampled_balanced(&self, rng: &mut Rng, k: usize) -> Vec<Launch> {
        if k >= self.all.len() {
            return self.all.clone();
        }
        // Round-robin quota per bucket, in ascending (w, h) order: round
        // r takes one launch from every bucket still holding > r, until
        // k are assigned. Purely structural — no randomness involved.
        let mut take = vec![0usize; self.wg_buckets.len()];
        let mut assigned = 0usize;
        let mut round = 0usize;
        while assigned < k {
            let mut advanced = false;
            for (t, bucket) in take.iter_mut().zip(&self.wg_buckets) {
                if assigned >= k {
                    break;
                }
                if round < bucket.len() {
                    *t += 1;
                    assigned += 1;
                    advanced = true;
                }
            }
            if !advanced {
                break;
            }
            round += 1;
        }
        // Draw each bucket's quota without replacement, then interleave
        // by round so the output still alternates workgroup shapes.
        let picks: Vec<Vec<usize>> = self
            .wg_buckets
            .iter()
            .zip(&take)
            .map(|(bucket, &t)| rng.sample_indices_sparse(bucket.len(), t))
            .collect();
        let mut out = Vec::with_capacity(assigned);
        let mut r = 0usize;
        while out.len() < assigned {
            for (bucket, p) in self.wg_buckets.iter().zip(&picks) {
                if let Some(&i) = p.get(r) {
                    out.push(bucket[i]);
                }
            }
            r += 1;
        }
        out
    }
}

/// Joint-label derivation (schema v2): the workgroup shape of the
/// fastest measured launch. `timed` pairs each launch with its best
/// achieved time (min over baseline/optimized) — the same sweep the
/// speedup labels come from, so the joint label costs no second pass.
/// Non-finite times are skipped; ties break toward the smaller (w, h)
/// so the label is deterministic whatever order the sweep arrives in.
pub fn argmax_wg(timed: &[(Launch, f64)]) -> Option<(u32, u32)> {
    let mut best: Option<((u32, u32), f64)> = None;
    for (l, t) in timed {
        if !t.is_finite() {
            continue;
        }
        let wg = (l.wg.w, l.wg.h);
        let better = match best {
            None => true,
            Some((bwg, bt)) => *t < bt || (*t == bt && wg < bwg),
        };
        if better {
            best = Some((wg, *t));
        }
    }
    best.map(|(wg, _)| wg)
}

/// Check the paper's constraints hold for a launch (used by tests and
/// property checks).
pub fn satisfies_paper_constraints(l: &Launch, out_w: u32, out_h: u32) -> bool {
    let p2 = |x: u32| x.is_power_of_two();
    l.valid()
        && p2(l.wg.w)
        && p2(l.wg.h)
        && p2(l.grid.w)
        && p2(l.grid.h)
        && l.wg.size() <= MAX_WG_THREADS
        && l.grid.size() >= MIN_GRID_TOTAL
        && out_w % l.grid.w == 0
        && out_h % l.grid.h == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_sweep_respects_constraints() {
        let sweep = full_sweep(2048, 2048);
        assert!(sweep.len() > 500, "sweep size {}", sweep.len());
        for l in &sweep {
            assert!(satisfies_paper_constraints(l, 2048, 2048), "{l:?}");
        }
    }

    #[test]
    fn no_duplicate_launches() {
        let sweep = full_sweep(2048, 2048);
        let mut set = std::collections::HashSet::new();
        for l in &sweep {
            assert!(set.insert((l.wg.w, l.wg.h, l.grid.w, l.grid.h)));
        }
    }

    #[test]
    fn sampled_returns_distinct_subset() {
        let sweep = LaunchSweep::new(2048, 2048);
        let mut rng = Rng::new(11);
        let s = sweep.sampled(&mut rng, 50);
        assert_eq!(s.len(), 50);
        let mut set = std::collections::HashSet::new();
        for l in &s {
            assert!(set.insert((l.wg.w, l.wg.h, l.grid.w, l.grid.h)));
        }
    }

    #[test]
    fn sampled_all_when_k_large() {
        let sweep = LaunchSweep::new(2048, 2048);
        let mut rng = Rng::new(12);
        assert_eq!(sweep.sampled(&mut rng, usize::MAX).len(), sweep.len());
    }

    #[test]
    fn balanced_sample_spans_wg_shapes() {
        let sweep = LaunchSweep::new(2048, 2048);
        let mut rng = Rng::new(13);
        let s = sweep.sampled_balanced(&mut rng, 66);
        let wgs: std::collections::HashSet<(u32, u32)> =
            s.iter().map(|l| (l.wg.w, l.wg.h)).collect();
        // at least half the distinct workgroup shapes show up
        assert!(wgs.len() >= 30, "only {} wg shapes", wgs.len());
    }

    #[test]
    fn balanced_sample_is_exact_distinct_and_deterministic() {
        let sweep = LaunchSweep::new(2048, 2048);
        for k in [1usize, 13, 48, 200, sweep.len() - 1] {
            let a = sweep.sampled_balanced(&mut Rng::new(99), k);
            let b = sweep.sampled_balanced(&mut Rng::new(99), k);
            assert_eq!(a.len(), k);
            assert_eq!(a, b, "same seed must reproduce the sample (k={k})");
            let mut set = std::collections::HashSet::new();
            for l in &a {
                assert!(
                    set.insert((l.wg.w, l.wg.h, l.grid.w, l.grid.h)),
                    "duplicate launch in balanced sample (k={k})"
                );
            }
        }
        // different seeds draw different samples (overwhelmingly likely)
        let a = sweep.sampled_balanced(&mut Rng::new(1), 48);
        let b = sweep.sampled_balanced(&mut Rng::new(2), 48);
        assert_ne!(a, b);
    }

    #[test]
    fn argmax_wg_picks_fastest_with_deterministic_ties() {
        use crate::kernelmodel::launch::{GridGeom, WgGeom};
        let launch = |w, h| {
            Launch::new(WgGeom { w, h }, GridGeom { w: 1024, h: 1024 })
        };
        // fastest wins
        let timed = vec![
            (launch(32, 1), 3.0),
            (launch(16, 8), 1.0),
            (launch(8, 8), 2.0),
        ];
        assert_eq!(argmax_wg(&timed), Some((16, 8)));
        // ties break toward the smaller (w, h)
        let tied = vec![(launch(32, 2), 1.0), (launch(8, 8), 1.0)];
        assert_eq!(argmax_wg(&tied), Some((8, 8)));
        let tied_rev: Vec<_> = tied.iter().rev().cloned().collect();
        assert_eq!(argmax_wg(&tied_rev), Some((8, 8)));
        // non-finite times are skipped; all-invalid -> None
        let nan = vec![(launch(4, 4), f64::NAN), (launch(2, 2), 5.0)];
        assert_eq!(argmax_wg(&nan), Some((2, 2)));
        assert_eq!(argmax_wg(&[(launch(4, 4), f64::INFINITY)]), None);
        assert_eq!(argmax_wg(&[]), None);
    }

    #[test]
    fn balanced_sample_k_at_or_above_len_returns_all() {
        let sweep = LaunchSweep::new(2048, 2048);
        let mut rng = Rng::new(3);
        assert_eq!(sweep.sampled_balanced(&mut rng, sweep.len()).len(), sweep.len());
        assert_eq!(
            sweep.sampled_balanced(&mut rng, usize::MAX).len(),
            sweep.len()
        );
    }
}
