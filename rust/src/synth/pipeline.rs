//! Composable record-stream stages between generator and sink.
//!
//! The streaming dataset builder produces `TuneRecord`s and hands them
//! to a [`super::sink::RecordSink`]; a [`Stage`] sits in between and
//! decides, record by record, whether to keep, drop, or rewrite. Stages
//! compose into a [`StagedSink`] — itself a `RecordSink`, so any
//! existing consumer (`dataset::build_streaming`,
//! `coordinator::train::run_sharded`, a `Tee` fan-out) threads a
//! pipeline in without changing its own shape.
//!
//! Built-in stages:
//!
//! * [`Validate`] — drop structurally unsound records (non-finite
//!   features, non-positive or non-finite speedup, and under schema v2
//!   a missing or invalid workgroup label), with a typed per-reason
//!   reject count.
//! * [`Dedup`] — drop records whose quantized (f32) feature vector has
//!   been seen before. The fingerprint is over the 18 features only,
//!   not the measured speedup: two measurements of the same instance
//!   differ by timing noise, and that noise should not defeat
//!   deduplication. Quantizing to f32 first makes a record and its
//!   binary-shard round-trip (see `super::binfmt`) dedup identically.
//! * [`Transform`] — rewrite each record with a named closure.
//!
//! Every stage's traffic is tallied (seen/kept/dropped/replaced plus
//! the stage's own reject reasons) and surfaced as [`StageCounters`]
//! for progress output and `TrainOutcome`.

use std::collections::HashSet;
use std::fmt;

use anyhow::Result;

use crate::sim::exec::{Schema, TuneRecord};

use super::sink::RecordSink;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// What a stage decided about one record.
pub enum StageOut {
    /// Pass the record through unchanged.
    Keep(TuneRecord),
    /// Remove the record from the stream.
    Drop,
    /// Pass a rewritten record through.
    Replace(TuneRecord),
}

/// One record-stream filter/transformer. Stages run serially on the
/// consume side of the streaming build, in the order they were
/// composed, each seeing only what the previous stage let through.
pub trait Stage {
    /// Stable stage name for counters and progress output.
    fn name(&self) -> &str;
    fn process(&mut self, rec: TuneRecord) -> StageOut;
    /// Per-reason drop counts for stages that reject records for more
    /// than one reason (label, count). Labels are stable identifiers.
    fn rejects(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}

/// Drop records whose quantized feature vector was already seen.
#[derive(Default)]
pub struct Dedup {
    seen: HashSet<u64>,
    dropped: u64,
}

impl Dedup {
    pub fn new() -> Self {
        Self::default()
    }

    /// FNV-1a over the f32 bit patterns of the 18 features (speedup and
    /// label excluded — see the module docs).
    pub fn fingerprint(rec: &TuneRecord) -> u64 {
        let mut h = FNV_OFFSET;
        for &f in rec.base.features.iter() {
            for b in (f as f32).to_bits().to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
            }
        }
        h
    }
}

impl Stage for Dedup {
    fn name(&self) -> &str {
        "dedup"
    }

    fn process(&mut self, rec: TuneRecord) -> StageOut {
        if self.seen.insert(Self::fingerprint(&rec)) {
            StageOut::Keep(rec)
        } else {
            self.dropped += 1;
            StageOut::Drop
        }
    }

    fn rejects(&self) -> Vec<(&'static str, u64)> {
        vec![("duplicate", self.dropped)]
    }
}

/// Drop structurally unsound records with typed reject counts:
/// `non_finite` (a NaN/inf feature), `bad_speedup` (non-finite or
/// non-positive), and under schema v2 `missing_label` (no workgroup
/// label, or one that is not a power-of-two shape of <= 1024
/// workitems). v1 has no label plane, so `missing_label` never fires
/// there.
pub struct Validate {
    schema: Schema,
    non_finite: u64,
    bad_speedup: u64,
    missing_label: u64,
}

impl Validate {
    pub fn new(schema: Schema) -> Self {
        Validate { schema, non_finite: 0, bad_speedup: 0, missing_label: 0 }
    }
}

impl Stage for Validate {
    fn name(&self) -> &str {
        "validate"
    }

    fn process(&mut self, rec: TuneRecord) -> StageOut {
        if rec.base.features.iter().any(|x| !x.is_finite()) {
            self.non_finite += 1;
            return StageOut::Drop;
        }
        if !rec.base.speedup.is_finite() || rec.base.speedup <= 0.0 {
            self.bad_speedup += 1;
            return StageOut::Drop;
        }
        if self.schema == Schema::V2 {
            match rec.best_wg {
                Some((w, h))
                    if w.is_power_of_two()
                        && h.is_power_of_two()
                        && w as u64 * h as u64 <= 1024 => {}
                _ => {
                    self.missing_label += 1;
                    return StageOut::Drop;
                }
            }
        }
        StageOut::Keep(rec)
    }

    fn rejects(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("non_finite", self.non_finite),
            ("bad_speedup", self.bad_speedup),
            ("missing_label", self.missing_label),
        ]
    }
}

/// Rewrite every record with a named closure.
pub struct Transform<F: FnMut(TuneRecord) -> TuneRecord> {
    name: &'static str,
    f: F,
}

impl<F: FnMut(TuneRecord) -> TuneRecord> Transform<F> {
    pub fn new(name: &'static str, f: F) -> Self {
        Transform { name, f }
    }
}

impl<F: FnMut(TuneRecord) -> TuneRecord> Stage for Transform<F> {
    fn name(&self) -> &str {
        self.name
    }

    fn process(&mut self, rec: TuneRecord) -> StageOut {
        StageOut::Replace((self.f)(rec))
    }
}

/// Traffic through one stage of a [`StagedSink`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageCounters {
    pub name: String,
    /// Records that reached this stage.
    pub seen: u64,
    pub kept: u64,
    pub dropped: u64,
    pub replaced: u64,
    /// The stage's own per-reason drop counts.
    pub rejects: Vec<(&'static str, u64)>,
}

impl fmt::Display for StageCounters {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: seen {}, kept {}, dropped {}",
            self.name,
            self.seen,
            self.kept + self.replaced,
            self.dropped
        )?;
        let nonzero: Vec<String> = self
            .rejects
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(k, n)| format!("{k} {n}"))
            .collect();
        if !nonzero.is_empty() {
            write!(f, " ({})", nonzero.join(", "))?;
        }
        Ok(())
    }
}

#[derive(Clone, Copy, Default)]
struct Tally {
    seen: u64,
    kept: u64,
    dropped: u64,
    replaced: u64,
}

/// A `RecordSink` adapter running every record through a stage chain
/// before the inner sink sees it. With no stages it forwards without
/// cloning, so wrapping is free for the plain path.
pub struct StagedSink<S: RecordSink> {
    inner: S,
    stages: Vec<Box<dyn Stage>>,
    tallies: Vec<Tally>,
}

impl<S: RecordSink> StagedSink<S> {
    pub fn new(inner: S, stages: Vec<Box<dyn Stage>>) -> Self {
        let tallies = vec![Tally::default(); stages.len()];
        StagedSink { inner, stages, tallies }
    }

    pub fn inner(&self) -> &S {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut S {
        &mut self.inner
    }

    pub fn into_inner(self) -> S {
        self.inner
    }

    /// Per-stage traffic counters, in stage order.
    pub fn counters(&self) -> Vec<StageCounters> {
        self.stages
            .iter()
            .zip(&self.tallies)
            .map(|(stage, t)| StageCounters {
                name: stage.name().to_string(),
                seen: t.seen,
                kept: t.kept,
                dropped: t.dropped,
                replaced: t.replaced,
                rejects: stage.rejects(),
            })
            .collect()
    }
}

impl<S: RecordSink> RecordSink for StagedSink<S> {
    fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
        if self.stages.is_empty() {
            return self.inner.accept(rec);
        }
        let mut cur = rec.clone();
        for (stage, tally) in self.stages.iter_mut().zip(self.tallies.iter_mut()) {
            tally.seen += 1;
            match stage.process(cur) {
                StageOut::Keep(r) => {
                    tally.kept += 1;
                    cur = r;
                }
                StageOut::Replace(r) => {
                    tally.replaced += 1;
                    cur = r;
                }
                StageOut::Drop => {
                    tally.dropped += 1;
                    return Ok(());
                }
            }
        }
        self.inner.accept(&cur)
    }

    fn finish(&mut self) -> Result<()> {
        self.inner.finish()
    }
}

/// Which built-in stages a run wants — the flag-level view
/// (`--validate` / `--dedup`) shared by the CLI and
/// `ShardedTrainConfig`. Validation runs before deduplication so a
/// malformed record never claims a fingerprint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PipelineSpec {
    pub validate: bool,
    pub dedup: bool,
}

impl PipelineSpec {
    pub fn is_empty(&self) -> bool {
        !self.validate && !self.dedup
    }

    /// Materialize the stage chain for a dataset of the given schema.
    pub fn build(&self, schema: Schema) -> Vec<Box<dyn Stage>> {
        let mut stages: Vec<Box<dyn Stage>> = Vec::new();
        if self.validate {
            stages.push(Box::new(Validate::new(schema)));
        }
        if self.dedup {
            stages.push(Box::new(Dedup::new()));
        }
        stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;
    use crate::sim::exec::SpeedupRecord;
    use crate::synth::sink::MemorySink;

    fn rec(i: u64) -> TuneRecord {
        let mut features = [0.0; NUM_FEATURES];
        features[0] = i as f64;
        TuneRecord {
            base: SpeedupRecord {
                name: format!("r{i}"),
                features,
                speedup: 0.5 + (i % 4) as f64,
                baseline_time: 1.0,
                optimized_time: 1.0,
            },
            best_wg: Some((1 << (i % 5), 1 << (i % 3))),
        }
    }

    #[test]
    fn dedup_drops_repeats_and_counts_them() {
        let mut sink = StagedSink::new(
            MemorySink::new(),
            vec![Box::new(Dedup::new()) as Box<dyn Stage>],
        );
        for i in 0..10 {
            sink.accept(&rec(i)).unwrap();
            sink.accept(&rec(i)).unwrap(); // exact duplicate
        }
        sink.finish().unwrap();
        assert_eq!(sink.inner().records.len(), 10);
        let c = &sink.counters()[0];
        assert_eq!(c.name, "dedup");
        assert_eq!(c.seen, 20);
        assert_eq!(c.kept, 10);
        assert_eq!(c.dropped, 10);
        assert_eq!(c.rejects, vec![("duplicate", 10)]);
    }

    #[test]
    fn dedup_ignores_speedup_but_not_features() {
        let mut d = Dedup::new();
        let a = rec(1);
        let mut b = rec(1);
        b.base.speedup = 99.0; // same instance, noisier measurement
        assert!(matches!(d.process(a), StageOut::Keep(_)));
        assert!(matches!(d.process(b), StageOut::Drop));
        let mut c = rec(1);
        c.base.features[3] = 7.0;
        assert!(matches!(d.process(c), StageOut::Keep(_)));
    }

    #[test]
    fn dedup_fingerprint_survives_f32_quantization() {
        let mut a = rec(2);
        a.base.features[1] = 0.1; // not f32-exact
        let mut b = a.clone();
        b.base.features[1] = 0.1f32 as f64; // its f32 round-trip
        assert_eq!(Dedup::fingerprint(&a), Dedup::fingerprint(&b));
    }

    #[test]
    fn validate_rejects_with_typed_counts() {
        let mut v = Validate::new(Schema::V2);
        assert!(matches!(v.process(rec(0)), StageOut::Keep(_)));
        let mut nan = rec(1);
        nan.base.features[5] = f64::NAN;
        assert!(matches!(v.process(nan), StageOut::Drop));
        let mut inf = rec(2);
        inf.base.speedup = f64::INFINITY;
        assert!(matches!(v.process(inf), StageOut::Drop));
        let mut neg = rec(3);
        neg.base.speedup = 0.0;
        assert!(matches!(v.process(neg), StageOut::Drop));
        let mut unlabeled = rec(4);
        unlabeled.best_wg = None;
        assert!(matches!(v.process(unlabeled), StageOut::Drop));
        let mut huge = rec(5);
        huge.best_wg = Some((64, 64)); // 4096 workitems
        assert!(matches!(v.process(huge), StageOut::Drop));
        assert_eq!(
            v.rejects(),
            vec![("non_finite", 1), ("bad_speedup", 2), ("missing_label", 2)]
        );
    }

    #[test]
    fn validate_v1_ignores_the_label_plane() {
        let mut v = Validate::new(Schema::V1);
        let mut unlabeled = rec(0);
        unlabeled.best_wg = None;
        assert!(matches!(v.process(unlabeled), StageOut::Keep(_)));
        assert_eq!(v.rejects()[2], ("missing_label", 0));
    }

    #[test]
    fn transform_replaces_and_is_counted() {
        let double = Transform::new("double-speedup", |mut r: TuneRecord| {
            r.base.speedup *= 2.0;
            r
        });
        let mut sink =
            StagedSink::new(MemorySink::new(), vec![Box::new(double) as Box<dyn Stage>]);
        for i in 0..5 {
            sink.accept(&rec(i)).unwrap();
        }
        assert_eq!(sink.inner().records.len(), 5);
        for (i, r) in sink.inner().records.iter().enumerate() {
            assert_eq!(r.base.speedup, rec(i as u64).base.speedup * 2.0);
        }
        let c = &sink.counters()[0];
        assert_eq!(c.name, "double-speedup");
        assert_eq!(c.replaced, 5);
        assert_eq!(c.kept, 0);
        assert_eq!(c.to_string(), "double-speedup: seen 5, kept 5, dropped 0");
    }

    #[test]
    fn stages_chain_in_order_and_later_stages_see_filtered_stream() {
        // validate drops the NaN record before dedup ever sees it
        let spec = PipelineSpec { validate: true, dedup: true };
        let mut sink = StagedSink::new(MemorySink::new(), spec.build(Schema::V2));
        let mut nan = rec(0);
        nan.base.features[0] = f64::NAN;
        sink.accept(&nan).unwrap();
        sink.accept(&rec(1)).unwrap();
        sink.accept(&rec(1)).unwrap();
        let c = sink.counters();
        assert_eq!(c[0].name, "validate");
        assert_eq!(c[1].name, "dedup");
        assert_eq!(c[0].seen, 3);
        assert_eq!(c[0].dropped, 1);
        assert_eq!(c[1].seen, 2, "dedup must not see the invalid record");
        assert_eq!(c[1].dropped, 1);
        assert_eq!(sink.inner().records.len(), 1);
    }

    #[test]
    fn empty_pipeline_is_passthrough() {
        let spec = PipelineSpec::default();
        assert!(spec.is_empty());
        let mut sink = StagedSink::new(MemorySink::new(), spec.build(Schema::V1));
        for i in 0..4 {
            sink.accept(&rec(i)).unwrap();
        }
        assert!(sink.counters().is_empty());
        assert_eq!(sink.into_inner().records.len(), 4);
    }

    #[test]
    fn counters_display_lists_nonzero_rejects() {
        let mut v = Validate::new(Schema::V2);
        let mut nan = rec(0);
        nan.base.features[0] = f64::NAN;
        let _ = v.process(nan);
        let _ = v.process(rec(1));
        let mut sink = StagedSink::new(
            MemorySink::new(),
            vec![Box::new(Dedup::new()) as Box<dyn Stage>],
        );
        sink.accept(&rec(0)).unwrap();
        sink.accept(&rec(0)).unwrap();
        let shown = sink.counters()[0].to_string();
        assert_eq!(shown, "dedup: seen 2, kept 1, dropped 1 (duplicate 1)");
    }
}
