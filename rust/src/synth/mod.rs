//! Synthetic benchmark generation (paper §4.1/§5): Table 2 sampling,
//! template enumeration, launch sweep, dataset building.
//!
//! The dataset layer has two build paths sharing one deterministic
//! record order: [`dataset::build_serial`] (the reference) and
//! [`dataset::build_streaming`], which fans template work across the
//! thread pool in chunks and streams every record to a
//! [`sink::RecordSink`] — in-memory, sharded-on-disk (line-oriented CSV
//! or the binary columnar format of [`binfmt`]), or a reservoir
//! sample — so paper-scale datasets never have to fit in memory.
//! [`pipeline`] provides composable per-record stages (validate, dedup,
//! transform) that slot between the generator and any sink, and
//! [`dataset::build_multi_device`] measures every template on several
//! devices in one generation pass. See `EXPERIMENTS.md` at the
//! repository root for how the generated population relates to the
//! paper's reported counts.
pub mod binfmt;
pub mod dataset;
pub mod generator;
pub mod pipeline;
pub mod sampler;
pub mod sink;
pub mod sweep;
