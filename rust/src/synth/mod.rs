//! Synthetic benchmark generation (paper §4.1/§5): Table 2 sampling,
//! template enumeration, launch sweep, dataset building.
pub mod dataset;
pub mod generator;
pub mod sampler;
pub mod sweep;
