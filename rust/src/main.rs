//! lmtuner CLI — the L3 coordinator entrypoint.
//!
//! Subcommands:
//!   generate   build the synthetic kernel-instance dataset (CSV)
//!   train      phase-1 pipeline: generate + simulate + fit + evaluate
//!   tune       k-fold CV over the forest hyperparameter grid (ml::select)
//!   crossdev   train-on-A/test-on-B accuracy matrix over the portfolio
//!   eval       evaluate a saved model on a dataset / the real benchmarks
//!   analyze    extract descriptor + 18 features from an OpenCL C kernel
//!   lint       semantic checks + staging certificates (exit 2 on deny)
//!   predict    one-off decision for a feature vector
//!   serve      start the batched PJRT prediction service (demo load)
//!   reproduce  regenerate paper figures/tables: fig1, fig6, table1-3
//!   info       device + artifact status
//!
//! `--device <key>` selects the simulated testbed wherever one is
//! involved (see `lmtuner info` for the registered portfolio).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use lmtuner::coordinator::crossdev;
use lmtuner::coordinator::service::{Service, ServiceConfig};
use lmtuner::coordinator::train::{self, TrainConfig};
use lmtuner::frontend::{self, AnalyzeOptions, Bindings, SemaOptions, Severity};
use lmtuner::gpu::registry;
use lmtuner::gpu::spec::DeviceSpec;
use lmtuner::kernelmodel::features::{self, FEATURE_NAMES, NUM_FEATURES};
use lmtuner::kernelmodel::launch::{GridGeom, Launch, WgGeom};
use lmtuner::ml::{io as model_io, metrics, select};
use lmtuner::obs::metrics::MetricsRegistry;
use lmtuner::report::{figures, tables};
use lmtuner::runtime::executor::BatchExecutor;
use lmtuner::runtime::fastexec::FlatForestExecutor;
use lmtuner::runtime::pjrt::Engine;
use lmtuner::sim::exec::{MeasureConfig, Schema, SpeedupRecord};
use lmtuner::synth::binfmt::ShardFormat;
use lmtuner::synth::dataset;
use lmtuner::synth::pipeline::{PipelineSpec, StageCounters, StagedSink};
use lmtuner::synth::sink::{self as shard_sink, ShardedSink};
use lmtuner::util::cli::Args;
use lmtuner::util::prng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Exit codes beyond the generic failure (1), so scripts and CI can tell
/// a broken invocation from a kernel that failed a check (DESIGN.md §2h):
/// `lint` found deny-set diagnostics.
const EXIT_LINT_FINDINGS: i32 = 2;
/// `analyze` refused to synthesize features past Deny diagnostics.
const EXIT_ANALYZE_REFUSED: i32 = 3;

/// Exit with an explicit code, flushing both streams first: they are
/// block-buffered when piped (as in CI), and `std::process::exit` skips
/// the normal end-of-main flush.
fn exit_with(code: i32) -> ! {
    use std::io::Write;
    let _ = std::io::stdout().flush();
    let _ = std::io::stderr().flush();
    std::process::exit(code);
}

fn usage() -> &'static str {
    "lmtuner <generate|train|tune|crossdev|eval|shards|analyze|lint|predict|serve|reproduce|info> [options]\n\
     \n\
     generate  --out data/synth.csv [--device m2090] [--scale 0.2]\n\
               [--configs 24] [--seed N] [--schema v1|v2]\n\
               [--shards N --out-dir data/shards] [--format csv|bin]\n\
               [--dedup] [--validate] [--devices m2090,k20,...]\n\
               (--shards streams shards to --out-dir; --format defaults\n\
                to the binary columnar format there, csv for --out;\n\
                --dedup/--validate insert pipeline stages; --devices\n\
                measures every device in ONE pass, sharding each stream\n\
                to --out-dir/<key>/; --schema v2 adds the measured-best\n\
                workgroup label per instance)\n\
     train     --model models/rf.txt [--device m2090] [--data data/synth.csv]\n\
               [--scale 0.2] [--configs 24] [--trees 20] [--mtry 4]\n\
               [--min-leaf 1] [--engine binned|exact] [--train-frac 0.1]\n\
               [--forest-config models/forest-config.txt] [--oob]\n\
               [--schema v1|v2]\n\
               [--shards N --out-dir data/shards --train-cap 50000]\n\
               [--format csv|bin] [--dedup] [--validate]\n\
               (--shards streams the dataset to disk: bounded memory at\n\
                any --scale; the forest fits on a reservoir sample;\n\
                --format bin writes binary columnar shards (default csv:\n\
                exact f64 speedups); --dedup/--validate filter the\n\
                stream before it reaches disk + reservoir;\n\
                --forest-config loads a `lmtuner tune` winner, explicit\n\
                flags still override it; --schema v2 trains the joint\n\
                verdict x workgroup-size forest and reports the joint\n\
                metric)\n\
     tune      [--out data/tune.csv] [--best models/forest-config.txt]\n\
               [--device m2090] [--scale 0.05] [--configs 8] [--seed N]\n\
               [--trees 10,20,40] [--mtry 2,4,8] [--min-leaf 1,4]\n\
               [--folds 5] [--threads N] [--engine binned|exact] [--no-noise]\n\
               (deterministic k-fold CV over the grid: per-config CSV ->\n\
                --out, best config -> --best for --forest-config)\n\
     crossdev  [--devices m2090,gtx480,gtx680,k20] [--out data/crossdev.csv]\n\
               [--scale 0.05] [--configs 8] [--train-frac 0.1] [--seed N]\n\
               [--forest-config models/forest-config.txt] [--schema v1|v2]\n\
               [--dump-dir DIR [--dump-shards N] [--format csv|bin]]\n\
               (train-on-A/test-on-B accuracy matrix over the portfolio;\n\
                --dump-dir also shards every device's dataset under\n\
                DIR/<key>/ in the one generation pass; --schema v2\n\
                additionally grades the joint verdict x workgroup\n\
                metric per cell)\n\
     eval      --model models/rf.txt [--data data/synth.csv] [--real]\n\
               [--device KEY]  (--data takes a CSV file, a binary shard,\n\
                or a shard directory in either format; the stamped device\n\
                must match --device, the model's output arity the schema)\n\
     shards    <dir>  (inspect a shard directory: per-shard format,\n\
                device, schema, rows, checksum; nonzero exit on corrupt\n\
                or incoherent shards)\n\
     analyze   <kernel.cl> --array NAME [--kernel NAME] [--device m2090]\n\
               [--wg 16x16] [--grid 512x512] [--set w=512,radius=2,...]\n\
               [--model models/rf.txt]\n\
               (parse OpenCL C, extract the descriptor + 18 features for\n\
                the given launch; --set binds scalar kernel arguments;\n\
                --model additionally prints the use-local-memory verdict,\n\
                plus a suggested workgroup size for joint v2 models;\n\
                refuses with exit 3 on deny-level lint diagnostics)\n\
     lint      <kernel.cl> [--json] [--deny warn] [--kernel NAME]\n\
               [--device m2090] [--wg 16x16] [--grid 512x512]\n\
               [--set w=512,...]\n\
               (semantic analysis over the kernel AST: barrier-divergence\n\
                and affine-bounds checks (deny), bank-conflict and\n\
                uncoalesced-access lints (warn), plus a staging-safety\n\
                certificate per __global array; exits 2 when the deny\n\
                set is non-empty — --deny warn promotes warnings into it;\n\
                --json emits the machine-readable report)\n\
     predict   --model models/rf.txt --features f1,...,f18 [--artifacts DIR]\n\
     serve     --model models/rf.txt [--device m2090]\n\
               [--backend auto|native|pjrt] [--artifacts artifacts]\n\
               [--requests N] [--batch 4096] [--wait-us 200] [--workers 1]\n\
     reproduce --figure fig1|fig6|table1|table2|table3|all [--scale 0.2]\n\
               [--device m2090]\n\
     info      [--artifacts artifacts]  (lists the device portfolio)\n\
     \n\
     generate/train/crossdev/serve/analyze also take --metrics-out FILE\n\
     (telemetry counters, gauges, and latency histograms as JSON) and\n\
     --trace-out FILE (line-delimited span events; also prints the\n\
     wall-time attribution tree on exit)"
}

/// Resolve `--device` against the registry (default: the paper's M2090).
fn device_arg(args: &mut Args) -> Result<DeviceSpec> {
    match args.opt_str("device") {
        Some(key) => registry::get(&key),
        None => Ok(registry::default_device()),
    }
}

/// `--metrics-out FILE` / `--trace-out FILE`, shared by the telemetry-
/// wired subcommands (generate/train/crossdev/serve/analyze).
struct Telemetry {
    metrics_out: Option<PathBuf>,
    tracing: bool,
}

/// Parse the telemetry flags BEFORE the command does real work:
/// `--trace-out` enables the global tracer, so every span recorded
/// downstream streams into the JSONL sink.
fn telemetry_args(args: &mut Args) -> Result<Telemetry> {
    let metrics_out = args.opt_str("metrics-out").map(PathBuf::from);
    let tracing = match args.opt_str("trace-out") {
        Some(path) => {
            let path = PathBuf::from(path);
            if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                std::fs::create_dir_all(dir)?;
            }
            lmtuner::obs::trace::global()
                .set_sink(&path)
                .with_context(|| format!("opening --trace-out {}", path.display()))?;
            println!("tracing span events to {}", path.display());
            true
        }
        None => false,
    };
    Ok(Telemetry { metrics_out, tracing })
}

impl Telemetry {
    /// Write `metrics.json` (when asked), flush the trace sink, and
    /// print the wall-time attribution tree (when tracing).
    fn finish(&self, reg: &MetricsRegistry) -> Result<()> {
        if let Some(path) = &self.metrics_out {
            if let Some(dir) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
                std::fs::create_dir_all(dir)?;
            }
            reg.write(path)
                .with_context(|| format!("writing --metrics-out {}", path.display()))?;
            println!("metrics written to {}", path.display());
        }
        if self.tracing {
            let tr = lmtuner::obs::trace::global();
            tr.flush()?;
            print!("{}", tr.render_tree());
        }
        Ok(())
    }
}

fn run() -> Result<()> {
    let mut args = Args::parse_env().map_err(|e| anyhow::anyhow!(e))?;
    let cmd = args.subcommand().map(str::to_string);
    match cmd.as_deref() {
        Some("generate") => cmd_generate(&mut args),
        Some("train") => cmd_train(&mut args),
        Some("tune") => cmd_tune(&mut args),
        Some("crossdev") => cmd_crossdev(&mut args),
        Some("eval") => cmd_eval(&mut args),
        Some("shards") => cmd_shards(&mut args),
        Some("analyze") => cmd_analyze(&mut args),
        Some("lint") => cmd_lint(&mut args),
        Some("predict") => cmd_predict(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("reproduce") => cmd_reproduce(&mut args),
        Some("info") => cmd_info(&mut args),
        _ => {
            println!("{}", usage());
            Ok(())
        }
    }
}

/// The skip-and-count guard's user-facing surface (ml::metrics): say
/// when evaluation instances were dropped instead of reporting accuracy
/// as if every row was covered.
fn warn_skipped(skipped: usize) {
    if skipped > 0 {
        eprintln!(
            "warning: {skipped} evaluation instance(s) skipped — non-finite \
             or <= 0 speedups carry no oracle label (see ml::metrics)"
        );
    }
}

/// Apply `--forest-config` (a `lmtuner tune` winner) and the explicit
/// forest flags to `cfg.forest`, explicit flags winning.
fn apply_forest_args(
    args: &mut Args,
    forest: &mut lmtuner::ml::forest::ForestConfig,
) -> Result<()> {
    if let Some(path) = args.opt_str("forest-config") {
        let loaded = select::load_forest_config(Path::new(&path))?;
        forest.num_trees = loaded.num_trees;
        forest.tree = loaded.tree;
        println!(
            "forest config from {path}: trees={} mtry={} min_leaf={} \
             max_depth={} engine={} bins={}",
            loaded.num_trees,
            loaded.tree.mtry,
            loaded.tree.min_samples_leaf,
            loaded.tree.max_depth,
            loaded.tree.engine,
            loaded.tree.max_bins
        );
    }
    if let Some(trees) = args.get::<usize>("trees").map_err(anyhow::Error::msg)? {
        forest.num_trees = trees;
    }
    if let Some(mtry) = args.get::<usize>("mtry").map_err(anyhow::Error::msg)? {
        forest.tree.mtry = mtry;
    }
    if let Some(min_leaf) = args.get::<usize>("min-leaf").map_err(anyhow::Error::msg)? {
        forest.tree.min_samples_leaf = min_leaf;
    }
    if let Some(engine) = args.opt_str("engine") {
        forest.tree.engine = engine.parse().map_err(anyhow::Error::msg)?;
    }
    Ok(())
}

fn train_config(args: &mut Args) -> Result<TrainConfig> {
    let mut cfg = TrainConfig {
        scale: args.get_or("scale", 0.2).map_err(anyhow::Error::msg)?,
        configs_per_kernel: args.get_or("configs", 24).map_err(anyhow::Error::msg)?,
        train_fraction: args.get_or("train-frac", 0.10).map_err(anyhow::Error::msg)?,
        seed: args.get_or("seed", 0x5EEDu64).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    apply_forest_args(args, &mut cfg.forest)?;
    cfg.compute_oob = args.flag("oob");
    if args.flag("no-noise") {
        cfg.measure = MeasureConfig::deterministic();
    }
    if let Some(s) = args.opt_str("schema") {
        cfg.schema = s.parse().map_err(anyhow::Error::msg)?;
    }
    Ok(cfg)
}

/// `--format csv|bin` with a per-command default.
fn format_arg(args: &mut Args, default: ShardFormat) -> Result<ShardFormat> {
    match args.opt_str("format") {
        Some(s) => s.parse().map_err(anyhow::Error::msg),
        None => Ok(default),
    }
}

/// `--dedup` / `--validate` select the per-record pipeline stages.
fn pipeline_args(args: &mut Args) -> PipelineSpec {
    PipelineSpec {
        validate: args.flag("validate"),
        dedup: args.flag("dedup"),
    }
}

fn print_stage_counters(counters: &[StageCounters]) {
    for c in counters {
        println!("stage {c}");
    }
}

/// Progress callback printing build throughput to stderr at most every
/// two seconds (and on the final chunk).
fn progress_printer() -> impl FnMut(&lmtuner::synth::dataset::BuildProgress) {
    let mut last = std::time::Instant::now();
    move |p| {
        let done = p.templates_done == p.templates_total;
        if last.elapsed().as_secs_f64() >= 2.0 || done {
            last = std::time::Instant::now();
            eprintln!(
                "  [{}/{} templates] {} records, {:.0} rows/s, {:.0}s elapsed",
                p.templates_done,
                p.templates_total,
                p.records,
                p.rows_per_second(),
                p.elapsed_seconds
            );
        }
    }
}

fn cmd_generate(args: &mut Args) -> Result<()> {
    let devices_arg = args.str_or("devices", "");
    let dev = &device_arg(args)?;
    let out_explicit = args.opt_str("out");
    let out = PathBuf::from(out_explicit.as_deref().unwrap_or("data/synth.csv"));
    let shards: Option<usize> = args.get("shards").map_err(anyhow::Error::msg)?;
    let out_dir_explicit = args.opt_str("out-dir");
    let out_dir =
        PathBuf::from(out_dir_explicit.as_deref().unwrap_or("data/shards"));
    // Sharded generation defaults to the binary columnar format — at
    // paper scale the CSV encode/parse cost dominates the pass.
    let format_explicit = args.opt_str("format");
    let format = match format_explicit.as_deref() {
        Some(s) => s.parse().map_err(anyhow::Error::msg)?,
        None => ShardFormat::Bin,
    };
    let stages = pipeline_args(args);
    let cfg = train_config(args)?;
    let tel = telemetry_args(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    if shards.is_some() && out_explicit.is_some() {
        bail!(
            "--out conflicts with --shards (sharded output goes to \
             --out-dir, currently {})",
            out_dir.display()
        );
    }
    if shards.is_none() && out_dir_explicit.is_some() {
        bail!("--out-dir requires --shards N (single-file output uses --out)");
    }
    if shards.is_none() && format_explicit.is_some() {
        bail!("--format requires --shards N (single-file --out is always CSV)");
    }
    if !devices_arg.is_empty() && shards.is_none() {
        bail!("--devices requires --shards N (one shard dir per device)");
    }

    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let templates = lmtuner::synth::generator::generate(&mut rng, cfg.scale);
    let sweep = lmtuner::synth::sweep::LaunchSweep::new(2048, 2048);
    let build = train::build_config(&cfg);
    let mut progress = progress_printer();

    if !devices_arg.is_empty() {
        // Multi-device: measure every template on every device in one
        // pass, each stream staged + sharded under out_dir/<key>/.
        let devices = devices_arg
            .split(',')
            .map(registry::get)
            .collect::<Result<Vec<_>>>()?;
        let shards = shards.unwrap();
        println!(
            "devices: [{}]; schema: {}; format: {format}",
            devices.iter().map(|d| d.key).collect::<Vec<_>>().join(", "),
            cfg.schema
        );
        let mut sinks: Vec<StagedSink<ShardedSink>> = Vec::new();
        for d in &devices {
            sinks.push(StagedSink::new(
                ShardedSink::create(
                    &out_dir.join(d.key),
                    shards,
                    d.key,
                    cfg.schema,
                    format,
                )?,
                stages.build(cfg.schema),
            ));
        }
        let summaries = dataset::build_multi_device(
            &templates,
            &sweep,
            &devices,
            &build,
            &mut sinks,
            Some(&mut progress),
        )?;
        let mut reg = MetricsRegistry::new();
        for ((d, sink), summary) in devices.iter().zip(&sinks).zip(&summaries) {
            println!(
                "{}: wrote {} instances to {} ({} shards); beneficial \
                 {:.1}%, geomean {:.2}x",
                d.key,
                sink.inner().written(),
                out_dir.join(d.key).display(),
                shards,
                100.0 * summary.beneficial_fraction(),
                summary.geomean_speedup()
            );
            print_stage_counters(&sink.counters());
            reg.add("generate.records", summary.records);
            reg.add(&format!("generate.{}.records", d.key), summary.records);
            train::export_stages(&sink.counters(), &mut reg);
        }
        reg.set_gauge("generate.elapsed_s", t0.elapsed().as_secs_f64());
        tel.finish(&reg)?;
        return Ok(());
    }

    println!("device: {} ({}); schema: {}", dev.name, dev.key, cfg.schema);
    let (summary, counters) = if let Some(shards) = shards {
        // Streamed, sharded build: bounded memory at any scale.
        let sink =
            ShardedSink::create(&out_dir, shards, dev.key, cfg.schema, format)?;
        let mut staged = StagedSink::new(sink, stages.build(cfg.schema));
        let summary = dataset::build_streaming(
            &templates, &sweep, dev, &build, &mut staged, Some(&mut progress),
        )?;
        let sink = staged.inner();
        println!(
            "wrote {} instances to {} ({} shards, format {}, device {}, schema {})",
            sink.written(),
            out_dir.display(),
            sink.shards(),
            sink.format(),
            sink.device(),
            sink.schema()
        );
        print_stage_counters(&staged.counters());
        (summary, staged.counters())
    } else {
        let sink = lmtuner::synth::sink::MemorySink::new();
        let mut staged = StagedSink::new(sink, stages.build(cfg.schema));
        let summary = dataset::build_streaming(
            &templates, &sweep, dev, &build, &mut staged, Some(&mut progress),
        )?;
        if let Some(dir) = out.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let records = &staged.inner().records;
        dataset::save_schema(records, &out, dev.key, cfg.schema)?;
        println!("wrote {} instances to {}", records.len(), out.display());
        print_stage_counters(&staged.counters());
        (summary, staged.counters())
    };
    println!(
        "beneficial {:.1}%, geomean {:.2}x, max {:.1}x",
        100.0 * summary.beneficial_fraction(),
        summary.geomean_speedup(),
        summary.max_speedup
    );
    let mut reg = MetricsRegistry::new();
    reg.add("generate.records", summary.records);
    reg.set_gauge("generate.elapsed_s", t0.elapsed().as_secs_f64());
    reg.set_gauge("generate.beneficial_frac", summary.beneficial_fraction());
    reg.set_gauge("generate.geomean_speedup", summary.geomean_speedup());
    train::export_stages(&counters, &mut reg);
    tel.finish(&reg)?;
    Ok(())
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let dev = &device_arg(args)?;
    let model_path = PathBuf::from(args.str_or("model", "models/rf.txt"));
    let data_path = args.opt_str("data").map(PathBuf::from);
    let shards: Option<usize> = args.get("shards").map_err(anyhow::Error::msg)?;
    let out_dir_explicit = args.opt_str("out-dir");
    let out_dir =
        PathBuf::from(out_dir_explicit.as_deref().unwrap_or("data/shards"));
    let train_cap_explicit = args.opt_str("train-cap").is_some();
    let train_cap: usize =
        args.get_or("train-cap", 50_000).map_err(anyhow::Error::msg)?;
    let train_frac_given = args.opt_str("train-frac").is_some();
    let format_explicit = args.opt_str("format").is_some();
    // CSV default: the text shards carry exact f64 speedups; --format
    // bin opts into the f32-quantized columnar format.
    let format = format_arg(args, ShardFormat::Csv)?;
    let stages = pipeline_args(args);
    let cfg = train_config(args)?;
    let tel = telemetry_args(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    if shards.is_none() && (out_dir_explicit.is_some() || train_cap_explicit) {
        // These options select the streaming pipeline; consuming them
        // silently would run the in-memory path the user asked to avoid.
        bail!("--out-dir/--train-cap require --shards N (streamed mode)");
    }
    if shards.is_none() && (format_explicit || !stages.is_empty()) {
        bail!("--format/--dedup/--validate require --shards N (streamed mode)");
    }
    if shards.is_some() {
        if train_frac_given {
            println!(
                "note: --train-frac ignored with --shards (the training \
                 split is the --train-cap {train_cap}-row reservoir)"
            );
        }
        // The reservoir must leave held-out rows to evaluate; the
        // instance count is bounded by templates x configs, so a cap
        // at or above that bound is guaranteed to swallow everything.
        let max_rows = lmtuner::synth::generator::template_count(cfg.scale)
            * cfg.configs_per_kernel;
        if train_cap >= max_rows {
            bail!(
                "--train-cap {train_cap} >= the {max_rows}-instance upper \
                 bound at --scale {} x --configs {}; nothing would be left \
                 to evaluate (lower --train-cap or raise --scale)",
                cfg.scale,
                cfg.configs_per_kernel
            );
        }
    }

    println!(
        "training on {} ({}): scale={} configs/kernel={} trees={} mtry={} train-frac={}",
        dev.name,
        dev.key,
        cfg.scale,
        cfg.configs_per_kernel,
        cfg.forest.num_trees,
        cfg.forest.tree.mtry,
        cfg.train_fraction
    );
    let mut progress = progress_printer();
    let out = if let Some(shards) = shards {
        let scfg = train::ShardedTrainConfig {
            shards,
            train_capacity: train_cap,
            format,
            stages,
            ..train::ShardedTrainConfig::new(cfg, out_dir.clone())
        };
        println!(
            "streaming dataset to {} ({} shards, format {}, train reservoir {})",
            scfg.out_dir.display(),
            scfg.shards,
            scfg.format,
            scfg.train_capacity
        );
        train::run_sharded(dev, &scfg, Some(&mut progress))?
    } else {
        train::run_with_progress(dev, &cfg, Some(&mut progress))
    };
    print_stage_counters(&out.stage_counters);
    println!(
        "dataset: {} instances in {:.1}s; trained on {} in {:.1}s (max depth {}, max nodes {})",
        out.summary.records,
        out.gen_seconds,
        out.train_size,
        out.fit_seconds,
        out.forest.max_depth(),
        out.forest.max_nodes(),
    );
    // Per-phase breakdown: generate, fit, and grade each report their
    // own elapsed + throughput instead of one folded rows/sec figure.
    for p in &out.phases {
        println!(
            "phase {:<8} {:>9} items in {:>6.1}s ({:.0}/s)",
            p.name,
            p.items,
            p.seconds,
            p.per_second()
        );
    }
    if let Some(oob) = &out.oob {
        println!(
            "oob: mse {:.4}  decision accuracy {:.1}%  ({}/{} samples covered)",
            oob.mse,
            100.0 * oob.decision_accuracy,
            oob.covered,
            oob.total
        );
    }
    println!("{}", figures::fig6(&out.synth_accuracy, &out.per_benchmark));
    warn_skipped(out.synth_accuracy.skipped);
    if let Some(j) = &out.joint {
        println!(
            "joint (schema v2): verdict {:.1}%  wg top-{} hit {:.1}%  \
             joint {:.1}%  (n {}, skipped {})",
            100.0 * j.verdict.count_based,
            j.top_k,
            100.0 * j.wg_hit_rate,
            100.0 * j.joint,
            j.n,
            j.skipped
        );
    }
    if let Some(dir) = model_path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    if shards.is_some() && data_path.is_some() {
        println!(
            "note: --data ignored with --shards (dataset already at {})",
            out_dir.display()
        );
        train::save_outcome(&out, &model_path, None)?;
    } else {
        train::save_outcome(&out, &model_path, data_path.as_deref())?;
        if let Some(p) = data_path {
            println!("dataset saved to {}", p.display());
        }
    }
    println!("model saved to {}", model_path.display());
    tel.finish(&out.metrics)?;
    Ok(())
}

fn cmd_tune(args: &mut Args) -> Result<()> {
    let dev = &device_arg(args)?;
    let out = PathBuf::from(args.str_or("out", "data/tune.csv"));
    let best_path = PathBuf::from(args.str_or("best", "models/forest-config.txt"));
    let scale: f64 = args.get_or("scale", 0.05).map_err(anyhow::Error::msg)?;
    let configs_per_kernel: usize =
        args.get_or("configs", 8).map_err(anyhow::Error::msg)?;
    let seed: u64 = args.get_or("seed", 0x5EEDu64).map_err(anyhow::Error::msg)?;
    let folds: usize = args.get_or("folds", 5).map_err(anyhow::Error::msg)?;
    let default_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let threads: usize =
        args.get_or("threads", default_threads).map_err(anyhow::Error::msg)?;
    let grid = select::GridSpec::parse(
        &args.str_or("trees", "10,20,40"),
        &args.str_or("mtry", "2,4,8"),
        &args.str_or("min-leaf", "1,4"),
    )?;
    let mut base_train = TrainConfig {
        scale,
        configs_per_kernel,
        seed,
        ..TrainConfig::default()
    };
    if args.flag("no-noise") {
        base_train.measure = MeasureConfig::deterministic();
    }
    let mut base_forest = lmtuner::ml::forest::ForestConfig::default();
    // --seed drives the whole run: dataset generation, fold assignment,
    // and every forest's bagging/mtry streams.
    base_forest.seed = seed;
    if let Some(engine) = args.opt_str("engine") {
        base_forest.tree.engine = engine.parse().map_err(anyhow::Error::msg)?;
    }
    args.finish().map_err(anyhow::Error::msg)?;

    println!(
        "tune on {} ({}): scale={scale} configs/kernel={configs_per_kernel} \
         grid={} configs x {folds} folds ({} threads)",
        dev.name,
        dev.key,
        grid.len(),
        threads
    );
    let t0 = std::time::Instant::now();
    // Same generation path as `train` (train::build_records), so the
    // winning config is selected on the distribution train fits on.
    let records = train::build_records(dev, &base_train);
    println!("{} instances in {:.1}s", records.len(), t0.elapsed().as_secs_f64());

    let tune_cfg = select::TuneConfig { folds, seed, threads, base: base_forest };
    let t1 = std::time::Instant::now();
    let outcome = select::cross_validate(&records, &grid, &tune_cfg)?;
    for (i, s) in outcome.scores.iter().enumerate() {
        let marker = if i == outcome.best { "*" } else { " " };
        println!(" {marker} {}", s.render());
    }
    select::write_csv(&outcome, &out)?;
    let best = outcome.best_score();
    select::save_forest_config(&best.config, &best_path)?;
    println!(
        "cross-validated {} configs x {} folds over {} rows in {:.1}s",
        outcome.scores.len(),
        outcome.folds,
        outcome.rows,
        t1.elapsed().as_secs_f64()
    );
    println!("per-config CV table written to {}", out.display());
    println!(
        "best config (count {:.1}%, penalty-weighted {:.1}%) written to {} \
         — consume with `lmtuner train --forest-config {}`",
        100.0 * best.count_based,
        100.0 * best.penalty_weighted,
        best_path.display(),
        best_path.display()
    );
    Ok(())
}

fn cmd_crossdev(args: &mut Args) -> Result<()> {
    let devices_arg = args.str_or("devices", "");
    let out = PathBuf::from(args.str_or("out", "data/crossdev.csv"));
    let mut base = TrainConfig {
        scale: args.get_or("scale", 0.05).map_err(anyhow::Error::msg)?,
        configs_per_kernel: args.get_or("configs", 8).map_err(anyhow::Error::msg)?,
        train_fraction: args.get_or("train-frac", 0.10).map_err(anyhow::Error::msg)?,
        seed: args.get_or("seed", 0x5EEDu64).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    apply_forest_args(args, &mut base.forest)?;
    if args.flag("no-noise") {
        base.measure = MeasureConfig::deterministic();
    }
    if let Some(s) = args.opt_str("schema") {
        base.schema = s.parse().map_err(anyhow::Error::msg)?;
    }
    let dump_dir = args.opt_str("dump-dir").map(PathBuf::from);
    let dump_shards: usize =
        args.get_or("dump-shards", 4).map_err(anyhow::Error::msg)?;
    let dump_format_explicit = args.opt_str("format").is_some();
    let dump_format = format_arg(args, ShardFormat::Bin)?;
    let tel = telemetry_args(args)?;
    args.finish().map_err(anyhow::Error::msg)?;
    if dump_dir.is_none() && dump_format_explicit {
        bail!("--format requires --dump-dir DIR (it sets the dump shard format)");
    }
    let dump = dump_dir.map(|dir| crossdev::DumpSpec {
        dir,
        format: dump_format,
        shards: dump_shards,
    });

    let devices = if devices_arg.is_empty() {
        registry::all()
    } else {
        devices_arg
            .split(',')
            .map(registry::get)
            .collect::<Result<Vec<_>>>()?
    };
    println!(
        "cross-device matrix over [{}] at scale {} x {} configs/kernel",
        devices.iter().map(|d| d.key).collect::<Vec<_>>().join(", "),
        base.scale,
        base.configs_per_kernel
    );
    if let Some(spec) = &dump {
        println!(
            "dumping each device's dataset to {}/<key>/ ({} shards, format {})",
            spec.dir.display(),
            spec.shards,
            spec.format
        );
    }
    let t0 = std::time::Instant::now();
    let matrix = crossdev::run_with_progress(
        &crossdev::CrossDevConfig { base, devices, dump },
        |stage| eprintln!("  {stage}"),
    )?;
    print!("{}", matrix.render());
    matrix.to_csv(&out)?;
    println!(
        "matrix written to {} ({} devices, held-out rows {:?}) in {:.1}s",
        out.display(),
        matrix.n(),
        matrix.test_rows,
        t0.elapsed().as_secs_f64()
    );
    let mut reg = MetricsRegistry::new();
    reg.add("crossdev.devices", matrix.n() as u64);
    reg.add("crossdev.cells", (matrix.n() * matrix.n()) as u64);
    reg.add(
        "crossdev.test_rows",
        matrix.test_rows.iter().map(|&r| r as u64).sum(),
    );
    reg.set_gauge("crossdev.elapsed_s", t0.elapsed().as_secs_f64());
    reg.set_gauge("crossdev.diagonal_mean", matrix.diagonal_mean());
    tel.finish(&reg)?;
    Ok(())
}

fn cmd_eval(args: &mut Args) -> Result<()> {
    let device_explicit = args.opt_str("device");
    let dev = &device_arg(args)?;
    let model_path = PathBuf::from(args.str_or("model", "models/rf.txt"));
    let data = args.opt_str("data").map(PathBuf::from);
    let real = args.flag("real");
    args.finish().map_err(anyhow::Error::msg)?;

    let forest = model_io::load(&model_path)?;
    if let Some(p) = data {
        // --data accepts a CSV file, a single binary shard, or a shard
        // directory in either format.
        let (records, tag, format) = dataset::load_any(&p)?;
        // Refuse to grade a dataset measured on a different device than
        // the one explicitly requested — the labels would not match the
        // testbed the caller thinks they are evaluating.
        if let (Some(_), Some(found)) = (&device_explicit, &tag.device) {
            shard_sink::ensure_same_device(
                dev.key,
                found,
                p.display().to_string(),
            )?;
        }
        match &tag.device {
            Some(d) => println!(
                "dataset device: {d}; schema: {}; format: {format}",
                tag.schema
            ),
            None => {
                println!(
                    "dataset device: <unstamped legacy file>; schema: {}; \
                     format: {format}",
                    tag.schema
                )
            }
        }
        // A single-output model graded on a joint dataset (or a joint
        // model on a v1 dataset) would silently score only half the
        // recommendation — refuse the pair instead.
        model_io::ensure_output_arity(
            &forest,
            tag.schema.outputs(),
            &format!(
                "eval --model {} --data {}",
                model_path.display(),
                p.display()
            ),
        )?;
        let refs: Vec<&SpeedupRecord> = records.iter().map(|r| &r.base).collect();
        // Grade through the serving hot path (the flat executor), so
        // eval measures exactly what `serve`/`analyze` ship.
        let exec = FlatForestExecutor::new(&train::encode_default(&forest))?;
        let flat = exec.flat().clone();
        let acc = metrics::evaluate_model(&refs, |x| flat.decide_row(x));
        println!(
            "{}: count {:.1}%  penalty-weighted {:.1}%  (min {:.2}, n {})",
            p.display(),
            100.0 * acc.count_based,
            100.0 * acc.penalty_weighted,
            acc.min_score,
            acc.n
        );
        warn_skipped(acc.skipped);
        if tag.schema == Schema::V2 {
            // One batched traversal yields the verdict and both
            // workgroup planes for every record.
            let rows: Vec<Vec<f64>> =
                records.iter().map(|r| r.base.features.to_vec()).collect();
            let k = exec.num_outputs();
            let outs = exec.predict_outputs(&rows)?;
            let mut jacc = metrics::JointAccumulator::new();
            for (i, r) in records.iter().enumerate() {
                let score = outs[i * k];
                let wg = if k >= 3 {
                    (outs[i * k + 1], outs[i * k + 2])
                } else {
                    (0.0, 0.0)
                };
                jacc.push(r.base.speedup, score > 0.0, r.best_wg, wg);
            }
            let j = jacc.finish();
            println!(
                "joint: wg top-{} hit {:.1}%  joint {:.1}%  (n {}, skipped {})",
                j.top_k,
                100.0 * j.wg_hit_rate,
                100.0 * j.joint,
                j.n,
                j.skipped
            );
        }
    }
    if real {
        println!("real benchmarks on {} ({})", dev.name, dev.key);
        let per = train::evaluate_real(dev, &forest, &MeasureConfig::default());
        for (name, a) in &per {
            println!(
                "{name:<14} count {:>5.1}%  penalty-weighted {:>5.1}%  (min {:.2}, n {})",
                100.0 * a.count_based,
                100.0 * a.penalty_weighted,
                a.min_score,
                a.n
            );
        }
        warn_skipped(per.iter().map(|(_, a)| a.skipped).sum());
    }
    Ok(())
}

/// Inspect a shard directory: one line per shard (format, device,
/// schema, rows, checksum), then stream totals. Any corrupt shard or
/// cross-shard incoherence (mixed formats/devices/schemas, gaps) is an
/// error, so the nonzero exit makes this a cheap integrity probe.
fn cmd_shards(args: &mut Args) -> Result<()> {
    let dir = args
        .positional()
        .get(1)
        .cloned()
        .context("usage: lmtuner shards <dir>")?;
    args.finish().map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(dir);

    let listing = shard_sink::shard_listing(&dir)?;
    let mut total_rows = 0u64;
    let mut first: Option<shard_sink::ShardInfo> = None;
    for (idx, _, path) in &listing {
        let info = shard_sink::inspect_shard(path)?;
        println!(
            "shard {idx:>5}  {}  rows {:>10}  device {:<10}  schema {}  checksum {}",
            info.format,
            info.rows,
            info.device.as_deref().unwrap_or("<unstamped>"),
            info.schema,
            match info.checksum {
                Some(c) => format!("{c:016x}"),
                None => "-".into(),
            }
        );
        total_rows += info.rows;
        if let Some(f) = &first {
            if info.format != f.format {
                return Err(shard_sink::FormatMismatch {
                    expected: f.format,
                    found: info.format,
                    at: path.display().to_string(),
                }
                .into());
            }
            if info.schema != f.schema {
                return Err(shard_sink::SchemaMismatch {
                    expected: f.schema,
                    found: info.schema,
                    at: path.display().to_string(),
                }
                .into());
            }
            shard_sink::ensure_same_device(
                f.device.as_deref().unwrap_or("<unstamped>"),
                info.device.as_deref().unwrap_or("<unstamped>"),
                path.display().to_string(),
            )?;
        } else {
            first = Some(info);
        }
    }
    let f = first.expect("shard_listing never returns an empty listing");
    println!(
        "{}: {} shard(s), {} rows, format {}, device {}, schema {}",
        dir.display(),
        listing.len(),
        total_rows,
        f.format,
        f.device.as_deref().unwrap_or("<unstamped>"),
        f.schema
    );
    Ok(())
}

/// Parse a `WxH` geometry argument ("16x8") on the typed error path.
fn parse_geom(s: &str, flag: &str) -> Result<(u32, u32)> {
    let (w, h) = s
        .split_once('x')
        .with_context(|| format!("{flag}={s}: expected WxH (e.g. 16x8)"))?;
    let parse = |v: &str| -> Result<u32> {
        let n: u32 = v
            .trim()
            .parse()
            .with_context(|| format!("{flag}={s}: `{v}` is not a positive integer"))?;
        if n == 0 {
            bail!("{flag}={s}: dimensions must be nonzero");
        }
        Ok(n)
    };
    Ok((parse(w)?, parse(h)?))
}

/// One parsed kernel source plus the launch/bindings context `analyze`
/// and `lint` share: a single positional → parse → bind path, so both
/// subcommands exit through the same typed errors (missing file, bad
/// `--wg`/`--grid` geometry, malformed `--set`, positioned parse
/// errors).
struct KernelSource {
    file: String,
    kernel: Option<String>,
    launch: Launch,
    bindings: Bindings,
    prog: lmtuner::frontend::ast::Program,
}

fn load_kernel_source(args: &mut Args, usage: &str) -> Result<KernelSource> {
    let file = args.positional().get(1).cloned().context(usage.to_string())?;
    let kernel = args.opt_str("kernel");
    let (wg_w, wg_h) = parse_geom(&args.str_or("wg", "16x16"), "--wg")?;
    let (grid_w, grid_h) = parse_geom(&args.str_or("grid", "512x512"), "--grid")?;
    let set = args.str_or("set", "");
    let bindings = Bindings::parse(&set).map_err(|e| anyhow::anyhow!("--set {e}"))?;
    let src = std::fs::read_to_string(&file).with_context(|| format!("reading {file}"))?;
    let launch = Launch::new(
        WgGeom { w: wg_w, h: wg_h },
        GridGeom { w: grid_w, h: grid_h },
    );
    let prog = frontend::parse_program(&src)?;
    Ok(KernelSource { file, kernel, launch, bindings, prog })
}

fn cmd_analyze(args: &mut Args) -> Result<()> {
    let dev = &device_arg(args)?;
    let target = args
        .opt_str("array")
        .context("--array <name> is required (the array considered for staging)")?;
    let model = args.opt_str("model");
    // Before the parse: --trace-out must capture the frontend spans.
    let tel = telemetry_args(args)?;
    let t_parse = std::time::Instant::now();
    let ks = load_kernel_source(args, "usage: lmtuner analyze <kernel.cl> --array NAME [options]")?;
    let parse_s = t_parse.elapsed().as_secs_f64();
    args.finish().map_err(anyhow::Error::msg)?;

    // Deny gate: barrier divergence or out-of-bounds accesses invalidate
    // everything synthesized downstream; refuse with a distinct exit
    // code (warnings are surfaced but do not block).
    let sopts = SemaOptions {
        kernel: ks.kernel.clone(),
        launch: ks.launch,
        bindings: ks.bindings.clone(),
        certificates: false,
    };
    let t_lint = std::time::Instant::now();
    let report = frontend::lint_program(&ks.prog, &sopts, dev)?;
    let mut reg = MetricsRegistry::new();
    reg.set_gauge("frontend.parse_s", parse_s);
    reg.set_gauge("frontend.lint_s", t_lint.elapsed().as_secs_f64());
    reg.add("analyze.diags.deny", report.diags.deny_count() as u64);
    reg.add("analyze.diags.warn", report.diags.warn_count() as u64);
    reg.add("analyze.diags.note", report.diags.note_count() as u64);
    for d in report.diags.iter().filter(|d| d.severity >= Severity::Warn) {
        eprintln!("{}:{d}", ks.file);
    }
    if report.diags.deny_count() > 0 {
        eprintln!(
            "error: {}: {} deny-level diagnostic(s) — inspect with `lmtuner lint {}`",
            ks.file,
            report.diags.deny_count(),
            ks.file
        );
        // The refused path still emits its telemetry — the parse/lint
        // timings and diag counters are exactly what a CI consumer
        // wants from a rejected kernel.
        tel.finish(&reg)?;
        exit_with(EXIT_ANALYZE_REFUSED);
    }

    let opts = AnalyzeOptions {
        target: target.clone(),
        kernel: ks.kernel.clone(),
        launch: ks.launch,
        bindings: ks.bindings.clone(),
    };
    let t_extract = std::time::Instant::now();
    let d = frontend::extract::extract_descriptor(&ks.prog, &opts, dev)?;
    reg.set_gauge("frontend.extract_s", t_extract.elapsed().as_secs_f64());

    println!("kernel: {} ({})", d.name, ks.file);
    println!(
        "target array: {target}; device: {} ({}); wg {}x{}; grid {}x{}",
        dev.name, dev.key, ks.launch.wg.w, ks.launch.wg.h, ks.launch.grid.w, ks.launch.grid.h
    );
    println!("descriptor:");
    println!(
        "  taps={} inner_iters={} wus_per_wi={} tx/access={:.2}",
        d.taps, d.inner_iters, d.wus_per_wi, d.tx_per_target_access
    );
    println!(
        "  staged region {}x{} ({} B), reuse {:.3}, tap offsets rows {}..{} cols {}..{}",
        d.region_rows,
        d.region_cols,
        d.region_bytes(),
        d.reuse,
        d.offset_bounds.0,
        d.offset_bounds.1,
        d.offset_bounds.2,
        d.offset_bounds.3
    );
    println!(
        "  comp ilb/ep {}/{}, ctx coalesced {}/{}, ctx non-coalesced {}/{}, regs {}+{}",
        d.comp_ilb,
        d.comp_ep,
        d.coal_ilb,
        d.coal_ep,
        d.uncoal_ilb,
        d.uncoal_ep,
        d.base_regs,
        d.opt_extra_regs
    );
    println!(
        "  lmem feasible on {}: {}",
        dev.key,
        if d.lmem_feasible(dev) { "yes" } else { "no (region exceeds shared memory)" }
    );
    let cert = frontend::certify(&ks.prog, &opts, dev);
    println!("  staging certificate: {}", cert.summary());
    let feats = features::extract(&d);
    println!("features:");
    for (name, v) in FEATURE_NAMES.iter().zip(feats.iter()) {
        println!("  {name}={v}");
    }
    if let Some(model_path) = model {
        let forest = model_io::load(Path::new(&model_path))?;
        let exec = FlatForestExecutor::new(&train::encode_default(&forest))?;
        let score = exec.predict(&[feats.to_vec()])?[0];
        println!(
            "verdict ({model_path}): log2(speedup) = {score:+.3} ({:.2}x) -> {}",
            2f64.powf(score),
            if score > 0.0 { "USE local memory" } else { "do NOT use local memory" }
        );
        if exec.num_outputs() >= 3 {
            let (lw, lh) = exec.predict_wg_logs(&[feats.to_vec()])?[0];
            let cands = metrics::wg_candidates(lw, lh, metrics::WG_TOP_K);
            let (bw, bh) = cands[0];
            let alts = cands[1..]
                .iter()
                .map(|(w, h)| format!("{w}x{h}"))
                .collect::<Vec<_>>()
                .join(", ");
            println!(
                "workgroup ({model_path}): suggest {bw}x{bh} (predicted log2 \
                 {lw:.2}/{lh:.2}; next best {alts})"
            );
        }
    }
    tel.finish(&reg)?;
    Ok(())
}

fn cmd_lint(args: &mut Args) -> Result<()> {
    let dev = &device_arg(args)?;
    let json = args.flag("json");
    let deny_warn = match args.opt_str("deny") {
        None => false,
        Some(s) if s == "warn" => true,
        Some(s) => bail!("--deny {s}: only `warn` can be promoted to the deny set"),
    };
    let ks =
        load_kernel_source(args, "usage: lmtuner lint <kernel.cl> [--json] [--deny warn]")?;
    args.finish().map_err(anyhow::Error::msg)?;

    let sopts = SemaOptions {
        kernel: ks.kernel.clone(),
        launch: ks.launch,
        bindings: ks.bindings.clone(),
        certificates: true,
    };
    let report = frontend::lint_program(&ks.prog, &sopts, dev)?;
    if json {
        println!("{}", report.to_json(&ks.file).dump_pretty());
    } else {
        for d in report.diags.iter() {
            println!("{}:{d}", ks.file);
        }
        println!(
            "{}: {} deny, {} warn, {} note",
            ks.file,
            report.diags.deny_count(),
            report.diags.warn_count(),
            report.diags.note_count()
        );
    }
    let failing =
        report.diags.deny_count() + if deny_warn { report.diags.warn_count() } else { 0 };
    if failing > 0 {
        exit_with(EXIT_LINT_FINDINGS);
    }
    Ok(())
}

fn parse_features(s: &str) -> Result<[f64; NUM_FEATURES]> {
    let vals: Result<Vec<f64>, _> =
        s.split(',').map(|x| x.trim().parse::<f64>()).collect();
    let vals = vals.context("parse --features")?;
    if vals.len() != NUM_FEATURES {
        bail!(
            "--features needs {} comma-separated values ({})",
            NUM_FEATURES,
            FEATURE_NAMES.join(",")
        );
    }
    let mut out = [0.0; NUM_FEATURES];
    out.copy_from_slice(&vals);
    Ok(out)
}

fn cmd_predict(args: &mut Args) -> Result<()> {
    let model_path = PathBuf::from(args.str_or("model", "models/rf.txt"));
    let feats_str = args
        .opt_str("features")
        .context("--features f1,...,f18 required")?;
    let artifacts = args.opt_str("artifacts");
    args.finish().map_err(anyhow::Error::msg)?;

    let forest = model_io::load(&model_path)?;
    let feats = parse_features(&feats_str)?;
    let (score, path) = if let Some(dir) = artifacts {
        // Serve through the PJRT artifact (the artifact-backed path).
        let engine = Arc::new(Engine::new(Path::new(&dir))?);
        let enc = train::encode_for_serving(&forest, &engine.manifest);
        let exec =
            lmtuner::runtime::forest_exec::ForestExecutor::new(engine, &enc)?;
        (exec.predict(&[feats.to_vec()])?[0], "pjrt")
    } else {
        (forest.predict(&feats), "native")
    };
    println!(
        "predicted log2(speedup) = {score:+.3} ({:.2}x) via {path} -> {}",
        2f64.powf(score),
        if score > 0.0 { "USE local memory" } else { "do NOT use local memory" }
    );
    Ok(())
}

fn cmd_serve(args: &mut Args) -> Result<()> {
    let dev = device_arg(args)?;
    let model_path = PathBuf::from(args.str_or("model", "models/rf.txt"));
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    let backend = args.str_or("backend", "auto");
    let requests: usize = args.get_or("requests", 10_000).map_err(anyhow::Error::msg)?;
    let batch: usize = args.get_or("batch", 4096).map_err(anyhow::Error::msg)?;
    let wait_us: u64 = args.get_or("wait-us", 200).map_err(anyhow::Error::msg)?;
    let workers: usize = args.get_or("workers", 1).map_err(anyhow::Error::msg)?;
    let tel = telemetry_args(args)?;
    args.finish().map_err(anyhow::Error::msg)?;

    let forest = model_io::load(&model_path)?;
    let cfg = ServiceConfig {
        max_batch: batch,
        max_wait: std::time::Duration::from_micros(wait_us),
        workers,
        ..Default::default()
    };
    let (svc, served_by) = match backend.as_str() {
        "pjrt" => {
            let engine = Arc::new(Engine::new(&artifacts)?);
            println!("engine: platform={}", engine.platform());
            engine.warmup()?;
            let enc = train::encode_for_serving(&forest, &engine.manifest);
            (Service::start_pjrt(engine, enc, cfg)?, "pjrt")
        }
        "native" => (
            Service::start_native(train::encode_default(&forest), cfg)?,
            "native",
        ),
        "auto" => match Engine::new(&artifacts) {
            Ok(engine) => {
                let engine = Arc::new(engine);
                println!("engine: platform={}", engine.platform());
                engine.warmup()?;
                let enc = train::encode_for_serving(&forest, &engine.manifest);
                (Service::start_pjrt(engine, enc, cfg)?, "pjrt")
            }
            Err(e) => {
                println!("artifacts unavailable ({e:#}); serving natively");
                (
                    Service::start_native(train::encode_default(&forest), cfg)?,
                    "native",
                )
            }
        },
        other => bail!("unknown --backend {other} (auto|native|pjrt)"),
    };
    println!("serving via the {served_by} backend ({workers} worker shard(s))");
    let h = svc.handle();

    // Periodic one-line snapshot while the load runs: merged live
    // worker stats roughly every two seconds, polled off a detached
    // observer so the Service value stays here for shutdown. The
    // 100ms stop-poll keeps shutdown prompt.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let printer = {
        let observer = svc.stats_observer();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut last = std::time::Instant::now();
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                std::thread::sleep(std::time::Duration::from_millis(100));
                if last.elapsed().as_secs_f64() >= 2.0 {
                    last = std::time::Instant::now();
                    eprintln!("  [serve] {}", observer.total().summary_line());
                }
            }
        })
    };

    // Demo load: replay the real-benchmark instance stream for the
    // selected device.
    let mut stream: Vec<[f64; NUM_FEATURES]> = Vec::new();
    for b in lmtuner::workloads::all() {
        for d in (b.instances)(&dev) {
            stream.push(lmtuner::kernelmodel::features::extract(&d));
        }
    }
    let t0 = std::time::Instant::now();
    let (tx, rx) = std::sync::mpsc::channel();
    let mut sent = 0usize;
    for i in 0..requests {
        let f = stream[i % stream.len()];
        if h.submit(i as u64, f, tx.clone()).is_ok() {
            sent += 1;
        }
    }
    drop(tx);
    let mut lat_us: Vec<f64> = Vec::with_capacity(sent);
    let mut yes = 0usize;
    let mut failed = 0usize;
    for _ in 0..sent {
        match rx.recv()? {
            Ok(resp) => {
                lat_us.push(resp.latency.as_secs_f64() * 1e6);
                yes += resp.use_local_memory as usize;
            }
            Err(e) => {
                eprintln!("request failed: {e}");
                failed += 1;
            }
        }
    }
    let elapsed = t0.elapsed();
    drop(h);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let _ = printer.join();
    let (stats, per_worker) = svc.shutdown_per_worker();
    println!(
        "served {}/{} requests in {:.2}s  ({:.0} req/s, {} batches, {} failed)",
        stats.served,
        requests,
        elapsed.as_secs_f64(),
        stats.served as f64 / elapsed.as_secs_f64(),
        stats.batches,
        failed
    );
    if lat_us.is_empty() {
        println!("no successful responses; skipping latency percentiles");
    } else {
        println!(
            "latency p50 {:.0}us  p95 {:.0}us  p99 {:.0}us  | decisions: {:.1}% use-lmem",
            lmtuner::util::stats::percentile(&lat_us, 50.0),
            lmtuner::util::stats::percentile(&lat_us, 95.0),
            lmtuner::util::stats::percentile(&lat_us, 99.0),
            100.0 * yes as f64 / lat_us.len() as f64
        );
    }
    // Per-worker breakdown from the merged histograms: a dead or slow
    // shard shows up as an outlier row instead of vanishing into the
    // total.
    for (i, w) in per_worker.iter().enumerate() {
        println!("worker {i}: {}", w.summary_line());
    }
    println!("merged:   {}", stats.summary_line());

    let mut reg = MetricsRegistry::new();
    stats.export("serve", &mut reg);
    for (i, w) in per_worker.iter().enumerate() {
        w.export(&format!("serve.worker{i}"), &mut reg);
    }
    reg.add("serve.requests", requests as u64);
    reg.add("serve.failed", failed as u64);
    reg.set_gauge("serve.elapsed_s", elapsed.as_secs_f64());
    reg.set_gauge("serve.req_per_s", stats.served as f64 / elapsed.as_secs_f64());
    tel.finish(&reg)?;
    Ok(())
}

fn cmd_reproduce(args: &mut Args) -> Result<()> {
    let dev = &device_arg(args)?;
    let figure = args.str_or("figure", "all");
    let cfg = train_config(args)?;
    args.finish().map_err(anyhow::Error::msg)?;

    match figure.as_str() {
        "table1" => println!("{}", tables::table1()),
        "table2" => println!("{}", tables::table2(cfg.seed, 100_000)),
        "table3" => println!("{}", tables::table3(dev)),
        "fig1" | "fig6" | "all" => {
            let out = train::run(dev, &cfg);
            if figure != "fig6" {
                let real = figures::real_benchmark_records(dev, &cfg.measure);
                let bases: Vec<SpeedupRecord> =
                    out.records.iter().map(|r| r.base.clone()).collect();
                println!("{}", figures::fig1(&bases, &real));
            }
            if figure != "fig1" {
                println!("{}", figures::fig6(&out.synth_accuracy, &out.per_benchmark));
            }
            if figure == "all" {
                println!("{}", tables::table1());
                println!("{}", tables::table2(cfg.seed, 100_000));
                println!("{}", tables::table3(dev));
            }
        }
        other => bail!("unknown --figure {other}"),
    }
    Ok(())
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let dev = device_arg(args)?;
    let artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    args.finish().map_err(anyhow::Error::msg)?;
    println!("lmtuner {}", lmtuner::version());
    println!("device portfolio ({} registered):", registry::all().len());
    for d in registry::all() {
        let marker = if d.key == dev.key { "*" } else { " " };
        println!(
            " {marker} {:<8} {} (CC {}.{}, {} SMs, {} KB lmem/SM, {:.0} GB/s)",
            d.key,
            d.name,
            d.compute_capability.0,
            d.compute_capability.1,
            d.num_sms,
            d.shared_mem_per_sm / 1024,
            d.mem_bandwidth / 1e9
        );
    }
    println!("features ({}): {}", NUM_FEATURES, FEATURE_NAMES.join(", "));
    match Engine::new(&artifacts) {
        Ok(engine) => {
            println!(
                "artifacts: {} loaded from {} (platform {})",
                engine.manifest.artifacts.len(),
                artifacts.display(),
                engine.platform()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
