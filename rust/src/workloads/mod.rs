//! The eight real-world benchmarks of paper Table 3.
//!
//! Each module describes one kernel's target-array access structure the
//! way the paper extracts features from real applications: *manually*,
//! by mapping the kernel's work-unit structure onto the template model.
//! Every benchmark produces exactly the kernel-instance count of Table 3
//! (varying launch configuration, tiling factors and problem sizes), and
//! the instances are *not* template instances — each uses its own access
//! geometry, so the distribution shift vs. the synthetic population
//! (paper Fig. 1b-1i vs 1a) is real.

pub mod convolution;
pub mod matrixmul;
pub mod mri_gridding;
pub mod mvt;
pub mod sad;
pub mod sgemm;
pub mod tpacf;
pub mod transpose;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;
use crate::kernelmodel::launch::{GridGeom, Launch, WgGeom};

/// Static description of one benchmark (Table 3 row).
pub struct Benchmark {
    pub name: &'static str,
    pub suite: &'static str,
    pub description: &'static str,
    /// Lines of (kernel) code reported by the paper.
    pub loc: u32,
    /// Kernel instances the paper evaluates.
    pub paper_instances: usize,
    pub instances: fn(&DeviceSpec) -> Vec<KernelDescriptor>,
}

/// Table 3, in paper order.
pub fn all() -> Vec<Benchmark> {
    vec![
        Benchmark {
            name: "transpose",
            suite: "NVIDIA SDK",
            description: "Matrix transpose",
            loc: 6,
            paper_instances: 21,
            instances: transpose::instances,
        },
        Benchmark {
            name: "matrixMul",
            suite: "NVIDIA SDK",
            description: "Matrix multiply (C = A x B)",
            loc: 9,
            paper_instances: 330,
            instances: matrixmul::instances,
        },
        Benchmark {
            name: "convolution",
            suite: "NVIDIA SDK",
            description: "2D separable convolution",
            loc: 10,
            paper_instances: 600,
            instances: convolution::instances,
        },
        Benchmark {
            name: "MVT",
            suite: "Polybench",
            description: "Matrix vector multiply",
            loc: 9,
            paper_instances: 120,
            instances: mvt::instances,
        },
        Benchmark {
            name: "SGEMM",
            suite: "Polybench",
            description: "C = alpha*A*B + beta*C",
            loc: 10,
            paper_instances: 48,
            instances: sgemm::instances,
        },
        Benchmark {
            name: "SAD",
            suite: "Parboil",
            description: "Sum-of-absolute-differences between image blocks",
            loc: 94,
            paper_instances: 517,
            instances: sad::instances,
        },
        Benchmark {
            name: "TPACF",
            suite: "Parboil",
            description: "Angular correlation function of astronomical bodies",
            loc: 129,
            paper_instances: 35,
            instances: tpacf::instances,
        },
        Benchmark {
            name: "MRI-GRIDDING",
            suite: "Parboil",
            description: "Regular-grid MR reconstruction by weighted interpolation",
            loc: 126,
            paper_instances: 35,
            instances: mri_gridding::instances,
        },
    ]
}

/// Shared builder so each benchmark only states what differs.
#[allow(clippy::too_many_arguments)]
pub struct DescriptorBuilder {
    pub name: String,
    pub taps: u32,
    pub inner_iters: u64,
    pub comp_ilb: u32,
    pub comp_ep: u32,
    pub coal_ilb: u32,
    pub coal_ep: u32,
    pub uncoal_ilb: u32,
    pub uncoal_ep: u32,
    pub tx_per_target_access: f64,
    pub region_rows: u64,
    pub region_cols: u64,
    pub reuse: f64,
    pub offset_bounds: (i32, i32, i32, i32),
    pub base_regs: u32,
    pub opt_extra_regs: u32,
    pub launch: Launch,
    pub wus_per_wi: u64,
}

impl DescriptorBuilder {
    pub fn build(self, dev: &DeviceSpec) -> KernelDescriptor {
        KernelDescriptor {
            name: self.name,
            taps: self.taps,
            inner_iters: self.inner_iters,
            comp_ilb: self.comp_ilb,
            comp_ep: self.comp_ep,
            coal_ilb: self.coal_ilb,
            coal_ep: self.coal_ep,
            uncoal_ilb: self.uncoal_ilb,
            uncoal_ep: self.uncoal_ep,
            tx_per_target_access: self.tx_per_target_access,
            uncoal_ctx_tx: dev.warp_size.min(self.launch.wg.size()) as f64,
            region_rows: self.region_rows,
            region_cols: self.region_cols,
            reuse: self.reuse,
            offset_bounds: self.offset_bounds,
            base_regs: self.base_regs.min(dev.max_regs_per_thread),
            opt_extra_regs: self
                .opt_extra_regs
                .min(dev.max_regs_per_thread - self.base_regs.min(dev.max_regs_per_thread)),
            launch: self.launch,
            wus_per_wi: self.wus_per_wi,
            elem_bytes: 4,
        }
    }
}

/// Launch over an out_w x out_h iteration space with the given workgroup;
/// grid covers the space directly (one workitem per output element unless
/// the caller divides).
pub fn launch_over(wg: (u32, u32), out: (u32, u32)) -> Launch {
    Launch::new(
        WgGeom { w: wg.0, h: wg.1 },
        GridGeom { w: out.0.max(wg.0), h: out.1.max(wg.1) },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};
    use crate::sim::timing::{simulate, Variant};

    #[test]
    fn instance_counts_match_table3() {
        let dev = DeviceSpec::m2090();
        for b in all() {
            let got = (b.instances)(&dev).len();
            assert_eq!(got, b.paper_instances, "{}", b.name);
        }
    }

    #[test]
    fn all_baselines_are_feasible() {
        let dev = DeviceSpec::m2090();
        for b in all() {
            for d in (b.instances)(&dev) {
                assert!(
                    simulate(&d, &dev, Variant::Baseline).feasible(),
                    "{}: {} baseline infeasible",
                    b.name,
                    d.name
                );
            }
        }
    }

    #[test]
    fn features_are_finite_and_speedups_sane() {
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        for b in all() {
            for d in (b.instances)(&dev) {
                let r = measure(&d, &dev, &cfg);
                assert!(r.features.iter().all(|x| x.is_finite()), "{}", d.name);
                assert!(r.speedup > 0.0 && r.speedup.is_finite());
            }
        }
    }

    #[test]
    fn benchmarks_have_distinct_speedup_profiles() {
        // The eight Fig.-1 histograms must not all look alike: at least
        // one benchmark should be mostly-beneficial and one mostly-not.
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let mut fracs = Vec::new();
        for b in all() {
            let recs: Vec<_> = (b.instances)(&dev)
                .iter()
                .map(|d| measure(d, &dev, &cfg))
                .collect();
            let frac = recs.iter().filter(|r| r.beneficial()).count() as f64
                / recs.len() as f64;
            fracs.push((b.name, frac));
        }
        let max = fracs.iter().map(|f| f.1).fold(0.0, f64::max);
        let min = fracs.iter().map(|f| f.1).fold(1.0, f64::min);
        assert!(max > 0.6, "no mostly-beneficial benchmark: {fracs:?}");
        assert!(min < 0.4, "no mostly-harmful benchmark: {fracs:?}");
    }

    #[test]
    fn benchmarks_port_across_the_device_registry() {
        // Every benchmark lowers cleanly on every registered device:
        // Table-3 instance counts hold, baselines stay launchable, and
        // features stay finite.
        use crate::gpu::registry;
        let cfg = MeasureConfig::deterministic();
        for dev in registry::all() {
            for b in all() {
                let instances = (b.instances)(&dev);
                assert_eq!(
                    instances.len(),
                    b.paper_instances,
                    "{} on {}",
                    b.name,
                    dev.key
                );
                for d in instances.iter().step_by(7) {
                    assert!(
                        simulate(d, &dev, Variant::Baseline).feasible(),
                        "{}: {} baseline infeasible on {}",
                        b.name,
                        d.name,
                        dev.key
                    );
                    let r = measure(d, &dev, &cfg);
                    assert!(
                        r.features.iter().all(|x| x.is_finite()),
                        "{} on {}",
                        d.name,
                        dev.key
                    );
                }
            }
        }
    }

    #[test]
    fn some_benchmark_label_flips_between_devices() {
        // The cross-device premise on the real workloads: at least one
        // instance's oracle decision differs between two devices in the
        // portfolio.
        use crate::gpu::registry;
        let cfg = MeasureConfig::deterministic();
        let devices = registry::all();
        for b in all() {
            let per_dev: Vec<Vec<bool>> = devices
                .iter()
                .map(|dev| {
                    (b.instances)(dev)
                        .iter()
                        .map(|d| measure(d, dev, &cfg).beneficial())
                        .collect()
                })
                .collect();
            for labels in &per_dev[1..] {
                if labels != &per_dev[0] {
                    return; // found a flip
                }
            }
        }
        panic!("no benchmark instance's oracle label differs across the portfolio");
    }

    #[test]
    fn names_are_unique_within_benchmarks() {
        let dev = DeviceSpec::m2090();
        for b in all() {
            let mut seen = std::collections::HashSet::new();
            for d in (b.instances)(&dev) {
                assert!(seen.insert(d.name.clone()), "dup {}", d.name);
            }
        }
    }
}
