//! Polybench SGEMM: C = alpha*A*B + beta*C (Table 3: 10 LOC, 48
//! instances).
//!
//! Same staging structure as matrixMul (tile of B reused across the
//! workgroup's rows) plus a heavier epilogue: the alpha/beta update reads
//! and writes C. 48 instances = 4 workgroups x 3 sizes x 4 k-tiles.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const WGS: [(u32, u32); 4] = [(16, 4), (16, 16), (32, 4), (32, 8)];
const SIZES: [u32; 3] = [512, 1024, 2048];
const TILE_K: [u32; 4] = [4, 8, 16, 32];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(48);
    for &wg in &WGS {
        for &size in &SIZES {
            for &tk in &TILE_K {
                let launch = launch_over(wg, (size, size));
                let region = (tk as u64, wg.0 as u64);
                let reuse = (launch.wg.size() as u64 * tk as u64) as f64
                    / (region.0 * region.1) as f64;
                out.push(
                    DescriptorBuilder {
                        name: format!("SGEMM_{size}_k{tk}_wg{}x{}", wg.0, wg.1),
                        taps: 1,
                        inner_iters: tk as u64,
                        comp_ilb: 2,
                        comp_ep: 4, // alpha*acc + beta*C
                        coal_ilb: 1,
                        coal_ep: 2, // C read + write
                        uncoal_ilb: 0,
                        uncoal_ep: 0,
                        tx_per_target_access: 1.0,
                        region_rows: region.0,
                        region_cols: region.1,
                        reuse,
                        offset_bounds: (0, 0, 0, 0),
                        base_regs: 24,
                        opt_extra_regs: 4,
                        launch,
                        wus_per_wi: (size / tk).max(1) as u64,
                    }
                    .build(dev),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_is_48() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 48);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "SGEMM")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 48);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn epilogue_heavier_than_matrixmul() {
        for d in instances(&DeviceSpec::m2090()) {
            assert!(d.comp_ep >= 4);
            assert_eq!(d.coal_ep, 2);
        }
    }
}
