//! NVIDIA SDK matrix transpose (Table 3: 6 LOC, 21 instances).
//!
//! out[x][y] = in[y][x]. The read is coalesced; the transposed write is
//! fully scattered across rows — the canonical coalescing-fix use of
//! local memory (stage a tile, write it back transposed, both coalesced).
//! No data reuse at all: the optimization lives or dies on the
//! non-coalescing degree and the launch shape.
//!
//! 21 instances = 7 workgroup tiles x 3 matrix sizes.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const WGS: [(u32, u32); 7] =
    [(8, 8), (16, 8), (16, 16), (32, 8), (32, 16), (32, 32), (64, 4)];
const SIZES: [u32; 3] = [512, 1024, 2048];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(21);
    for &size in &SIZES {
        for &wg in &WGS {
            let launch = launch_over(wg, (size, size));
            // Scattered write: one row per lane along wi_x.
            let tx = dev.warp_size.min(wg.0) as f64;
            out.push(
                DescriptorBuilder {
                    name: format!("transpose_{size}_wg{}x{}", wg.0, wg.1),
                    taps: 1,
                    inner_iters: 1,
                    comp_ilb: 0,
                    comp_ep: 2, // index arithmetic
                    coal_ilb: 1, // the coalesced read of in
                    coal_ep: 0,
                    uncoal_ilb: 0,
                    uncoal_ep: 0,
                    tx_per_target_access: tx,
                    // Scattered writes span wg.0 rows of `out`; the staged
                    // tile is wg.0 rows x (wg.1 + 1) columns (+1 is the
                    // classic bank-conflict pad).
                    region_rows: wg.0 as u64,
                    region_cols: wg.1 as u64 + 1,
                    reuse: 1.0,
                    offset_bounds: (0, 0, 0, 0),
                    base_regs: 10,
                    opt_extra_regs: 4,
                    launch,
                    wus_per_wi: 1,
                }
                .build(dev),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_21() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 21);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "transpose")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 21);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn mostly_beneficial() {
        // Transpose is the canonical staging win.
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let recs: Vec<_> =
            instances(&dev).iter().map(|d| measure(d, &dev, &cfg)).collect();
        let wins = recs.iter().filter(|r| r.beneficial()).count();
        assert!(wins * 2 > recs.len(), "{wins}/{}", recs.len());
    }

    #[test]
    fn no_reuse_extracted() {
        for d in instances(&DeviceSpec::m2090()) {
            assert_eq!(d.reuse, 1.0);
            assert!(d.tx_per_target_access >= 8.0);
        }
    }
}
