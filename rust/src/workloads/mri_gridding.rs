//! Parboil MRI-GRIDDING: regular-grid MR reconstruction by weighted
//! interpolation of acquired sample points (Table 3: 126 LOC, 35
//! instances).
//!
//! Each work unit processes one sample and scatters a weighted kernel
//! into a neighbourhood of grid cells. The sample reads are coalesced
//! streams; the grid updates are scattered with little inter-thread
//! overlap (samples land anywhere), so the stageable region is a large
//! bin of the output grid with low reuse — staging is usually not worth
//! it, except for dense bins with small kernels.
//!
//! 35 instances = 5 workgroups x 7 (kernel width, bin size) configs.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const WGS: [(u32, u32); 5] = [(64, 1), (128, 1), (256, 1), (512, 1), (32, 4)];
/// (interp kernel width, grid bin edge) — 7 combos.
const CONFIGS: [(u32, u32); 7] = [
    (2, 16), (2, 32), (4, 16), (4, 32), (4, 64), (8, 32), (8, 64),
];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(35);
    for &wg in &WGS {
        for &(kw, bin) in &CONFIGS {
            let launch = launch_over(wg, (32768, 1));
            let taps = kw * kw;
            let rows = bin as u64;
            let cols = bin as u64;
            // Samples scatter: modest overlap within a bin.
            let reuse = (launch.wg.size() as u64 * taps as u64) as f64
                / (rows * cols) as f64;
            out.push(
                DescriptorBuilder {
                    name: format!("MRI-GRIDDING_wg{}x{}_k{kw}_b{bin}", wg.0, wg.1),
                    taps,
                    inner_iters: 1,
                    comp_ilb: 6 + 2 * taps, // Kaiser-Bessel weight + MACs
                    comp_ep: 4,
                    coal_ilb: 2, // sample coordinates + value reads
                    coal_ep: 0,
                    uncoal_ilb: 0,
                    uncoal_ep: 0,
                    // Scattered grid updates: lanes land in different rows.
                    tx_per_target_access: (dev.warp_size / 4) as f64,
                    region_rows: rows,
                    region_cols: cols,
                    reuse,
                    offset_bounds: (
                        0,
                        kw as i32 - 1,
                        0,
                        kw as i32 - 1,
                    ),
                    base_regs: 38,
                    opt_extra_regs: 6,
                    launch,
                    wus_per_wi: 8,
                }
                .build(dev),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_35() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 35);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "MRI-GRIDDING")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 35);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn outcome_is_mixed() {
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let recs: Vec<_> =
            instances(&dev).iter().map(|d| measure(d, &dev, &cfg)).collect();
        let wins = recs.iter().filter(|r| r.beneficial()).count();
        assert!(wins > 0 && wins < recs.len(), "{wins}/{}", recs.len());
    }

    #[test]
    fn low_reuse_vs_sad() {
        let dev = DeviceSpec::m2090();
        let avg: f64 = instances(&dev).iter().map(|d| d.reuse).sum::<f64>()
            / 35.0;
        assert!(avg < 20.0, "avg reuse {avg}");
    }
}
