//! NVIDIA SDK 2D separable convolution (Table 3: 10 LOC, 600 instances).
//!
//! Two passes: a row convolution (taps along x) and a column convolution
//! (taps along y). Both are warp-coalesced; the optimization's value is
//! the (2r+1)-way stencil-overlap reuse inside the workgroup's apron-
//! extended tile, against the staging + barrier + occupancy cost.
//!
//! 600 instances = 2 passes x 5 radii x 5 workgroups x 4 sizes x 3 rows
//! per thread.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const RADII: [u32; 5] = [1, 2, 3, 4, 6];
const WGS: [(u32, u32); 5] = [(16, 4), (16, 16), (32, 4), (32, 8), (64, 4)];
const SIZES: [u32; 4] = [256, 512, 1024, 2048];
const ROWS_PER_THREAD: [u32; 3] = [1, 2, 4];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(600);
    for pass in ["row", "col"] {
        for &r in &RADII {
            for &wg in &WGS {
                for &size in &SIZES {
                    for &rpt in &ROWS_PER_THREAD {
                    let launch = launch_over(wg, (size, size / rpt));
                    let taps = 2 * r + 1;
                    // Apron extends along the pass direction only.
                    let (rows, cols, bounds) = if pass == "row" {
                        (
                            wg.1 as u64,
                            (wg.0 + 2 * r) as u64,
                            (0, 0, -(r as i32), r as i32),
                        )
                    } else {
                        (
                            (wg.1 + 2 * r) as u64,
                            wg.0 as u64,
                            (-(r as i32), r as i32, 0, 0),
                        )
                    };
                    let reuse = (launch.wg.size() * taps) as f64
                        / (rows * cols) as f64;
                    out.push(
                        DescriptorBuilder {
                            name: format!(
                                "convolution_{pass}_r{r}_wg{}x{}_{size}_rpt{rpt}",
                                wg.0, wg.1
                            ),
                            taps,
                            inner_iters: 1,
                            comp_ilb: taps, // one MAC per tap
                            comp_ep: 1,
                            coal_ilb: 0,
                            coal_ep: 1, // output write
                            uncoal_ilb: 0,
                            uncoal_ep: 0,
                            tx_per_target_access: if pass == "row" {
                                1.0
                            } else {
                                // column pass: taps hit different rows but
                                // each warp row is still one segment
                                1.0
                            },
                            region_rows: rows,
                            region_cols: cols,
                            reuse,
                            offset_bounds: bounds,
                            base_regs: 14 + (taps / 4).min(20),
                            opt_extra_regs: 4,
                            launch,
                            wus_per_wi: rpt as u64,
                        }
                        .build(dev),
                    );
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_is_600() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 600);
    }

    #[test]
    fn table3_row_count_is_exact() {
        // Pin the paper's count through the Table 3 registry so the
        // frontend fixture reconciliation cannot silently drift.
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "convolution")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 600);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn reuse_grows_with_radius() {
        let dev = DeviceSpec::m2090();
        let all = instances(&dev);
        let avg = |r: u32| {
            let v: Vec<f64> = all
                .iter()
                .filter(|d| d.name.contains(&format!("_r{r}_")))
                .map(|d| d.reuse)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(avg(6) > avg(1), "{} !> {}", avg(6), avg(1));
    }

    #[test]
    fn both_passes_present_with_correct_apron() {
        for d in instances(&DeviceSpec::m2090()) {
            let (r0, r1, c0, c1) = d.offset_bounds;
            if d.name.contains("_row_") {
                assert_eq!((r0, r1), (0, 0));
                assert!(c1 > 0 && c0 < 0);
            } else {
                assert_eq!((c0, c1), (0, 0));
                assert!(r1 > 0 && r0 < 0);
            }
        }
    }
}
