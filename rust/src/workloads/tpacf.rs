//! Parboil TPACF: two-point angular correlation function of astronomical
//! bodies (Table 3: 129 LOC, 35 instances).
//!
//! Compute-dominated: each work unit compares a body against a tile of
//! other bodies — dozens of transcendental-heavy operations per pair,
//! a single coalesced target read per iteration, and a histogram update.
//! Latency is already hidden by arithmetic, so staging the body tile
//! rarely pays and the extra shared memory can cost occupancy: TPACF is
//! the "mostly don't optimize" histogram of Fig. 1.
//!
//! 35 instances = 5 workgroups x 7 dataset/tile configs.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const WGS: [(u32, u32); 5] = [(64, 1), (128, 1), (256, 1), (32, 4), (64, 4)];
/// (bodies, tile of bodies staged per round) — 7 combos.
const CONFIGS: [(u32, u32); 7] = [
    (4096, 64), (4096, 128), (16384, 64), (16384, 128), (16384, 256),
    (65536, 128), (65536, 256),
];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(35);
    for &wg in &WGS {
        for &(bodies, tile) in &CONFIGS {
            let launch = launch_over(wg, (bodies.min(8192), 1));
            // Staged region: tile bodies x 3 coords (f32).
            let rows = tile as u64;
            let cols = 3u64;
            let reuse = (launch.wg.size() as u64 * tile as u64) as f64
                / (rows * cols) as f64;
            out.push(
                DescriptorBuilder {
                    name: format!("TPACF_wg{}x{}_{bodies}_t{tile}", wg.0, wg.1),
                    taps: 3, // the three coordinates of the partner body
                    inner_iters: tile as u64,
                    comp_ilb: 38, // dot product + acos approximation + bin
                    comp_ep: 8,
                    coal_ilb: 0,
                    coal_ep: 0,
                    uncoal_ilb: 0,
                    uncoal_ep: 1, // per-round histogram merge (scattered)
                    tx_per_target_access: 1.0,
                    region_rows: rows,
                    region_cols: cols,
                    reuse,
                    offset_bounds: (0, 2, 0, 0),
                    base_regs: 42,
                    opt_extra_regs: 6,
                    launch,
                    wus_per_wi: (bodies / tile).max(1) as u64,
                }
                .build(dev),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_35() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 35);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "TPACF")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 35);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn mostly_not_beneficial() {
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let recs: Vec<_> =
            instances(&dev).iter().map(|d| measure(d, &dev, &cfg)).collect();
        let wins = recs.iter().filter(|r| r.beneficial()).count();
        assert!(wins * 2 < recs.len(), "{wins}/{}", recs.len());
    }

    #[test]
    fn compute_dominated() {
        for d in instances(&DeviceSpec::m2090()) {
            assert!(d.comp_ilb as f64 >= 10.0 * d.taps as f64 / 3.0);
        }
    }
}
