//! Parboil SAD: sum-of-absolute-differences between image-block pairs
//! for H.264 motion estimation (Table 3: 94 LOC, 517 instances).
//!
//! Each work unit scores one candidate motion vector for one 4x4 block:
//! 16 reference-frame taps per search position, with neighbouring search
//! positions overlapping heavily (high intra-workgroup reuse of the
//! search window). The search window staged per workgroup can get large,
//! so benefit flips with search range and workgroup shape.
//!
//! 517 instances = 11 (block, search-range) combos x 47 launch configs —
//! the paper's sweep is likewise a truncated parameter product.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

/// (block edge, search range) — 11 combos.
const SHAPES: [(u32, u32); 11] = [
    (4, 4), (4, 8), (4, 16), (4, 32), (8, 4), (8, 8), (8, 16), (8, 32),
    (16, 4), (16, 8), (16, 48),
];
const WGS: [(u32, u32); 8] = [
    (8, 4), (8, 8), (16, 4), (16, 8), (16, 16), (32, 4), (32, 8), (64, 2),
];
const FRAMES: [u32; 6] = [176, 352, 704, 1408, 2816, 5632]; // CIF multiples

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(517);
    'outer: for &(block, range) in &SHAPES {
        for &wg in &WGS {
            for &frame in &FRAMES {
                if out.len() == 517 {
                    break 'outer;
                }
                let launch = launch_over(wg, (frame.min(1408), 64));
                let taps = block * block;
                // Search window staged per workgroup: the union of all
                // candidate blocks the group's work units touch.
                let rows = (2 * range + block + wg.1) as u64;
                let cols = (2 * range + block + wg.0) as u64;
                let positions = (2 * range + 1) as u64; // per work unit
                let reuse = (launch.wg.size() as u64
                    * taps as u64
                    * positions) as f64
                    / (rows * cols) as f64;
                out.push(
                    DescriptorBuilder {
                        name: format!(
                            "SAD_b{block}_r{range}_wg{}x{}_{frame}",
                            wg.0, wg.1
                        ),
                        taps,
                        inner_iters: positions,
                        comp_ilb: 2 * taps, // abs-diff + accumulate per tap
                        comp_ep: 6,         // min-reduction bookkeeping
                        coal_ilb: 1,        // current-block read
                        coal_ep: 1,         // SAD output write
                        uncoal_ilb: 0,
                        uncoal_ep: 1,       // motion-vector table update
                        tx_per_target_access: (block as f64 / 8.0).max(1.0),
                        region_rows: rows,
                        region_cols: cols,
                        reuse,
                        offset_bounds: (
                            -(range as i32),
                            (range + block) as i32,
                            -(range as i32),
                            (range + block) as i32,
                        ),
                        base_regs: 30,
                        opt_extra_regs: 6,
                        launch,
                        wus_per_wi: 4,
                    }
                    .build(dev),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_517() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 517);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "SAD")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 517);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn reuse_is_high() {
        let dev = DeviceSpec::m2090();
        let avg: f64 = instances(&dev).iter().map(|d| d.reuse).sum::<f64>()
            / 517.0;
        assert!(avg > 10.0, "avg reuse {avg}");
    }

    #[test]
    fn large_windows_can_be_infeasible() {
        // Some search windows exceed 48 KB -> those instances must be
        // "don't optimize" (the mixed outcome the paper reports for SAD).
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let over: Vec<_> = instances(&dev)
            .into_iter()
            .filter(|d| !d.lmem_feasible(&dev))
            .collect();
        assert!(!over.is_empty());
        for d in &over {
            assert!(!measure(d, &dev, &cfg).beneficial());
        }
    }
}
