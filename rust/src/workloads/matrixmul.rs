//! NVIDIA SDK matrix multiply, C = A x B (Table 3: 9 LOC, 330 instances).
//!
//! Work unit = one C element; each k-tile round the workgroup stages a
//! TILE_K x WG_W block of B (the target array). B accesses are warp-
//! coalesced already — the optimization's value is pure inter-thread
//! reuse (each staged element serves the workgroup's WG_H rows), traded
//! against staging cost and occupancy.
//!
//! 330 instances = 2 sizes x 3 k-tiles x 11 workgroups x 5 unrolls.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const SIZES: [u32; 2] = [512, 1024];
const TILE_K: [u32; 3] = [4, 8, 16];
const WGS: [(u32, u32); 11] = [
    (16, 4), (16, 8), (16, 16), (32, 2), (32, 4), (32, 8), (32, 16),
    (8, 8), (8, 16), (64, 2), (64, 4),
];
const UNROLL: [u32; 5] = [1, 2, 3, 4, 5];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(330);
    for &size in &SIZES {
        for &tk in &TILE_K {
            for &wg in &WGS {
                for &u in &UNROLL {
                    let launch = launch_over(wg, (size, size));
                    let region = (tk as u64, wg.0 as u64);
                    let reuse = (launch.wg.size() as u64 * tk as u64) as f64
                        / (region.0 * region.1) as f64; // = wg_h
                    out.push(
                        DescriptorBuilder {
                            name: format!(
                                "matrixMul_{size}_k{tk}_wg{}x{}_u{u}",
                                wg.0, wg.1
                            ),
                            taps: 1,
                            inner_iters: tk as u64,
                            comp_ilb: 2 * u, // unrolled FMA chain
                            comp_ep: 2,
                            coal_ilb: 1, // the A[row, k] broadcast read
                            coal_ep: 1,  // C write
                            uncoal_ilb: 0,
                            uncoal_ep: 0,
                            tx_per_target_access: 1.0, // B is coalesced
                            region_rows: region.0,
                            region_cols: region.1,
                            reuse,
                            offset_bounds: (0, 0, 0, 0),
                            base_regs: 18 + 2 * u,
                            opt_extra_regs: 4,
                            launch,
                            wus_per_wi: (size / tk).max(1) as u64, // k rounds
                        }
                        .build(dev),
                    );
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_330() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 330);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "matrixMul")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 330);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn reuse_equals_wg_height() {
        for d in instances(&DeviceSpec::m2090()) {
            assert!((d.reuse - d.launch.wg.h as f64).abs() < 1e-9, "{}", d.name);
        }
    }

    #[test]
    fn outcome_depends_on_configuration() {
        // matrixMul must be mixed: tall workgroups reuse enough to win,
        // flat ones don't.
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let recs: Vec<_> =
            instances(&dev).iter().map(|d| measure(d, &dev, &cfg)).collect();
        let wins = recs.iter().filter(|r| r.beneficial()).count();
        assert!(wins > 0, "never beneficial");
        assert!(wins < recs.len(), "always beneficial");
    }
}
