//! Polybench MVT: y1 = A*x1 and y2 = A^T*x2 (Table 3: 9 LOC, 120
//! instances).
//!
//! Kernel 1 (row-wise reduction) is the paper's §2 motivating case: each
//! workitem reduces its own row, so a warp touches 32 different rows at
//! once — fully scattered. Staging a column batch fixes the coalescing.
//! Kernel 2 walks columns: already coalesced, no reuse — staging can only
//! lose. The two shapes give MVT its bimodal Fig.-1 histogram.
//!
//! 120 instances = 2 kernels x 6 workgroups x 10 problem/batch configs.

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

use super::{launch_over, DescriptorBuilder};

const WGS: [(u32, u32); 6] =
    [(32, 1), (64, 1), (128, 1), (256, 1), (32, 4), (64, 4)];
const CONFIGS: [(u32, u32); 10] = [
    // (matrix size, column batch staged per round)
    (512, 16), (512, 32), (1024, 16), (1024, 32), (1024, 64),
    (2048, 16), (2048, 32), (2048, 64), (2048, 128), (4096, 32),
];

pub fn instances(dev: &DeviceSpec) -> Vec<KernelDescriptor> {
    let mut out = Vec::with_capacity(120);
    for kernel in [1u32, 2u32] {
        for &wg in &WGS {
            for &(size, batch) in &CONFIGS {
                let launch = launch_over(wg, (size, 1));
                let wg_size = launch.wg.size();
                let scattered = kernel == 1;
                let tx = if scattered {
                    dev.warp_size.min(wg_size) as f64
                } else {
                    1.0
                };
                out.push(
                    DescriptorBuilder {
                        name: format!("MVT_k{kernel}_wg{}x{}_{size}_b{batch}", wg.0, wg.1),
                        taps: 1,
                        inner_iters: batch as u64,
                        comp_ilb: 2, // multiply-add with x
                        comp_ep: 1,
                        coal_ilb: 1, // x vector read (broadcast-coalesced)
                        coal_ep: 1,  // y write
                        uncoal_ilb: 0,
                        uncoal_ep: 0,
                        tx_per_target_access: tx,
                        // Stage wg_size rows x batch columns of A.
                        region_rows: wg_size as u64,
                        region_cols: batch as u64,
                        reuse: 1.0, // every A element read exactly once
                        offset_bounds: (0, 0, 0, 0),
                        base_regs: 12,
                        opt_extra_regs: 4,
                        launch,
                        wus_per_wi: (size / batch).max(1) as u64,
                    }
                    .build(dev),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::{measure, MeasureConfig};

    #[test]
    fn count_is_120() {
        assert_eq!(instances(&DeviceSpec::m2090()).len(), 120);
    }

    #[test]
    fn table3_row_count_is_exact() {
        let b = crate::workloads::all()
            .into_iter()
            .find(|b| b.name == "MVT")
            .expect("Table 3 row");
        assert_eq!(b.paper_instances, 120);
        assert_eq!((b.instances)(&DeviceSpec::m2090()).len(), b.paper_instances);
    }

    #[test]
    fn kernel1_scattered_kernel2_coalesced() {
        for d in instances(&DeviceSpec::m2090()) {
            if d.name.contains("_k1_") {
                assert!(d.tx_per_target_access > 1.0, "{}", d.name);
            } else {
                assert_eq!(d.tx_per_target_access, 1.0, "{}", d.name);
            }
        }
    }

    #[test]
    fn bimodal_benefit() {
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let (mut k1_wins, mut k1_n, mut k2_wins, mut k2_n) = (0, 0, 0, 0);
        for d in instances(&dev) {
            let r = measure(&d, &dev, &cfg);
            if d.name.contains("_k1_") {
                k1_n += 1;
                k1_wins += r.beneficial() as usize;
            } else {
                k2_n += 1;
                k2_wins += r.beneficial() as usize;
            }
        }
        assert!(k1_wins * 2 > k1_n, "k1: {k1_wins}/{k1_n}");
        assert!(k2_wins * 2 < k2_n, "k2: {k2_wins}/{k2_n}");
    }
}
