//! CART regression tree (the Random-Forest base learner).
//!
//! Variance-reduction splits over a random feature subset per node
//! (`mtry`), grown to purity subject to `min_samples_leaf` — matching
//! Weka's RandomTree as used by the paper (20 trees, 4 attributes/node,
//! unlimited depth).

use crate::util::prng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    Split {
        feature: usize,
        /// Go left iff x[feature] <= threshold.
        threshold: f64,
        left: usize,
        right: usize,
        /// Mean target of the training samples reaching this node (used
        /// when depth-truncating for tensor export).
        mean: f64,
    },
    Leaf {
        value: f64,
    },
}

#[derive(Clone, Debug)]
pub struct Tree {
    /// Node 0 is the root.
    pub nodes: Vec<Node>,
}

#[derive(Clone, Copy, Debug)]
pub struct TreeConfig {
    /// Features considered per split (paper: 4).
    pub mtry: usize,
    /// Minimum samples in a leaf.
    pub min_samples_leaf: usize,
    /// Hard depth cap (large = effectively unlimited).
    pub max_depth: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig { mtry: 4, min_samples_leaf: 1, max_depth: 64 }
    }
}

struct Builder<'a> {
    x: &'a [Vec<f64>], // column-major: x[feature][sample]
    y: &'a [f64],
    cfg: TreeConfig,
    nodes: Vec<Node>,
}

impl Tree {
    /// Fit on (x columns, y) using the provided sample indices (the
    /// bootstrap sample). `x` is column-major: x[f][i] is feature f of
    /// sample i.
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        indices: &mut [usize],
        cfg: TreeConfig,
        rng: &mut Rng,
    ) -> Tree {
        assert!(!x.is_empty() && !indices.is_empty());
        let mut b = Builder { x, y, cfg, nodes: Vec::new() };
        b.nodes.push(Node::Leaf { value: 0.0 }); // placeholder root
        b.grow(0, indices, 0, rng);
        Tree { nodes: b.nodes }
    }

    pub fn predict(&self, features: &[f64]) -> f64 {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right, .. } => {
                    i = if features[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn depth(&self) -> usize {
        self.depth_from(0)
    }

    fn depth_from(&self, i: usize) -> usize {
        match &self.nodes[i] {
            Node::Leaf { .. } => 0,
            Node::Split { left, right, .. } => {
                1 + self.depth_from(*left).max(self.depth_from(*right))
            }
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Structural validity: children in range, exactly one root, no node
    /// reachable twice (tree, not DAG). Used by property tests.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.nodes.len();
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            if i >= n {
                return Err(format!("child {i} out of range {n}"));
            }
            if seen[i] {
                return Err(format!("node {i} reachable twice"));
            }
            seen[i] = true;
            if let Node::Split { left, right, .. } = &self.nodes[i] {
                stack.push(*left);
                stack.push(*right);
            }
        }
        if seen.iter().any(|s| !s) {
            return Err("unreachable nodes".into());
        }
        Ok(())
    }
}

impl<'a> Builder<'a> {
    fn grow(&mut self, node: usize, idx: &mut [usize], depth: usize, rng: &mut Rng) {
        let mean = idx.iter().map(|&i| self.y[i]).sum::<f64>() / idx.len() as f64;

        if idx.len() < 2 * self.cfg.min_samples_leaf || depth >= self.cfg.max_depth {
            self.nodes[node] = Node::Leaf { value: mean };
            return;
        }

        match self.best_split(idx, rng) {
            None => self.nodes[node] = Node::Leaf { value: mean },
            Some((feature, threshold)) => {
                // Partition in place.
                let mid = partition(idx, |i| self.x[feature][i] <= threshold);
                if mid == 0 || mid == idx.len() {
                    self.nodes[node] = Node::Leaf { value: mean };
                    return;
                }
                let left = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                let right = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                self.nodes[node] = Node::Split { feature, threshold, left, right, mean };
                let (l, r) = idx.split_at_mut(mid);
                self.grow(left, l, depth + 1, rng);
                self.grow(right, r, depth + 1, rng);
            }
        }
    }

    /// Best (feature, threshold) by SSE reduction over an `mtry`-subset.
    fn best_split(&self, idx: &[usize], rng: &mut Rng) -> Option<(usize, f64)> {
        let nf = self.x.len();
        let mtry = self.cfg.mtry.min(nf);
        let mut feats = rng.sample_indices(nf, mtry);
        // Deterministic tie-break order.
        feats.sort_unstable();

        let n = idx.len() as f64;
        let sum: f64 = idx.iter().map(|&i| self.y[i]).sum();
        let parent_score = sum * sum / n; // constant term dropped

        let mut best: Option<(f64, usize, f64)> = None;
        let mut order: Vec<usize> = idx.to_vec();
        for &f in &feats {
            let col = &self.x[f];
            order.sort_unstable_by(|&a, &b| {
                col[a].partial_cmp(&col[b]).unwrap()
            });
            let mut lsum = 0.0;
            let mut lcnt = 0.0;
            for w in 0..order.len() - 1 {
                let i = order[w];
                lsum += self.y[i];
                lcnt += 1.0;
                let (a, b) = (col[i], col[order[w + 1]]);
                if a == b {
                    continue; // not a valid cut point
                }
                let lc = lcnt as usize;
                let rc = order.len() - lc;
                if lc < self.cfg.min_samples_leaf || rc < self.cfg.min_samples_leaf {
                    continue;
                }
                let rsum = sum - lsum;
                let score = lsum * lsum / lcnt + rsum * rsum / (n - lcnt);
                let gain = score - parent_score;
                if gain > 1e-12
                    && best.map(|(g, _, _)| gain > g).unwrap_or(true)
                {
                    best = Some((gain, f, 0.5 * (a + b)));
                }
            }
        }
        best.map(|(_, f, t)| (f, t))
    }
}

/// Stable-ish in-place partition; returns the split point.
fn partition<F: Fn(usize) -> bool>(idx: &mut [usize], pred: F) -> usize {
    let mut mid = 0;
    for i in 0..idx.len() {
        if pred(idx[i]) {
            idx.swap(mid, i);
            mid += 1;
        }
    }
    mid
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Column-major x from row-major rows.
    fn columns(rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let nf = rows[0].len();
        (0..nf)
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect()
    }

    fn fit_all(rows: &[Vec<f64>], y: &[f64], cfg: TreeConfig) -> Tree {
        let x = columns(rows);
        let mut idx: Vec<usize> = (0..y.len()).collect();
        let mut rng = Rng::new(77);
        Tree::fit(&x, y, &mut idx, cfg, &mut rng)
    }

    #[test]
    fn fits_a_step_function_exactly() {
        let rows: Vec<Vec<f64>> =
            (0..100).map(|i| vec![i as f64, 0.0]).collect();
        let y: Vec<f64> =
            (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        let cfg = TreeConfig { mtry: 2, min_samples_leaf: 1, max_depth: 16 };
        let t = fit_all(&rows, &y, cfg);
        for i in 0..100 {
            let want = if i < 50 { -1.0 } else { 1.0 };
            assert_eq!(t.predict(&[i as f64, 0.0]), want, "i={i}");
        }
        assert!(t.depth() >= 1);
        t.validate().unwrap();
    }

    #[test]
    fn constant_target_is_single_leaf() {
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![3.25; 20];
        let t = fit_all(&rows, &y, TreeConfig::default());
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict(&[5.0]), 3.25);
    }

    #[test]
    fn min_samples_leaf_respected() {
        let rows: Vec<Vec<f64>> = (0..64).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        let cfg = TreeConfig { mtry: 1, min_samples_leaf: 8, max_depth: 64 };
        let t = fit_all(&rows, &y, cfg);
        // Count samples per leaf by running all points through.
        let mut counts = std::collections::HashMap::new();
        for i in 0..64 {
            let mut node = 0usize;
            loop {
                match &t.nodes[node] {
                    Node::Leaf { .. } => break,
                    Node::Split { feature, threshold, left, right, .. } => {
                        node = if rows[i][*feature] <= *threshold {
                            *left
                        } else {
                            *right
                        };
                    }
                }
            }
            *counts.entry(node).or_insert(0usize) += 1;
        }
        for (_, c) in counts {
            assert!(c >= 8, "leaf with {c} samples");
        }
    }

    #[test]
    fn depth_cap_enforced() {
        let rows: Vec<Vec<f64>> = (0..256).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..256).map(|i| i as f64).collect();
        let cfg = TreeConfig { mtry: 1, min_samples_leaf: 1, max_depth: 3 };
        let t = fit_all(&rows, &y, cfg);
        assert!(t.depth() <= 3);
    }

    #[test]
    fn prediction_reduces_sse_vs_mean() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![rng.next_f64(), rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| 3.0 * r[0] - 2.0 * r[1] + 0.1 * rng.normal())
            .collect();
        let cfg = TreeConfig { mtry: 3, min_samples_leaf: 4, max_depth: 64 };
        let t = fit_all(&rows, &y, cfg);
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let sse_mean: f64 = y.iter().map(|v| (v - mean) * (v - mean)).sum();
        let sse_tree: f64 = rows
            .iter()
            .zip(&y)
            .map(|(r, v)| {
                let p = t.predict(r);
                (v - p) * (v - p)
            })
            .sum();
        assert!(sse_tree < 0.2 * sse_mean, "{sse_tree} vs {sse_mean}");
        t.validate().unwrap();
    }

    #[test]
    fn structure_is_valid_on_random_data() {
        crate::util::prop::check("tree-valid", 20, |rng| {
            let n = 20 + rng.range(0, 200);
            let rows: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![rng.next_f64(), rng.next_f64()])
                .collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = columns(&rows);
            let mut idx: Vec<usize> = (0..n).collect();
            let cfg = TreeConfig { mtry: 2, min_samples_leaf: 2, max_depth: 32 };
            let t = Tree::fit(&x, &y, &mut idx, cfg, rng);
            t.validate()?;
            // predictions must be finite
            for r in rows.iter().take(10) {
                crate::prop_assert!(t.predict(r).is_finite(), "nan pred");
            }
            Ok(())
        });
    }
}
