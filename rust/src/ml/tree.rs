//! CART regression tree (the Random-Forest base learner).
//!
//! Variance-reduction splits over a random feature subset per node
//! (`mtry`), grown to purity subject to `min_samples_leaf` — matching
//! Weka's RandomTree as used by the paper (20 trees, 4 attributes/node,
//! unlimited depth).
//!
//! Two split engines share the growth logic and the scoring rule
//! (SSE reduction with the constant term dropped):
//!
//! * [`SplitEngine::Exact`] — the v1 reference: re-sort the node's
//!   samples per candidate feature and sweep adjacent distinct values
//!   (O(mtry·n log n) per node).
//! * [`SplitEngine::Binned`] — ml-v2, the default: sweep pre-binned
//!   histograms ([`crate::ml::binning`], ≤ 256 quantile bins per
//!   feature) in O(mtry·n) per node, falling back to a sort of the
//!   node's `u8` codes for tiny nodes where zeroing 256 buckets would
//!   dominate. See `binning.rs` for the equivalence contract.
//!
//! Split sweeps order values with `f64::total_cmp`, so a NaN feature
//! value can never panic the trainer (NaN sorts last / bins last and is
//! never a valid cut); rejecting non-finite inputs outright is the job
//! of `Forest::fit_records`.

use super::binning::BinnedDataset;
use crate::util::prng::Rng;

/// How candidate splits are enumerated. `Exact` is the v1 per-node-sort
/// reference engine; `Binned` is the ml-v2 histogram engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitEngine {
    Exact,
    Binned,
}

impl std::fmt::Display for SplitEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SplitEngine::Exact => "exact",
            SplitEngine::Binned => "binned",
        })
    }
}

impl std::str::FromStr for SplitEngine {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "exact" => Ok(SplitEngine::Exact),
            "binned" => Ok(SplitEngine::Binned),
            other => Err(format!("unknown split engine {other:?} (exact|binned)")),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Node {
    Split {
        feature: usize,
        /// Go left iff x[feature] <= threshold.
        threshold: f64,
        left: usize,
        right: usize,
        /// Mean target of the training samples reaching this node (used
        /// when depth-truncating for tensor export).
        mean: f64,
    },
    Leaf {
        value: f64,
    },
}

#[derive(Clone, Debug)]
pub struct Tree {
    /// Node 0 is the root.
    pub nodes: Vec<Node>,
    /// Extra-output planes (multi-output trees, dataset schema v2).
    /// `extra[k][i]` is the mean of extra target `k` over the training
    /// samples reaching node `i` — recorded for *every* node during
    /// growth, so depth-truncating exporters have subtree means, and
    /// read at the leaf reached by `predict`'s traversal. The tree
    /// structure is grown on the primary target only; extra targets
    /// never influence splits (single-output trees are bit-identical
    /// whether or not extras exist). Empty for single-output trees.
    pub extra: Vec<Vec<f64>>,
}

#[derive(Clone, Copy, Debug)]
pub struct TreeConfig {
    /// Features considered per split (paper: 4).
    pub mtry: usize,
    /// Minimum samples in a leaf.
    pub min_samples_leaf: usize,
    /// Hard depth cap (large = effectively unlimited).
    pub max_depth: usize,
    /// Split-candidate enumeration engine.
    pub engine: SplitEngine,
    /// Quantile bins per feature for the binned engine (clamped to
    /// [2, 256]; codes must fit a `u8`).
    pub max_bins: usize,
}

impl Default for TreeConfig {
    fn default() -> Self {
        TreeConfig {
            mtry: 4,
            min_samples_leaf: 1,
            max_depth: 64,
            engine: SplitEngine::Binned,
            max_bins: super::binning::MAX_BINS,
        }
    }
}

struct Builder<'a> {
    x: &'a [Vec<f64>], // column-major: x[feature][sample]
    y: &'a [f64],
    extras: &'a [Vec<f64>],
    cfg: TreeConfig,
    nodes: Vec<Node>,
    extra: Vec<Vec<f64>>,
}

/// Record the per-node extra-target means for `node` (see
/// [`Tree::extra`]). `grow` visits every node index exactly once, so
/// after growth each plane has exactly one value per node.
fn record_extras(
    extras: &[Vec<f64>],
    extra: &mut [Vec<f64>],
    node: usize,
    idx: &[usize],
) {
    for (t, plane) in extras.iter().zip(extra.iter_mut()) {
        let m = idx.iter().map(|&i| t[i]).sum::<f64>() / idx.len() as f64;
        if plane.len() <= node {
            plane.resize(node + 1, 0.0);
        }
        plane[node] = m;
    }
}

impl Tree {
    /// Fit on (x columns, y) using the provided sample indices (the
    /// bootstrap sample). `x` is column-major: x[f][i] is feature f of
    /// sample i. Dispatches on `cfg.engine`; with `Binned` a private
    /// binning is built for this tree — forests bin once and call
    /// [`Tree::fit_with_bins`] directly instead.
    pub fn fit(
        x: &[Vec<f64>],
        y: &[f64],
        indices: &mut [usize],
        cfg: TreeConfig,
        rng: &mut Rng,
    ) -> Tree {
        Tree::fit_multi(x, y, &[], indices, cfg, rng)
    }

    /// Multi-output fit: grow on the primary target `y` exactly as
    /// [`Tree::fit`] (same splits, same RNG stream), recording per-node
    /// means of each extra target column (`extras[k][i]` = target k of
    /// sample i) along the way.
    pub fn fit_multi(
        x: &[Vec<f64>],
        y: &[f64],
        extras: &[Vec<f64>],
        indices: &mut [usize],
        cfg: TreeConfig,
        rng: &mut Rng,
    ) -> Tree {
        assert!(!x.is_empty() && !indices.is_empty());
        match cfg.engine {
            SplitEngine::Exact => {
                let mut b = Builder {
                    x,
                    y,
                    extras,
                    cfg,
                    nodes: Vec::new(),
                    extra: vec![Vec::new(); extras.len()],
                };
                b.nodes.push(Node::Leaf { value: 0.0 }); // placeholder root
                b.grow(0, indices, 0, rng);
                Tree { nodes: b.nodes, extra: b.extra }
            }
            SplitEngine::Binned => {
                let bins = BinnedDataset::build(x, cfg.max_bins);
                Tree::fit_with_bins_multi(&bins, y, extras, indices, cfg, rng)
            }
        }
    }

    /// Fit against a pre-binned dataset (`ml::binning`). Thresholds
    /// stored on split nodes are raw feature-space cut values, so the
    /// resulting tree predicts on unbinned feature vectors.
    pub fn fit_with_bins(
        bins: &BinnedDataset,
        y: &[f64],
        indices: &mut [usize],
        cfg: TreeConfig,
        rng: &mut Rng,
    ) -> Tree {
        Tree::fit_with_bins_multi(bins, y, &[], indices, cfg, rng)
    }

    /// Multi-output variant of [`Tree::fit_with_bins`]; see
    /// [`Tree::fit_multi`].
    pub fn fit_with_bins_multi(
        bins: &BinnedDataset,
        y: &[f64],
        extras: &[Vec<f64>],
        indices: &mut [usize],
        cfg: TreeConfig,
        rng: &mut Rng,
    ) -> Tree {
        assert!(bins.num_features() > 0 && !indices.is_empty());
        let nb = bins.max_bins_used();
        let mut b = BinnedBuilder {
            bins,
            y,
            extras,
            cfg,
            nodes: Vec::new(),
            extra: vec![Vec::new(); extras.len()],
            cnt: vec![0u32; nb],
            sum: vec![0.0f64; nb],
        };
        b.nodes.push(Node::Leaf { value: 0.0 }); // placeholder root
        b.grow(0, indices, 0, rng);
        Tree { nodes: b.nodes, extra: b.extra }
    }

    pub fn predict(&self, features: &[f64]) -> f64 {
        match &self.nodes[self.leaf_index(features)] {
            Node::Leaf { value } => *value,
            Node::Split { .. } => unreachable!("leaf_index returned a split"),
        }
    }

    /// Index of the leaf `features` routes to (shared by the primary
    /// prediction and every extra-output read, so all outputs come from
    /// one traversal-consistent node).
    pub fn leaf_index(&self, features: &[f64]) -> usize {
        let mut i = 0;
        loop {
            match &self.nodes[i] {
                Node::Leaf { .. } => return i,
                Node::Split { feature, threshold, left, right, .. } => {
                    i = if features[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    /// Outputs this tree produces: the primary target plus the extra
    /// planes.
    pub fn num_outputs(&self) -> usize {
        1 + self.extra.len()
    }

    /// Predict extra output `k` (0-based among the extras): the mean of
    /// extra target `k` at the leaf `features` routes to.
    pub fn predict_extra(&self, features: &[f64], k: usize) -> f64 {
        self.extra[k][self.leaf_index(features)]
    }

    pub fn depth(&self) -> usize {
        self.depth_from(0)
    }

    fn depth_from(&self, i: usize) -> usize {
        match &self.nodes[i] {
            Node::Leaf { .. } => 0,
            Node::Split { left, right, .. } => {
                1 + self.depth_from(*left).max(self.depth_from(*right))
            }
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| matches!(n, Node::Leaf { .. }))
            .count()
    }

    /// Structural validity: children in range, exactly one root, no node
    /// reachable twice (tree, not DAG). Used by property tests.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.nodes.len();
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            if i >= n {
                return Err(format!("child {i} out of range {n}"));
            }
            if seen[i] {
                return Err(format!("node {i} reachable twice"));
            }
            seen[i] = true;
            if let Node::Split { left, right, .. } = &self.nodes[i] {
                stack.push(*left);
                stack.push(*right);
            }
        }
        if seen.iter().any(|s| !s) {
            return Err("unreachable nodes".into());
        }
        for (k, plane) in self.extra.iter().enumerate() {
            if plane.len() != n {
                return Err(format!(
                    "extra plane {k} has {} values for {n} nodes",
                    plane.len()
                ));
            }
        }
        Ok(())
    }
}

impl<'a> Builder<'a> {
    fn grow(&mut self, node: usize, idx: &mut [usize], depth: usize, rng: &mut Rng) {
        let mean = idx.iter().map(|&i| self.y[i]).sum::<f64>() / idx.len() as f64;
        record_extras(self.extras, &mut self.extra, node, idx);

        if idx.len() < 2 * self.cfg.min_samples_leaf || depth >= self.cfg.max_depth {
            self.nodes[node] = Node::Leaf { value: mean };
            return;
        }

        match self.best_split(idx, rng) {
            None => self.nodes[node] = Node::Leaf { value: mean },
            Some((feature, threshold)) => {
                // Partition in place.
                let col = &self.x[feature];
                let mid = partition(idx, |i| col[i] <= threshold);
                if mid == 0 || mid == idx.len() {
                    self.nodes[node] = Node::Leaf { value: mean };
                    return;
                }
                let left = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                let right = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                self.nodes[node] = Node::Split { feature, threshold, left, right, mean };
                let (l, r) = idx.split_at_mut(mid);
                self.grow(left, l, depth + 1, rng);
                self.grow(right, r, depth + 1, rng);
            }
        }
    }

    /// Best (feature, threshold) by SSE reduction over an `mtry`-subset.
    fn best_split(&self, idx: &[usize], rng: &mut Rng) -> Option<(usize, f64)> {
        let nf = self.x.len();
        let mtry = self.cfg.mtry.min(nf);
        let mut feats = rng.sample_indices(nf, mtry);
        // Deterministic tie-break order.
        feats.sort_unstable();

        let n = idx.len() as f64;
        let sum: f64 = idx.iter().map(|&i| self.y[i]).sum();
        let parent_score = sum * sum / n; // constant term dropped

        let mut best: Option<(f64, usize, f64)> = None;
        let mut order: Vec<usize> = idx.to_vec();
        for &f in &feats {
            let col = &self.x[f];
            // total_cmp: NaN sorts last and can never panic the sweep.
            order.sort_unstable_by(|&a, &b| col[a].total_cmp(&col[b]));
            let mut lsum = 0.0;
            let mut lcnt = 0.0;
            for w in 0..order.len() - 1 {
                let i = order[w];
                lsum += self.y[i];
                lcnt += 1.0;
                let (a, b) = (col[i], col[order[w + 1]]);
                if !(a < b) {
                    continue; // equal (or NaN-adjacent): not a valid cut
                }
                let lc = lcnt as usize;
                let rc = order.len() - lc;
                if lc < self.cfg.min_samples_leaf || rc < self.cfg.min_samples_leaf {
                    continue;
                }
                let rsum = sum - lsum;
                let score = lsum * lsum / lcnt + rsum * rsum / (n - lcnt);
                let gain = score - parent_score;
                if gain > 1e-12
                    && best.map(|(g, _, _)| gain > g).unwrap_or(true)
                {
                    best = Some((gain, f, 0.5 * (a + b)));
                }
            }
        }
        best.map(|(_, f, t)| (f, t))
    }
}

/// Node sizes below this use a sort of the node's `u8` codes instead of
/// the bucket sweep: for tiny nodes, zeroing and scanning up to 256
/// buckets per candidate feature costs more than sorting the handful of
/// codes. Both paths enumerate the same candidates with the same scores.
const SORT_SWEEP_CUTOFF: usize = 128;

struct BinnedBuilder<'a> {
    bins: &'a BinnedDataset,
    y: &'a [f64],
    extras: &'a [Vec<f64>],
    cfg: TreeConfig,
    nodes: Vec<Node>,
    extra: Vec<Vec<f64>>,
    /// Per-bin sample counts, reused across nodes (zeroed per feature).
    cnt: Vec<u32>,
    /// Per-bin target sums, reused across nodes.
    sum: Vec<f64>,
}

impl<'a> BinnedBuilder<'a> {
    fn grow(&mut self, node: usize, idx: &mut [usize], depth: usize, rng: &mut Rng) {
        let y = self.y;
        let mean = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
        record_extras(self.extras, &mut self.extra, node, idx);

        if idx.len() < 2 * self.cfg.min_samples_leaf || depth >= self.cfg.max_depth {
            self.nodes[node] = Node::Leaf { value: mean };
            return;
        }

        match self.best_split(idx, rng) {
            None => self.nodes[node] = Node::Leaf { value: mean },
            Some((feature, bin)) => {
                let threshold = self.bins.features[feature].cuts[bin];
                let codes = &self.bins.codes[feature];
                // code <= bin  iff  x <= cuts[bin] (binning.rs), so the
                // u8 partition is the raw-threshold partition.
                let mid = partition(idx, |i| codes[i] as usize <= bin);
                if mid == 0 || mid == idx.len() {
                    self.nodes[node] = Node::Leaf { value: mean };
                    return;
                }
                let left = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                let right = self.nodes.len();
                self.nodes.push(Node::Leaf { value: 0.0 });
                self.nodes[node] = Node::Split { feature, threshold, left, right, mean };
                let (l, r) = idx.split_at_mut(mid);
                self.grow(left, l, depth + 1, rng);
                self.grow(right, r, depth + 1, rng);
            }
        }
    }

    /// Best (feature, left-bin) by the same SSE-reduction score as the
    /// exact engine. A candidate cut sits right after every non-empty
    /// bin with a non-empty remainder, i.e. between adjacent *present*
    /// codes — exactly the exact engine's adjacent-distinct-values rule,
    /// restricted to bin boundaries.
    fn best_split(&mut self, idx: &[usize], rng: &mut Rng) -> Option<(usize, usize)> {
        let bins = self.bins;
        let y = self.y;
        let nf = bins.num_features();
        let mtry = self.cfg.mtry.min(nf);
        let mut feats = rng.sample_indices(nf, mtry);
        // Deterministic tie-break order.
        feats.sort_unstable();

        let n = idx.len() as f64;
        let sum: f64 = idx.iter().map(|&i| y[i]).sum();
        let parent_score = sum * sum / n; // constant term dropped
        let min_leaf = self.cfg.min_samples_leaf;

        let sorted_path = idx.len() < SORT_SWEEP_CUTOFF;
        let mut order: Vec<usize> = if sorted_path { idx.to_vec() } else { Vec::new() };

        let mut best: Option<(f64, usize, usize)> = None;
        for &f in &feats {
            let fb = &bins.features[f];
            let nb = fb.num_bins();
            if nb < 2 {
                continue; // constant column: nothing to cut
            }
            let codes = &bins.codes[f];
            if sorted_path {
                // Sweep the node's codes in sorted order (u8 keys).
                order.sort_unstable_by_key(|&i| codes[i]);
                let mut lsum = 0.0;
                let mut lcnt = 0usize;
                for w in 0..order.len() - 1 {
                    let i = order[w];
                    lsum += y[i];
                    lcnt += 1;
                    let (a, b) = (codes[i], codes[order[w + 1]]);
                    if a == b {
                        continue; // not a bin boundary
                    }
                    if lcnt < min_leaf || order.len() - lcnt < min_leaf {
                        continue;
                    }
                    let lc = lcnt as f64;
                    let rsum = sum - lsum;
                    let score = lsum * lsum / lc + rsum * rsum / (n - lc);
                    let gain = score - parent_score;
                    if gain > 1e-12
                        && best.map(|(g, _, _)| gain > g).unwrap_or(true)
                    {
                        best = Some((gain, f, a as usize));
                    }
                }
            } else {
                // Bucket sweep: one histogram pass, then a walk over the
                // (≤ 256) bins.
                for b in 0..nb {
                    self.cnt[b] = 0;
                    self.sum[b] = 0.0;
                }
                for &i in idx.iter() {
                    let c = codes[i] as usize;
                    self.cnt[c] += 1;
                    self.sum[c] += y[i];
                }
                let mut lsum = 0.0;
                let mut lcnt = 0usize;
                for b in 0..nb - 1 {
                    lcnt += self.cnt[b] as usize;
                    lsum += self.sum[b];
                    if self.cnt[b] == 0 {
                        continue; // same partition as an earlier boundary
                    }
                    if lcnt == idx.len() {
                        break; // nothing left on the right
                    }
                    if lcnt < min_leaf || idx.len() - lcnt < min_leaf {
                        continue;
                    }
                    let lc = lcnt as f64;
                    let rsum = sum - lsum;
                    let score = lsum * lsum / lc + rsum * rsum / (n - lc);
                    let gain = score - parent_score;
                    if gain > 1e-12
                        && best.map(|(g, _, _)| gain > g).unwrap_or(true)
                    {
                        best = Some((gain, f, b));
                    }
                }
            }
        }
        best.map(|(_, f, b)| (f, b))
    }
}

/// Stable-ish in-place partition; returns the split point.
fn partition<F: Fn(usize) -> bool>(idx: &mut [usize], pred: F) -> usize {
    let mut mid = 0;
    for i in 0..idx.len() {
        if pred(idx[i]) {
            idx.swap(mid, i);
            mid += 1;
        }
    }
    mid
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Column-major x from row-major rows.
    fn columns(rows: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let nf = rows[0].len();
        (0..nf)
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect()
    }

    fn fit_all(rows: &[Vec<f64>], y: &[f64], cfg: TreeConfig) -> Tree {
        let x = columns(rows);
        let mut idx: Vec<usize> = (0..y.len()).collect();
        let mut rng = Rng::new(77);
        Tree::fit(&x, y, &mut idx, cfg, &mut rng)
    }

    fn both_engines(cfg: TreeConfig) -> [TreeConfig; 2] {
        [
            TreeConfig { engine: SplitEngine::Exact, ..cfg },
            TreeConfig { engine: SplitEngine::Binned, ..cfg },
        ]
    }

    #[test]
    fn fits_a_step_function_exactly() {
        let rows: Vec<Vec<f64>> =
            (0..100).map(|i| vec![i as f64, 0.0]).collect();
        let y: Vec<f64> =
            (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        for cfg in both_engines(TreeConfig {
            mtry: 2,
            min_samples_leaf: 1,
            max_depth: 16,
            ..TreeConfig::default()
        }) {
            let t = fit_all(&rows, &y, cfg);
            for i in 0..100 {
                let want = if i < 50 { -1.0 } else { 1.0 };
                assert_eq!(t.predict(&[i as f64, 0.0]), want, "i={i} {}", cfg.engine);
            }
            assert!(t.depth() >= 1);
            t.validate().unwrap();
        }
    }

    #[test]
    fn constant_target_is_single_leaf() {
        let rows: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![3.25; 20];
        for cfg in both_engines(TreeConfig::default()) {
            let t = fit_all(&rows, &y, cfg);
            assert_eq!(t.nodes.len(), 1, "{}", cfg.engine);
            assert_eq!(t.predict(&[5.0]), 3.25);
        }
    }

    #[test]
    fn min_samples_leaf_respected() {
        let rows: Vec<Vec<f64>> = (0..64).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        for cfg in both_engines(TreeConfig {
            mtry: 1,
            min_samples_leaf: 8,
            ..TreeConfig::default()
        }) {
            let t = fit_all(&rows, &y, cfg);
            // Count samples per leaf by running all points through.
            let mut counts = std::collections::HashMap::new();
            for i in 0..64 {
                let mut node = 0usize;
                loop {
                    match &t.nodes[node] {
                        Node::Leaf { .. } => break,
                        Node::Split { feature, threshold, left, right, .. } => {
                            node = if rows[i][*feature] <= *threshold {
                                *left
                            } else {
                                *right
                            };
                        }
                    }
                }
                *counts.entry(node).or_insert(0usize) += 1;
            }
            for (_, c) in counts {
                assert!(c >= 8, "leaf with {c} samples ({})", cfg.engine);
            }
        }
    }

    #[test]
    fn depth_cap_enforced() {
        let rows: Vec<Vec<f64>> = (0..256).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..256).map(|i| i as f64).collect();
        for cfg in both_engines(TreeConfig {
            mtry: 1,
            max_depth: 3,
            ..TreeConfig::default()
        }) {
            let t = fit_all(&rows, &y, cfg);
            assert!(t.depth() <= 3, "{}", cfg.engine);
        }
    }

    #[test]
    fn prediction_reduces_sse_vs_mean() {
        let mut rng = Rng::new(5);
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| vec![rng.next_f64(), rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| 3.0 * r[0] - 2.0 * r[1] + 0.1 * rng.normal())
            .collect();
        let mean = y.iter().sum::<f64>() / y.len() as f64;
        let sse_mean: f64 = y.iter().map(|v| (v - mean) * (v - mean)).sum();
        for cfg in both_engines(TreeConfig {
            mtry: 3,
            min_samples_leaf: 4,
            ..TreeConfig::default()
        }) {
            let t = fit_all(&rows, &y, cfg);
            let sse_tree: f64 = rows
                .iter()
                .zip(&y)
                .map(|(r, v)| {
                    let p = t.predict(r);
                    (v - p) * (v - p)
                })
                .sum();
            assert!(sse_tree < 0.2 * sse_mean, "{sse_tree} vs {sse_mean} ({})", cfg.engine);
            t.validate().unwrap();
        }
    }

    #[test]
    fn structure_is_valid_on_random_data() {
        crate::util::prop::check("tree-valid", 20, |rng| {
            let n = 20 + rng.range(0, 200);
            let rows: Vec<Vec<f64>> = (0..n)
                .map(|_| vec![rng.next_f64(), rng.next_f64()])
                .collect();
            let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let x = columns(&rows);
            for engine in [SplitEngine::Exact, SplitEngine::Binned] {
                let mut idx: Vec<usize> = (0..n).collect();
                let cfg = TreeConfig {
                    mtry: 2,
                    min_samples_leaf: 2,
                    max_depth: 32,
                    engine,
                    ..TreeConfig::default()
                };
                let mut trng = rng.fork(engine as u64);
                let t = Tree::fit(&x, &y, &mut idx, cfg, &mut trng);
                t.validate()?;
                // predictions must be finite
                for r in rows.iter().take(10) {
                    crate::prop_assert!(t.predict(r).is_finite(), "nan pred");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn binned_matches_exact_when_values_fit_the_bins() {
        // One sample per distinct value and splits confined to feature 0:
        // every node's value range stays contiguous, so the exact
        // engine's node-local midpoints coincide with the global bin
        // cuts and the two engines grow *identical* trees.
        let rows: Vec<Vec<f64>> =
            (0..200).map(|i| vec![i as f64, 0.0]).collect();
        let y: Vec<f64> = (0..200)
            .map(|i| match i {
                0..=49 => -2.0,
                50..=119 => 0.5,
                _ => 3.0,
            })
            .collect();
        let cfg = TreeConfig { mtry: 2, ..TreeConfig::default() };
        let [ce, cb] = both_engines(cfg);
        let te = fit_all(&rows, &y, ce);
        let tb = fit_all(&rows, &y, cb);
        assert_eq!(te.nodes, tb.nodes);
    }

    #[test]
    fn nan_feature_values_do_not_panic_either_engine() {
        // Regression for the partial_cmp().unwrap() panic at the old
        // tree.rs:179: a poisoned feature value must not abort the fit.
        let mut rows: Vec<Vec<f64>> =
            (0..50).map(|i| vec![i as f64, (i % 5) as f64]).collect();
        rows[17][0] = f64::NAN;
        rows[31][1] = f64::NAN;
        let y: Vec<f64> = (0..50).map(|i| if i < 25 { -1.0 } else { 1.0 }).collect();
        for cfg in both_engines(TreeConfig { mtry: 2, ..TreeConfig::default() }) {
            let t = fit_all(&rows, &y, cfg);
            t.validate().unwrap();
            assert!(t.predict(&[3.0, 1.0]).is_finite());
        }
    }

    #[test]
    fn fit_with_bins_matches_fit_binned_dispatch() {
        // Tree::fit with the binned engine must equal building the
        // binning by hand and calling fit_with_bins (the forest path).
        let mut rng = Rng::new(21);
        let rows: Vec<Vec<f64>> = (0..300)
            .map(|_| vec![rng.range_f64(-1.0, 1.0), rng.range_f64(-1.0, 1.0)])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] * r[1]).collect();
        let x = columns(&rows);
        let cfg = TreeConfig { mtry: 2, ..TreeConfig::default() };
        let mut idx_a: Vec<usize> = (0..300).collect();
        let mut idx_b: Vec<usize> = (0..300).collect();
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let a = Tree::fit(&x, &y, &mut idx_a, cfg, &mut rng_a);
        let bins = crate::ml::binning::BinnedDataset::build(&x, cfg.max_bins);
        let b = Tree::fit_with_bins(&bins, &y, &mut idx_b, cfg, &mut rng_b);
        assert_eq!(a.nodes, b.nodes);
    }

    #[test]
    fn multi_output_fit_shares_structure_and_records_leaf_means() {
        let mut rng = Rng::new(31);
        let rows: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| r[0] - r[1]).collect();
        let e0: Vec<f64> = rows.iter().map(|r| 2.0 * r[0]).collect();
        let e1: Vec<f64> = rows.iter().map(|r| r[1] + 5.0).collect();
        let x = columns(&rows);
        for cfg in both_engines(TreeConfig {
            mtry: 2,
            min_samples_leaf: 4,
            ..TreeConfig::default()
        }) {
            let mut idx_a: Vec<usize> = (0..200).collect();
            let mut idx_b: Vec<usize> = (0..200).collect();
            let single = Tree::fit(&x, &y, &mut idx_a, cfg, &mut Rng::new(9));
            let multi = Tree::fit_multi(
                &x,
                &y,
                &[e0.clone(), e1.clone()],
                &mut idx_b,
                cfg,
                &mut Rng::new(9),
            );
            // extras never influence structure or the primary output
            assert_eq!(single.nodes, multi.nodes, "{}", cfg.engine);
            assert_eq!(single.num_outputs(), 1);
            assert_eq!(multi.num_outputs(), 3);
            multi.validate().unwrap();

            // every extra read is the mean of that target over the
            // samples routed to the same leaf
            for probe in rows.iter().take(20) {
                let leaf = multi.leaf_index(probe);
                let members: Vec<usize> = (0..rows.len())
                    .filter(|&i| multi.leaf_index(&rows[i]) == leaf)
                    .collect();
                for (k, t) in [&e0, &e1].iter().enumerate() {
                    let want = members.iter().map(|&i| t[i]).sum::<f64>()
                        / members.len() as f64;
                    let got = multi.predict_extra(probe, k);
                    assert!((got - want).abs() < 1e-9, "{} k={k}", cfg.engine);
                }
            }
        }
    }
}
