//! Tensor encoding of a trained forest for the L1/L2 inference path.
//!
//! The Pallas kernel (python/compile/kernels/forest.py) traverses padded
//! per-tree node tables: feat_idx/thresh/left/right/leaf, each [T, N],
//! with leaves self-looping so a fixed-depth traversal is exact. This
//! module flattens `ml::forest::Forest` into that contract, truncating
//! over-budget subtrees to leaves that predict the subtree's training
//! mean (stored on every split node at fit time).

use super::forest::Forest;
use super::tree::{Node, Tree};

/// Sizing contract shared with the AOT artifacts. Must match
/// `python/compile/config.py` (checked at runtime against manifest.json).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExportContract {
    pub num_trees: usize,
    pub max_nodes: usize,
    pub max_depth: usize,
    pub num_features: usize,
}

impl Default for ExportContract {
    fn default() -> Self {
        ExportContract {
            num_trees: 20,
            max_nodes: 8192,
            max_depth: 32,
            num_features: crate::kernelmodel::features::NUM_FEATURES,
        }
    }
}

/// Flattened forest, ready to feed PJRT as literals.
#[derive(Clone, Debug)]
pub struct EncodedForest {
    pub contract: ExportContract,
    /// [T * N], row-major by tree.
    pub feat_idx: Vec<i32>,
    pub thresh: Vec<f32>,
    pub left: Vec<i32>,
    pub right: Vec<i32>,
    pub leaf: Vec<f32>,
    /// Extra-output leaf planes (joint forests, dataset schema v2): one
    /// [T * N] tensor per extra output, indexed exactly like `leaf` and
    /// filled at the same leaf/truncation sites, so every output of a
    /// prediction comes from one traversal.
    pub extra: Vec<Vec<f32>>,
    /// How many split nodes were truncated to leaves during export.
    pub truncated: usize,
}

impl EncodedForest {
    /// Traverse one tree to its leaf's flat index. This is THE shared
    /// predict kernel: the scalar path, the native batch executor, and
    /// (semantically) the Pallas kernel all implement this exact
    /// traversal. Leaves self-loop, so stopping early at a self-loop is
    /// equivalent to the kernel's fixed-depth walk.
    #[inline]
    fn tree_leaf_index(&self, tree: usize, features: &[f64]) -> usize {
        let n = self.contract.max_nodes;
        let base = tree * n;
        let mut node = 0usize;
        for _ in 0..self.contract.max_depth {
            let l = self.left[base + node] as usize;
            let r = self.right[base + node] as usize;
            if l == node && r == node {
                break; // leaf reached (padded trees stop at the root)
            }
            let fi = self.feat_idx[base + node] as usize;
            let go_left = (features[fi] as f32) <= self.thresh[base + node];
            node = if go_left { l } else { r };
        }
        base + node
    }

    /// Pure-rust reference of the encoded traversal — must agree with the
    /// Pallas kernel and (modulo truncation) with `Forest::predict`.
    pub fn predict(&self, features: &[f64]) -> f64 {
        let mut total = 0.0;
        for t in 0..self.contract.num_trees {
            total += self.leaf[self.tree_leaf_index(t, features)] as f64;
        }
        total / self.contract.num_trees as f64
    }

    pub fn decide(&self, features: &[f64]) -> bool {
        self.predict(features) > 0.0
    }

    /// Outputs per prediction: 1 + extra planes (matches
    /// `Forest::num_outputs` of the encoded forest).
    pub fn num_outputs(&self) -> usize {
        1 + self.extra.len()
    }

    /// Predicted extra output `k` (0-based among the extras), same
    /// traversal and padded-tree scale correction as `predict`. This is
    /// the historical per-plane path; [`Self::predict_outputs`] reads
    /// every plane from one shared traversal instead.
    pub fn predict_extra(&self, features: &[f64], k: usize) -> f64 {
        let plane = &self.extra[k];
        let mut total = 0.0;
        for t in 0..self.contract.num_trees {
            total += plane[self.tree_leaf_index(t, features)] as f64;
        }
        total / self.contract.num_trees as f64
    }

    /// All `num_outputs()` predictions from a single traversal: each
    /// tree's leaf index is computed once and every output plane is read
    /// at it. Per-plane sums run in the same tree order as `predict` /
    /// `predict_extra`, so the results are bit-identical to the
    /// per-plane walks — just without re-traversing per output.
    pub fn predict_outputs(&self, features: &[f64]) -> Vec<f64> {
        let k = self.num_outputs();
        let mut totals = vec![0.0f64; k];
        for t in 0..self.contract.num_trees {
            let li = self.tree_leaf_index(t, features);
            totals[0] += self.leaf[li] as f64;
            for (j, plane) in self.extra.iter().enumerate() {
                totals[1 + j] += plane[li] as f64;
            }
        }
        let trees = self.contract.num_trees as f64;
        for v in totals.iter_mut() {
            *v /= trees;
        }
        totals
    }

    /// Joint forests: predicted (log2 wg_w, log2 wg_h); `None` when the
    /// encoding carries no workgroup outputs. Single traversal shared
    /// with the verdict plane (see `predict_outputs`).
    pub fn predict_wg_logs(&self, features: &[f64]) -> Option<(f64, f64)> {
        if self.num_outputs() < 3 {
            return None;
        }
        let out = self.predict_outputs(features);
        Some((out[1], out[2]))
    }

    /// Validity: children in range, leaves self-loop, reachable depth
    /// bounded by the contract, feature indices within
    /// `contract.num_features`, thresholds finite. A corrupt model that
    /// slips past `ml::io::load` (e.g. a feature index beyond the
    /// contract) must fail here with a typed message, not panic or
    /// mispredict at traversal time.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.contract.max_nodes;
        for (k, plane) in self.extra.iter().enumerate() {
            if plane.len() != self.contract.num_trees * n {
                return Err(format!(
                    "extra plane {k}: {} values, contract needs {}",
                    plane.len(),
                    self.contract.num_trees * n
                ));
            }
        }
        for t in 0..self.contract.num_trees {
            let base = t * n;
            for i in 0..n {
                let (l, r) = (self.left[base + i], self.right[base + i]);
                if l < 0 || r < 0 || l as usize >= n || r as usize >= n {
                    return Err(format!("tree {t} node {i}: child out of range"));
                }
                let f = self.feat_idx[base + i];
                if f < 0 || f as usize >= self.contract.num_features {
                    return Err(format!(
                        "tree {t} node {i}: feature index {f} out of range \
                         (contract has {} features)",
                        self.contract.num_features
                    ));
                }
                let th = self.thresh[base + i];
                if !th.is_finite() {
                    return Err(format!(
                        "tree {t} node {i}: non-finite threshold {th}"
                    ));
                }
            }
            // walk from root: depth of every reachable leaf <= max_depth
            let mut stack = vec![(0usize, 0usize)];
            while let Some((i, d)) = stack.pop() {
                let (l, r) =
                    (self.left[base + i] as usize, self.right[base + i] as usize);
                if l == i && r == i {
                    continue; // leaf
                }
                if d >= self.contract.max_depth {
                    return Err(format!("tree {t}: split deeper than contract"));
                }
                stack.push((l, d + 1));
                stack.push((r, d + 1));
            }
        }
        Ok(())
    }
}

/// Encode a forest under the contract. Panics if the forest has more
/// trees than the contract (pad smaller forests with zero-leaf trees).
pub fn encode(forest: &Forest, contract: ExportContract) -> EncodedForest {
    assert!(
        forest.trees.len() <= contract.num_trees,
        "forest has {} trees, contract allows {}",
        forest.trees.len(),
        contract.num_trees
    );
    let n = contract.max_nodes;
    let t = contract.num_trees;
    let num_extra = forest.num_outputs() - 1;
    let mut enc = EncodedForest {
        contract,
        feat_idx: vec![0; t * n],
        thresh: vec![0.0; t * n],
        left: Vec::with_capacity(t * n),
        right: Vec::with_capacity(t * n),
        leaf: vec![0.0; t * n],
        extra: vec![vec![0.0; t * n]; num_extra],
        truncated: 0,
    };
    // Default: every node is a self-looping zero leaf.
    for _ in 0..t {
        for i in 0..n {
            enc.left.push(i as i32);
            enc.right.push(i as i32);
        }
    }
    // NOTE: when forest.trees.len() < t, the padded zero-leaf trees would
    // bias the mean; scale real leaves so the sum/t matches the true mean.
    let scale = t as f64 / forest.trees.len() as f64;
    for (ti, tree) in forest.trees.iter().enumerate() {
        let truncated = encode_tree(tree, ti, scale as f32, &mut enc);
        enc.truncated += truncated;
    }
    enc
}

/// DFS-encode one tree into slot `ti`; returns #truncated splits.
fn encode_tree(tree: &Tree, ti: usize, scale: f32, enc: &mut EncodedForest) -> usize {
    let n = enc.contract.max_nodes;
    let base = ti * n;
    let mut next_free = 1usize; // slot 0 = root
    let mut truncated = 0usize;
    // stack of (source node, dest slot, depth)
    let mut stack = vec![(0usize, 0usize, 0usize)];
    while let Some((src, dst, depth)) = stack.pop() {
        match &tree.nodes[src] {
            Node::Leaf { value } => {
                enc.leaf[base + dst] = *value as f32 * scale;
                enc.left[base + dst] = dst as i32;
                enc.right[base + dst] = dst as i32;
                for (k, plane) in tree.extra.iter().enumerate() {
                    enc.extra[k][base + dst] = plane[src] as f32 * scale;
                }
            }
            Node::Split { feature, threshold, left, right, mean } => {
                let out_of_budget = next_free + 2 > n;
                let out_of_depth = depth + 1 > enc.contract.max_depth;
                if out_of_budget || out_of_depth {
                    // Truncate: leaf predicting the subtree's training mean.
                    // `tree.extra` holds a value for every node (splits
                    // included) precisely so truncation has subtree means
                    // for the extra outputs too.
                    truncated += 1;
                    enc.leaf[base + dst] = *mean as f32 * scale;
                    enc.left[base + dst] = dst as i32;
                    enc.right[base + dst] = dst as i32;
                    for (k, plane) in tree.extra.iter().enumerate() {
                        enc.extra[k][base + dst] = plane[src] as f32 * scale;
                    }
                } else {
                    let l = next_free;
                    let r = next_free + 1;
                    next_free += 2;
                    enc.feat_idx[base + dst] = *feature as i32;
                    enc.thresh[base + dst] = *threshold as f32;
                    enc.left[base + dst] = l as i32;
                    enc.right[base + dst] = r as i32;
                    stack.push((*left, l, depth + 1));
                    stack.push((*right, r, depth + 1));
                }
            }
        }
    }
    truncated
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::forest::ForestConfig;
    use crate::util::prng::Rng;

    fn toy_forest(trees: usize) -> (Forest, Vec<Vec<f64>>) {
        let mut rng = Rng::new(31);
        let rows: Vec<Vec<f64>> = (0..400)
            .map(|_| {
                (0..crate::kernelmodel::features::NUM_FEATURES)
                    .map(|_| rng.range_f64(-1.0, 1.0))
                    .collect()
            })
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| if r[0] + r[3] > 0.0 { 1.0 } else { -1.0 })
            .collect();
        let x: Vec<Vec<f64>> = (0..rows[0].len())
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect();
        let cfg = ForestConfig { num_trees: trees, threads: 2, ..Default::default() };
        (Forest::fit(&x, &y, &cfg), rows)
    }

    #[test]
    fn encoded_matches_native_when_untruncated() {
        let (f, rows) = toy_forest(5);
        let contract = ExportContract {
            num_trees: 5,
            max_nodes: 8192,
            max_depth: 64,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        assert_eq!(enc.truncated, 0);
        enc.validate().unwrap();
        for r in rows.iter().take(50) {
            let a = f.predict(r);
            let b = enc.predict(r);
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn truncation_degrades_gracefully() {
        let (f, rows) = toy_forest(5);
        let contract = ExportContract {
            num_trees: 5,
            max_nodes: 16, // force truncation
            max_depth: 3,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        assert!(enc.truncated > 0);
        enc.validate().unwrap();
        // Decisions still mostly agree away from the boundary.
        let mut agree = 0;
        let mut total = 0;
        for r in rows.iter().take(200) {
            if f.predict(r).abs() < 0.4 {
                continue;
            }
            total += 1;
            if enc.decide(r) == f.decide(r) {
                agree += 1;
            }
        }
        assert!(
            agree as f64 / total.max(1) as f64 > 0.8,
            "{agree}/{total}"
        );
    }

    #[test]
    fn padded_trees_scale_correction() {
        let (f, rows) = toy_forest(5);
        let contract = ExportContract {
            num_trees: 20, // 15 padded zero trees
            max_nodes: 8192,
            max_depth: 64,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        enc.validate().unwrap();
        for r in rows.iter().take(50) {
            let a = f.predict(r);
            let b = enc.predict(r);
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "contract allows")]
    fn too_many_trees_panics() {
        let (f, _) = toy_forest(5);
        let contract = ExportContract { num_trees: 3, ..Default::default() };
        encode(&f, contract);
    }

    fn toy_joint_forest(trees: usize) -> (Forest, Vec<Vec<f64>>) {
        let mut rng = Rng::new(47);
        let rows: Vec<Vec<f64>> = (0..400)
            .map(|_| {
                (0..crate::kernelmodel::features::NUM_FEATURES)
                    .map(|_| rng.range_f64(-1.0, 1.0))
                    .collect()
            })
            .collect();
        let y: Vec<f64> = rows
            .iter()
            .map(|r| if r[0] + r[3] > 0.0 { 1.0 } else { -1.0 })
            .collect();
        // Extra targets correlated with different features than y.
        let lw: Vec<f64> =
            rows.iter().map(|r| if r[1] > 0.0 { 5.0 } else { 2.0 }).collect();
        let lh: Vec<f64> =
            rows.iter().map(|r| if r[2] > 0.0 { 3.0 } else { 0.0 }).collect();
        let x: Vec<Vec<f64>> = (0..rows[0].len())
            .map(|f| rows.iter().map(|r| r[f]).collect())
            .collect();
        let cfg = ForestConfig { num_trees: trees, threads: 2, ..Default::default() };
        (Forest::fit_multi(&x, &y, &[lw, lh], &cfg), rows)
    }

    #[test]
    fn joint_encoding_carries_extra_planes() {
        let (f, rows) = toy_joint_forest(5);
        assert_eq!(f.num_outputs(), 3);
        // Padded contract exercises the scale correction on extras too.
        let contract = ExportContract {
            num_trees: 8,
            max_nodes: 8192,
            max_depth: 64,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        assert_eq!(enc.truncated, 0);
        assert_eq!(enc.num_outputs(), 3);
        enc.validate().unwrap();
        for r in rows.iter().take(50) {
            let (ew, eh) = enc.predict_wg_logs(r).unwrap();
            assert!((f.predict_extra(r, 0) - ew).abs() < 1e-4);
            assert!((f.predict_extra(r, 1) - eh).abs() < 1e-4);
        }
        // Single-output forests encode with no extra planes.
        let (single, _) = toy_forest(5);
        let senc = encode(&single, ExportContract::default());
        assert_eq!(senc.num_outputs(), 1);
        assert!(senc.predict_wg_logs(&rows[0]).is_none());
    }

    #[test]
    fn validate_rejects_out_of_range_features_and_non_finite_thresholds() {
        let (f, _) = toy_forest(5);
        let contract = ExportContract {
            num_trees: 5,
            max_nodes: 8192,
            max_depth: 64,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        enc.validate().unwrap();

        // Feature index beyond the contract: previously validated clean
        // and panicked at predict time (features[fi] out of bounds).
        let mut bad = enc.clone();
        let split = (0..bad.left.len())
            .find(|&i| bad.left[i] as usize != i)
            .expect("toy forest has at least one split");
        bad.feat_idx[split] = contract.num_features as i32;
        let err = bad.validate().unwrap_err();
        assert!(err.contains("feature index"), "{err}");
        let mut neg = enc.clone();
        neg.feat_idx[split] = -1;
        assert!(neg.validate().unwrap_err().contains("feature index"));

        // Non-finite threshold: NaN compares false everywhere, silently
        // routing every row right; reject it instead.
        for bad_thresh in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut bad = enc.clone();
            bad.thresh[split] = bad_thresh;
            let err = bad.validate().unwrap_err();
            assert!(err.contains("non-finite threshold"), "{err}");
        }
    }

    #[test]
    fn single_pass_wg_logs_pins_to_the_per_plane_walks() {
        // The shared-traversal `predict_wg_logs` must reproduce the old
        // three-pass results (predict + 2x predict_extra) bit-for-bit:
        // same leaves, same per-plane summation order.
        let (f, rows) = toy_joint_forest(5);
        for contract in [
            ExportContract { num_trees: 8, max_nodes: 8192, max_depth: 64, ..Default::default() },
            ExportContract { num_trees: 5, max_nodes: 16, max_depth: 3, ..Default::default() },
        ] {
            let enc = encode(&f, contract);
            for r in rows.iter().take(100) {
                let (w, h) = enc.predict_wg_logs(r).unwrap();
                assert_eq!(w, enc.predict_extra(r, 0), "plane 0 diverged");
                assert_eq!(h, enc.predict_extra(r, 1), "plane 1 diverged");
                let out = enc.predict_outputs(r);
                assert_eq!(out.len(), 3);
                assert_eq!(out[0], enc.predict(r), "primary plane diverged");
                assert_eq!((out[1], out[2]), (w, h));
            }
        }
        // Single-output forests: predict_outputs is just [predict].
        let (single, srows) = toy_forest(5);
        let enc = encode(&single, ExportContract::default());
        for r in srows.iter().take(20) {
            assert_eq!(enc.predict_outputs(r), vec![enc.predict(r)]);
        }
    }

    #[test]
    fn truncated_joint_encoding_stays_valid() {
        let (f, rows) = toy_joint_forest(5);
        let contract = ExportContract {
            num_trees: 5,
            max_nodes: 16,
            max_depth: 3,
            ..Default::default()
        };
        let enc = encode(&f, contract);
        assert!(enc.truncated > 0);
        enc.validate().unwrap();
        // Truncated leaves predict subtree means: still finite and in the
        // convex hull of the training targets.
        for r in rows.iter().take(50) {
            let (ew, eh) = enc.predict_wg_logs(r).unwrap();
            assert!((2.0..=5.0).contains(&ew), "{ew}");
            assert!((0.0..=3.0).contains(&eh), "{eh}");
        }
    }
}
