//! Histogram pre-binning for the forest trainer (the ml-v2 split engine).
//!
//! [`BinnedDataset::build`] quantizes each feature column **once** into at
//! most `max_bins` (≤ 256, so codes fit a `u8`) quantile bins;
//! [`crate::ml::tree::Tree::fit_with_bins`] then finds each node's best
//! split with an O(n·mtry) bucket sweep instead of the exact engine's
//! per-node O(mtry·n log n) sorts. Binning depends only on the raw
//! columns — not on any bootstrap sample — so a forest bins once and
//! shares the result across all of its trees.
//!
//! Cut values are real feature-space thresholds (midpoints between
//! adjacent distinct column values), so a binned tree predicts on raw
//! feature vectors exactly like an exact one.
//!
//! Equivalence contract (tested in `rust/tests/mlcore.rs` and the tree
//! unit tests):
//!
//! * a column with at most `max_bins` distinct values gets one bin per
//!   distinct value — the candidate cut set is then identical to the
//!   exact engine's, and the two engines induce identical partitions of
//!   the training samples at every node;
//! * a continuous column is quantized to quantile bins: candidate cuts
//!   are restricted to bin boundaries, which perturbs individual trees
//!   only near score ties; on the tier-1 suites both paper metrics stay
//!   within 0.5% of the exact engine (asserted in the equivalence
//!   suite).

/// Hard upper bound on bins per feature: codes must fit in a `u8`.
pub const MAX_BINS: usize = 256;

/// The bin layout of one feature column.
#[derive(Clone, Debug, PartialEq)]
pub struct FeatureBins {
    /// Strictly increasing cut values. `cuts[b]` separates bin `b`
    /// (where `x <= cuts[b]`) from bin `b + 1`; a column with `k ≤
    /// max_bins` distinct values has `k - 1` cuts.
    pub cuts: Vec<f64>,
}

impl FeatureBins {
    /// Quantile-bin one column into at most `max_bins` bins.
    pub fn from_column(col: &[f64], max_bins: usize) -> FeatureBins {
        let max_bins = max_bins.clamp(2, MAX_BINS);
        let mut sorted: Vec<f64> =
            col.iter().copied().filter(|v| !v.is_nan()).collect();
        sorted.sort_unstable_by(f64::total_cmp);
        let mut distinct: Vec<f64> = Vec::new();
        for &v in &sorted {
            if distinct.last().map_or(true, |&p| p != v) {
                distinct.push(v);
            }
        }
        let mut cuts = Vec::new();
        if distinct.len() <= max_bins {
            // One bin per distinct value: the binned candidate cut set
            // equals the exact engine's.
            for w in distinct.windows(2) {
                push_cut(&mut cuts, w[0], w[1]);
            }
        } else {
            // Quantile edges: cut between the values flanking each
            // rank k·n/max_bins (skipped where the flanking values tie,
            // which merges duplicate-heavy quantiles).
            let n = sorted.len();
            for k in 1..max_bins {
                let r = k * n / max_bins; // 1 <= r <= n-1
                let (lo, hi) = (sorted[r - 1], sorted[r]);
                if hi > lo {
                    push_cut(&mut cuts, lo, hi);
                }
            }
        }
        FeatureBins { cuts }
    }

    pub fn num_bins(&self) -> usize {
        self.cuts.len() + 1
    }

    /// Bin index of a raw value: the number of cuts strictly below it,
    /// so `code(x) <= b` iff `x <= cuts[b]` — partitioning a node by
    /// code is identical to partitioning it by the raw threshold. NaN
    /// lands in the last bin (the exact engine's `total_cmp` order
    /// sorts NaN last too).
    #[inline]
    pub fn code_of(&self, v: f64) -> u8 {
        if v.is_nan() {
            return self.cuts.len() as u8;
        }
        self.cuts.partition_point(|&c| v > c) as u8
    }
}

/// Append the midpoint of `(lo, hi)` as a cut, keeping the cut list
/// strictly increasing and finite. An f64 midpoint of huge values can
/// overflow (fall back to `lo`, which still separates `<= lo` from
/// `> lo`), and a midpoint that rounds onto an existing cut is dropped —
/// the neighbouring cut already separates the same values.
fn push_cut(cuts: &mut Vec<f64>, lo: f64, hi: f64) {
    let mut c = 0.5 * (lo + hi);
    if !c.is_finite() {
        c = lo;
    }
    if cuts.last().map_or(true, |&p| c > p) {
        cuts.push(c);
    }
}

/// All feature columns of one training matrix, pre-binned. Built once
/// per forest fit and shared (by reference) across the tree builders.
#[derive(Clone, Debug)]
pub struct BinnedDataset {
    pub features: Vec<FeatureBins>,
    /// `codes[f][i]`: bin of sample `i` in feature `f` (column-major,
    /// mirroring the raw matrix it was built from).
    pub codes: Vec<Vec<u8>>,
}

impl BinnedDataset {
    /// Bin every column of a column-major feature matrix.
    pub fn build(x: &[Vec<f64>], max_bins: usize) -> BinnedDataset {
        let mut features = Vec::with_capacity(x.len());
        let mut codes = Vec::with_capacity(x.len());
        for col in x {
            let fb = FeatureBins::from_column(col, max_bins);
            codes.push(col.iter().map(|&v| fb.code_of(v)).collect());
            features.push(fb);
        }
        BinnedDataset { features, codes }
    }

    pub fn num_features(&self) -> usize {
        self.features.len()
    }

    pub fn num_samples(&self) -> usize {
        self.codes.first().map_or(0, Vec::len)
    }

    /// Largest per-feature bin count (sizes the split-sweep scratch).
    pub fn max_bins_used(&self) -> usize {
        self.features.iter().map(FeatureBins::num_bins).max().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn small_distinct_column_gets_exact_cuts() {
        // 5 distinct values -> 4 cuts at the midpoints, codes 0..=4.
        let col = vec![3.0, 1.0, 2.0, 1.0, 5.0, 4.0, 3.0];
        let fb = FeatureBins::from_column(&col, 256);
        assert_eq!(fb.cuts, vec![1.5, 2.5, 3.5, 4.5]);
        assert_eq!(fb.num_bins(), 5);
        let codes: Vec<u8> = col.iter().map(|&v| fb.code_of(v)).collect();
        assert_eq!(codes, vec![2, 0, 1, 0, 4, 3, 2]);
    }

    #[test]
    fn code_threshold_consistency() {
        // code(x) <= b  iff  x <= cuts[b], for every cut and value.
        let mut rng = Rng::new(11);
        let col: Vec<f64> =
            (0..3000).map(|_| rng.range_f64(-10.0, 10.0)).collect();
        let fb = FeatureBins::from_column(&col, 64);
        assert!(fb.num_bins() <= 64);
        assert!(fb.num_bins() > 32, "quantiles collapsed: {}", fb.num_bins());
        for w in fb.cuts.windows(2) {
            assert!(w[0] < w[1], "cuts not strictly increasing");
        }
        for &v in col.iter().take(500) {
            let c = fb.code_of(v) as usize;
            for (b, &cut) in fb.cuts.iter().enumerate() {
                assert_eq!(c <= b, v <= cut, "v={v} cut={cut} code={c}");
            }
        }
    }

    #[test]
    fn constant_column_is_one_bin() {
        let fb = FeatureBins::from_column(&[7.0; 50], 256);
        assert_eq!(fb.num_bins(), 1);
        assert_eq!(fb.code_of(7.0), 0);
    }

    #[test]
    fn nan_goes_to_the_last_bin() {
        let fb = FeatureBins::from_column(&[1.0, 2.0, f64::NAN, 3.0], 256);
        assert_eq!(fb.num_bins(), 3); // NaN excluded from cut estimation
        assert_eq!(fb.code_of(f64::NAN) as usize, fb.num_bins() - 1);
        assert!(fb.cuts.iter().all(|c| c.is_finite()));
    }

    #[test]
    fn duplicate_heavy_column_merges_quantiles() {
        // 90% zeros, a long tail: quantile edges inside the zero run
        // must merge instead of producing duplicate cuts.
        let mut col = vec![0.0; 900];
        col.extend((0..300).map(|i| 1.0 + i as f64));
        let fb = FeatureBins::from_column(&col, 16);
        assert!(fb.num_bins() <= 16);
        for w in fb.cuts.windows(2) {
            assert!(w[0] < w[1]);
        }
        // the zero mass is separable from the tail
        assert!(fb.code_of(0.0) < fb.code_of(5.0));
    }

    #[test]
    fn dataset_builds_all_columns() {
        let x = vec![
            (0..100).map(|i| i as f64).collect::<Vec<_>>(),
            vec![1.0; 100],
        ];
        let ds = BinnedDataset::build(&x, 256);
        assert_eq!(ds.num_features(), 2);
        assert_eq!(ds.num_samples(), 100);
        assert_eq!(ds.features[0].num_bins(), 100);
        assert_eq!(ds.features[1].num_bins(), 1);
        assert_eq!(ds.max_bins_used(), 100);
        // codes of the ramp column are the identity
        for (i, &c) in ds.codes[0].iter().enumerate() {
            assert_eq!(c as usize, i);
        }
    }
}
