//! Random Forest regression (paper §5.1: Weka RF, 20 trees, 4 attributes
//! per node, unlimited depth), built from scratch on `ml::tree`.
//!
//! The forest regresses log2(kernel speedup); `decide()` thresholds the
//! prediction at 0 (speedup 1.0) to produce the optimize/don't decision.

use crate::kernelmodel::features::NUM_FEATURES;
use crate::sim::exec::SpeedupRecord;
use crate::util::pool::parallel_map;
use crate::util::prng::Rng;

use super::tree::{Tree, TreeConfig};

#[derive(Clone, Copy, Debug)]
pub struct ForestConfig {
    /// Number of trees (paper: 20).
    pub num_trees: usize,
    pub tree: TreeConfig,
    pub seed: u64,
    pub threads: usize,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig {
            num_trees: 20,
            tree: TreeConfig::default(),
            seed: 0xF0_4E57,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub config_summary: String,
}

impl Forest {
    /// Fit on dataset records: features -> log2(speedup). Accepts both
    /// borrowed (`&[&SpeedupRecord]`, the split() output) and owned
    /// (`&[SpeedupRecord]`, e.g. a reservoir sample) slices.
    pub fn fit_records<R: std::borrow::Borrow<SpeedupRecord>>(
        records: &[R],
        cfg: &ForestConfig,
    ) -> Forest {
        let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
            .map(|f| records.iter().map(|r| r.borrow().features[f]).collect())
            .collect();
        let y: Vec<f64> = records.iter().map(|r| r.borrow().target()).collect();
        Self::fit(&x, &y, cfg)
    }

    /// Fit on column-major features and targets.
    pub fn fit(x: &[Vec<f64>], y: &[f64], cfg: &ForestConfig) -> Forest {
        assert!(!y.is_empty(), "empty training set");
        let n = y.len();
        let mut root = Rng::new(cfg.seed);
        let seeds: Vec<u64> = (0..cfg.num_trees).map(|_| root.next_u64()).collect();
        let trees = parallel_map(&seeds, cfg.threads, |&seed| {
            let mut rng = Rng::new(seed);
            // Bootstrap sample (with replacement), classic bagging.
            let mut idx: Vec<usize> =
                (0..n).map(|_| rng.below(n as u64) as usize).collect();
            Tree::fit(x, y, &mut idx, cfg.tree, &mut rng)
        });
        Forest {
            trees,
            config_summary: format!(
                "trees={} mtry={} min_leaf={} max_depth={}",
                cfg.num_trees,
                cfg.tree.mtry,
                cfg.tree.min_samples_leaf,
                cfg.tree.max_depth
            ),
        }
    }

    /// Predicted log2(speedup).
    pub fn predict(&self, features: &[f64]) -> f64 {
        let s: f64 = self.trees.iter().map(|t| t.predict(features)).sum();
        s / self.trees.len() as f64
    }

    /// The auto-tuning decision: apply the optimization?
    pub fn decide(&self, features: &[f64]) -> bool {
        self.predict(features) > 0.0
    }

    pub fn predict_batch(&self, rows: &[&[f64]]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }

    pub fn max_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.nodes.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = sign-ish function of two features with interaction.
        let mut rng = Rng::new(seed);
        let rows: Vec<(f64, f64, f64)> = (0..n)
            .map(|_| {
                let a = rng.range_f64(-2.0, 2.0);
                let b = rng.range_f64(-2.0, 2.0);
                let y = if a * b > 0.0 { 1.5 } else { -1.5 };
                (a, b, y + 0.05 * rng.normal())
            })
            .collect();
        let x = vec![
            rows.iter().map(|r| r.0).collect(),
            rows.iter().map(|r| r.1).collect(),
        ];
        let y = rows.iter().map(|r| r.2).collect();
        (x, y)
    }

    #[test]
    fn learns_xor_like_interaction() {
        let (x, y) = toy_problem(2000, 42);
        let cfg = ForestConfig {
            num_trees: 10,
            threads: 2,
            ..ForestConfig::default()
        };
        let f = Forest::fit(&x, &y, &cfg);
        let mut correct = 0;
        let mut rng = Rng::new(99);
        let trials = 500;
        for _ in 0..trials {
            let a = rng.range_f64(-2.0, 2.0);
            let b = rng.range_f64(-2.0, 2.0);
            if a.abs() < 0.2 || b.abs() < 0.2 {
                correct += 1; // too close to the boundary to grade
                continue;
            }
            let want = a * b > 0.0;
            if f.decide(&[a, b]) == want {
                correct += 1;
            }
        }
        assert!(
            correct as f64 / trials as f64 > 0.9,
            "accuracy {}",
            correct as f64 / trials as f64
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = toy_problem(300, 7);
        let cfg = ForestConfig { num_trees: 5, threads: 3, ..Default::default() };
        let a = Forest::fit(&x, &y, &cfg);
        let b = Forest::fit(&x, &y, &cfg);
        for p in [[0.3, -0.7], [1.0, 1.0], [-1.5, 0.2]] {
            assert_eq!(a.predict(&p), b.predict(&p));
        }
    }

    #[test]
    fn forest_averages_trees() {
        let (x, y) = toy_problem(300, 8);
        let cfg = ForestConfig { num_trees: 4, threads: 1, ..Default::default() };
        let f = Forest::fit(&x, &y, &cfg);
        let p = [0.5, 0.5];
        let manual: f64 =
            f.trees.iter().map(|t| t.predict(&p)).sum::<f64>() / 4.0;
        assert!((f.predict(&p) - manual).abs() < 1e-12);
    }

    #[test]
    fn single_tree_forest_works() {
        let (x, y) = toy_problem(100, 9);
        let cfg = ForestConfig { num_trees: 1, threads: 1, ..Default::default() };
        let f = Forest::fit(&x, &y, &cfg);
        assert_eq!(f.trees.len(), 1);
        assert!(f.predict(&[1.0, 1.0]).is_finite());
    }
}
