//! Random Forest regression (paper §5.1: Weka RF, 20 trees, 4 attributes
//! per node, unlimited depth), built from scratch on `ml::tree`.
//!
//! The forest regresses log2(kernel speedup); `decide()` thresholds the
//! prediction at 0 (speedup 1.0) to produce the optimize/don't decision.
//!
//! ml-v2: with the default [`SplitEngine::Binned`] engine the feature
//! columns are quantile-binned **once** per fit (`ml::binning`) and the
//! binning is shared across every tree's builder — binning depends only
//! on the columns, never on a bootstrap sample. `SplitEngine::Exact`
//! keeps the v1 per-node-sort reference engine selectable for
//! equivalence testing and ablation.

use crate::kernelmodel::features::NUM_FEATURES;
use crate::sim::exec::{SpeedupRecord, TuneRecord};
use crate::util::pool::parallel_map;
use crate::util::prng::Rng;

use super::binning::BinnedDataset;
use super::tree::{SplitEngine, Tree, TreeConfig};

#[derive(Clone, Copy, Debug)]
pub struct ForestConfig {
    /// Number of trees (paper: 20).
    pub num_trees: usize,
    pub tree: TreeConfig,
    pub seed: u64,
    pub threads: usize,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig {
            num_trees: 20,
            tree: TreeConfig::default(),
            seed: 0xF0_4E57,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
        }
    }
}

/// Typed rejection of training input the forest cannot learn from.
/// Before ml-v2 a single NaN feature would panic the split sweep deep
/// inside `tree.rs`; now the sweeps are NaN-total and the *validation*
/// is explicit, up front, and recoverable.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FitError {
    EmptyTrainingSet,
    /// `features[feature]` of record `row` is NaN or infinite.
    NonFiniteFeature { row: usize, feature: usize, value: f64 },
    /// Record `row` has a speedup whose log2 target is not finite
    /// (NaN/infinite, zero or negative speedup).
    NonFiniteTarget { row: usize, speedup: f64 },
    /// Joint (multi-output) training was requested but record `row`
    /// carries no workgroup label (a v1 up-conversion, or the `0,0`
    /// sentinel). Training the workgroup outputs on fabricated labels
    /// would silently poison the joint model.
    MissingWgLabel { row: usize },
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::EmptyTrainingSet => write!(f, "empty training set"),
            FitError::NonFiniteFeature { row, feature, value } => write!(
                f,
                "training record {row}: feature {feature} is {value} — \
                 the trainer requires finite features"
            ),
            FitError::NonFiniteTarget { row, speedup } => write!(
                f,
                "training record {row}: speedup {speedup} has no finite \
                 log2 target — speedups must be finite and > 0"
            ),
            FitError::MissingWgLabel { row } => write!(
                f,
                "training record {row}: no workgroup label — joint \
                 (schema v2) training needs labeled records"
            ),
        }
    }
}

impl std::error::Error for FitError {}

/// Out-of-bag generalization estimate (free with bagging: every tree
/// leaves ~37% of the samples out of its bootstrap, and those samples
/// are test data for that tree).
#[derive(Clone, Copy, Debug)]
pub struct OobEstimate {
    /// Mean squared error of OOB predictions against the log2 targets.
    pub mse: f64,
    /// Fraction of covered samples whose OOB decision (prediction > 0)
    /// matches the oracle label (target > 0).
    pub decision_accuracy: f64,
    /// Samples left out of at least one bootstrap (only they have an
    /// OOB prediction; with >= 10 trees this is nearly all of them).
    pub covered: usize,
    pub total: usize,
}

#[derive(Clone, Debug)]
pub struct Forest {
    pub trees: Vec<Tree>,
    pub config_summary: String,
}

impl Forest {
    /// Fit on dataset records: features -> log2(speedup). Accepts both
    /// borrowed (`&[&SpeedupRecord]`, the split() output) and owned
    /// (`&[SpeedupRecord]`, e.g. a reservoir sample) slices. Rejects
    /// empty input and non-finite features/targets with a typed
    /// [`FitError`] instead of training a silently-poisoned model.
    pub fn fit_records<R: std::borrow::Borrow<SpeedupRecord>>(
        records: &[R],
        cfg: &ForestConfig,
    ) -> Result<Forest, FitError> {
        Self::validate_records(records)?;
        let (x, y) = Self::columns(records);
        Ok(Self::fit(&x, &y, cfg))
    }

    /// [`Forest::fit_records`] plus the out-of-bag estimate.
    pub fn fit_records_with_oob<R: std::borrow::Borrow<SpeedupRecord>>(
        records: &[R],
        cfg: &ForestConfig,
    ) -> Result<(Forest, OobEstimate), FitError> {
        Self::validate_records(records)?;
        let (x, y) = Self::columns(records);
        Ok(Self::fit_with_oob(&x, &y, cfg))
    }

    /// Joint (multi-output) fit on schema-v2 records: the trees are
    /// grown on log2(speedup) exactly as [`Forest::fit_records`] —
    /// identical structure, splits, and primary predictions — with
    /// log2(wg_w) and log2(wg_h) recorded as per-node extra outputs.
    /// Every record must carry a workgroup label; an unlabeled record
    /// (v1 up-conversion) is the typed [`FitError::MissingWgLabel`].
    pub fn fit_tune_records<R: std::borrow::Borrow<TuneRecord>>(
        records: &[R],
        cfg: &ForestConfig,
    ) -> Result<Forest, FitError> {
        let bases: Vec<&SpeedupRecord> =
            records.iter().map(|r| &r.borrow().base).collect();
        Self::validate_records(&bases)?;
        let mut lw = Vec::with_capacity(records.len());
        let mut lh = Vec::with_capacity(records.len());
        for (row, r) in records.iter().enumerate() {
            match r.borrow().wg_targets() {
                Some((w, h)) => {
                    lw.push(w);
                    lh.push(h);
                }
                None => return Err(FitError::MissingWgLabel { row }),
            }
        }
        let (x, y) = Self::columns(&bases);
        Ok(Self::fit_multi(&x, &y, &[lw, lh], cfg))
    }

    /// Column-major feature matrix + log2 targets of a record slice
    /// (the layout `fit`/`fit_prebinned` consume; `ml::select` uses it
    /// to extract each CV fold once instead of per grid config).
    pub fn columns<R: std::borrow::Borrow<SpeedupRecord>>(
        records: &[R],
    ) -> (Vec<Vec<f64>>, Vec<f64>) {
        let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
            .map(|f| records.iter().map(|r| r.borrow().features[f]).collect())
            .collect();
        let y: Vec<f64> = records.iter().map(|r| r.borrow().target()).collect();
        (x, y)
    }

    /// Check every record the trainer is about to learn from: all
    /// features finite, log2(speedup) finite. Returns the first
    /// offending row as a typed error.
    pub fn validate_records<R: std::borrow::Borrow<SpeedupRecord>>(
        records: &[R],
    ) -> Result<(), FitError> {
        if records.is_empty() {
            return Err(FitError::EmptyTrainingSet);
        }
        for (row, r) in records.iter().enumerate() {
            let r = r.borrow();
            for (feature, &value) in r.features.iter().enumerate() {
                if !value.is_finite() {
                    return Err(FitError::NonFiniteFeature { row, feature, value });
                }
            }
            if !r.target().is_finite() {
                return Err(FitError::NonFiniteTarget { row, speedup: r.speedup });
            }
        }
        Ok(())
    }

    /// Fit on column-major features and targets.
    pub fn fit(x: &[Vec<f64>], y: &[f64], cfg: &ForestConfig) -> Forest {
        Self::fit_multi(x, y, &[], cfg)
    }

    /// Multi-output fit on column-major features: trees grown on `y`,
    /// per-node means of each `extras` column recorded as extra
    /// outputs (see [`crate::ml::tree::Tree::fit_multi`]). With
    /// `extras = &[]` this IS [`Forest::fit`].
    pub fn fit_multi(
        x: &[Vec<f64>],
        y: &[f64],
        extras: &[Vec<f64>],
        cfg: &ForestConfig,
    ) -> Forest {
        // ml-v2: bin once, share across trees.
        let bins = match cfg.tree.engine {
            SplitEngine::Binned => Some(BinnedDataset::build(x, cfg.tree.max_bins)),
            SplitEngine::Exact => None,
        };
        Self::fit_impl(x, y, extras, bins.as_ref(), cfg)
    }

    /// [`Forest::fit`] reusing a pre-built binning of `x` — `ml::select`
    /// bins each CV fold once and shares it across every grid config
    /// (binning depends only on the columns, not on the forest
    /// hyperparameters). With the exact engine the binning is ignored.
    pub fn fit_prebinned(
        x: &[Vec<f64>],
        y: &[f64],
        bins: &BinnedDataset,
        cfg: &ForestConfig,
    ) -> Forest {
        let bins = match cfg.tree.engine {
            SplitEngine::Binned => Some(bins),
            SplitEngine::Exact => None,
        };
        Self::fit_impl(x, y, &[], bins, cfg)
    }

    /// The per-tree bagging draws. The SINGLE definition of the
    /// bootstrap stream: `fit_impl` grows each tree from it and
    /// `oob_estimate` recovers in-bag membership from it, so the two
    /// can never silently desynchronize. Returns the drawn indices plus
    /// the generator, positioned after the draws, that the tree builder
    /// continues with (mtry sampling).
    fn bootstrap(tree_seed: u64, n: usize) -> (Rng, Vec<usize>) {
        let mut rng = Rng::new(tree_seed);
        let idx = (0..n).map(|_| rng.below(n as u64) as usize).collect();
        (rng, idx)
    }

    fn fit_impl(
        x: &[Vec<f64>],
        y: &[f64],
        extras: &[Vec<f64>],
        bins: Option<&BinnedDataset>,
        cfg: &ForestConfig,
    ) -> Forest {
        assert!(!y.is_empty(), "empty training set");
        let n = y.len();
        let mut root = Rng::new(cfg.seed);
        let seeds: Vec<u64> = (0..cfg.num_trees).map(|_| root.next_u64()).collect();
        let trees = parallel_map(&seeds, cfg.threads, |&seed| {
            // Bootstrap sample (with replacement), classic bagging.
            let (mut rng, mut idx) = Self::bootstrap(seed, n);
            match bins {
                Some(b) => Tree::fit_with_bins_multi(
                    b, y, extras, &mut idx, cfg.tree, &mut rng,
                ),
                None => Tree::fit_multi(x, y, extras, &mut idx, cfg.tree, &mut rng),
            }
        });
        Forest {
            trees,
            config_summary: format!(
                "trees={} mtry={} min_leaf={} max_depth={} engine={} bins={}",
                cfg.num_trees,
                cfg.tree.mtry,
                cfg.tree.min_samples_leaf,
                cfg.tree.max_depth,
                cfg.tree.engine,
                cfg.tree.max_bins
            ),
        }
    }

    /// Fit plus the out-of-bag estimate. `cfg` must be the config the
    /// forest is fitted with: the bagging draws are replayed from
    /// `cfg.seed` to recover each tree's bootstrap membership.
    pub fn fit_with_oob(
        x: &[Vec<f64>],
        y: &[f64],
        cfg: &ForestConfig,
    ) -> (Forest, OobEstimate) {
        let forest = Self::fit(x, y, cfg);
        let oob = forest.oob_estimate(x, y, cfg);
        (forest, oob)
    }

    /// Recover each tree's bootstrap membership by replaying
    /// `Forest::bootstrap` (the same private function `fit` draws from,
    /// so the two paths cannot desynchronize) and grade every sample on
    /// the trees that never saw it. `covered == 0` (possible only with
    /// very few trees) yields NaN metrics.
    pub fn oob_estimate(&self, x: &[Vec<f64>], y: &[f64], cfg: &ForestConfig) -> OobEstimate {
        let n = y.len();
        let mut root = Rng::new(cfg.seed);
        let inbag: Vec<Vec<bool>> = (0..self.trees.len())
            .map(|_| {
                let (_, idx) = Self::bootstrap(root.next_u64(), n);
                let mut m = vec![false; n];
                for i in idx {
                    m[i] = true;
                }
                m
            })
            .collect();
        let rows: Vec<Vec<f64>> =
            (0..n).map(|i| x.iter().map(|c| c[i]).collect()).collect();
        let ids: Vec<usize> = (0..n).collect();
        let preds: Vec<Option<f64>> = parallel_map(&ids, cfg.threads, |&i| {
            let mut s = 0.0;
            let mut c = 0usize;
            for (t, tree) in self.trees.iter().enumerate() {
                if !inbag[t][i] {
                    s += tree.predict(&rows[i]);
                    c += 1;
                }
            }
            if c > 0 { Some(s / c as f64) } else { None }
        });
        let mut covered = 0usize;
        let mut se = 0.0;
        let mut agree = 0usize;
        for (i, p) in preds.iter().enumerate() {
            if let Some(p) = p {
                covered += 1;
                se += (p - y[i]) * (p - y[i]);
                agree += ((*p > 0.0) == (y[i] > 0.0)) as usize;
            }
        }
        if covered == 0 {
            return OobEstimate {
                mse: f64::NAN,
                decision_accuracy: f64::NAN,
                covered: 0,
                total: n,
            };
        }
        OobEstimate {
            mse: se / covered as f64,
            decision_accuracy: agree as f64 / covered as f64,
            covered,
            total: n,
        }
    }

    /// Predicted log2(speedup).
    pub fn predict(&self, features: &[f64]) -> f64 {
        let s: f64 = self.trees.iter().map(|t| t.predict(features)).sum();
        s / self.trees.len() as f64
    }

    /// The auto-tuning decision: apply the optimization?
    pub fn decide(&self, features: &[f64]) -> bool {
        self.predict(features) > 0.0
    }

    /// Outputs per prediction: 1 for single-output forests, 1 + extra
    /// planes for joint forests (every tree has the same arity).
    pub fn num_outputs(&self) -> usize {
        self.trees.first().map(|t| t.num_outputs()).unwrap_or(1)
    }

    /// Predicted extra output `k` (0-based among the extras): forest
    /// mean of the per-tree leaf values.
    pub fn predict_extra(&self, features: &[f64], k: usize) -> f64 {
        let s: f64 = self.trees.iter().map(|t| t.predict_extra(features, k)).sum();
        s / self.trees.len() as f64
    }

    /// Joint forests: predicted (log2 wg_w, log2 wg_h). `None` for
    /// single-output forests (callers snap the logs to a valid
    /// power-of-two shape via `ml::metrics::snap_wg`).
    pub fn predict_wg_logs(&self, features: &[f64]) -> Option<(f64, f64)> {
        if self.num_outputs() < 3 {
            return None;
        }
        Some((self.predict_extra(features, 0), self.predict_extra(features, 1)))
    }

    /// Batch prediction fanned across the host's cores. Order-preserving
    /// chunked map, so results are identical at any thread count.
    pub fn predict_batch(&self, rows: &[&[f64]]) -> Vec<f64> {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        self.predict_batch_with(rows, threads)
    }

    /// [`Forest::predict_batch`] with an explicit thread count
    /// (`1` = serial, for callers that already parallelize above).
    pub fn predict_batch_with(&self, rows: &[&[f64]], threads: usize) -> Vec<f64> {
        parallel_map(rows, threads, |r| self.predict(r))
    }

    pub fn max_depth(&self) -> usize {
        self.trees.iter().map(|t| t.depth()).max().unwrap_or(0)
    }

    pub fn max_nodes(&self) -> usize {
        self.trees.iter().map(|t| t.nodes.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_problem(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = sign-ish function of two features with interaction.
        let mut rng = Rng::new(seed);
        let rows: Vec<(f64, f64, f64)> = (0..n)
            .map(|_| {
                let a = rng.range_f64(-2.0, 2.0);
                let b = rng.range_f64(-2.0, 2.0);
                let y = if a * b > 0.0 { 1.5 } else { -1.5 };
                (a, b, y + 0.05 * rng.normal())
            })
            .collect();
        let x = vec![
            rows.iter().map(|r| r.0).collect(),
            rows.iter().map(|r| r.1).collect(),
        ];
        let y = rows.iter().map(|r| r.2).collect();
        (x, y)
    }

    fn toy_records(n: usize, seed: u64) -> Vec<SpeedupRecord> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let mut features = [0.0; NUM_FEATURES];
                for f in features.iter_mut() {
                    *f = rng.range_f64(-1.0, 1.0);
                }
                let speedup = (features[0] + 0.2 * rng.normal()).exp2();
                SpeedupRecord {
                    name: format!("toy-{i}"),
                    features,
                    speedup,
                    baseline_time: 1.0,
                    optimized_time: 1.0 / speedup,
                }
            })
            .collect()
    }

    #[test]
    fn learns_xor_like_interaction() {
        let (x, y) = toy_problem(2000, 42);
        let cfg = ForestConfig {
            num_trees: 10,
            threads: 2,
            ..ForestConfig::default()
        };
        let f = Forest::fit(&x, &y, &cfg);
        let mut correct = 0;
        let mut rng = Rng::new(99);
        let trials = 500;
        for _ in 0..trials {
            let a = rng.range_f64(-2.0, 2.0);
            let b = rng.range_f64(-2.0, 2.0);
            if a.abs() < 0.2 || b.abs() < 0.2 {
                correct += 1; // too close to the boundary to grade
                continue;
            }
            let want = a * b > 0.0;
            if f.decide(&[a, b]) == want {
                correct += 1;
            }
        }
        assert!(
            correct as f64 / trials as f64 > 0.9,
            "accuracy {}",
            correct as f64 / trials as f64
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = toy_problem(300, 7);
        let cfg = ForestConfig { num_trees: 5, threads: 3, ..Default::default() };
        let a = Forest::fit(&x, &y, &cfg);
        let b = Forest::fit(&x, &y, &cfg);
        for p in [[0.3, -0.7], [1.0, 1.0], [-1.5, 0.2]] {
            assert_eq!(a.predict(&p), b.predict(&p));
        }
    }

    #[test]
    fn forest_averages_trees() {
        let (x, y) = toy_problem(300, 8);
        let cfg = ForestConfig { num_trees: 4, threads: 1, ..Default::default() };
        let f = Forest::fit(&x, &y, &cfg);
        let p = [0.5, 0.5];
        let manual: f64 =
            f.trees.iter().map(|t| t.predict(&p)).sum::<f64>() / 4.0;
        assert!((f.predict(&p) - manual).abs() < 1e-12);
    }

    #[test]
    fn single_tree_forest_works() {
        let (x, y) = toy_problem(100, 9);
        let cfg = ForestConfig { num_trees: 1, threads: 1, ..Default::default() };
        let f = Forest::fit(&x, &y, &cfg);
        assert_eq!(f.trees.len(), 1);
        assert!(f.predict(&[1.0, 1.0]).is_finite());
    }

    #[test]
    fn exact_and_binned_agree_on_the_toy_problem() {
        let (x, y) = toy_problem(1500, 12);
        let mut cfg = ForestConfig { num_trees: 8, threads: 2, ..Default::default() };
        cfg.tree.engine = SplitEngine::Exact;
        let fe = Forest::fit(&x, &y, &cfg);
        cfg.tree.engine = SplitEngine::Binned;
        let fb = Forest::fit(&x, &y, &cfg);
        // Same decisions away from the boundary.
        let mut rng = Rng::new(31);
        let mut agree = 0usize;
        let mut graded = 0usize;
        for _ in 0..500 {
            let p = [rng.range_f64(-2.0, 2.0), rng.range_f64(-2.0, 2.0)];
            let pe = fe.predict(&p);
            if pe.abs() < 0.1 {
                continue;
            }
            graded += 1;
            agree += (fb.decide(&p) == (pe > 0.0)) as usize;
        }
        assert!(graded > 300);
        assert!(
            agree as f64 / graded as f64 > 0.95,
            "{agree}/{graded} decisions agree"
        );
    }

    #[test]
    fn poisoned_rows_are_typed_errors_not_panics() {
        // Regression: a single NaN feature used to panic the split sweep
        // (`partial_cmp().unwrap()`); now it is a typed, recoverable Err.
        let mut recs = toy_records(50, 3);
        recs[13].features[2] = f64::NAN;
        let err = Forest::fit_records(&recs, &ForestConfig::default()).unwrap_err();
        assert!(
            matches!(
                err,
                FitError::NonFiniteFeature { row: 13, feature: 2, value } if value.is_nan()
            ),
            "{err:?}"
        );
        assert!(err.to_string().contains("feature 2"));

        let mut recs = toy_records(50, 4);
        recs[7].speedup = 0.0; // log2 -> -inf
        let err = Forest::fit_records(&recs, &ForestConfig::default()).unwrap_err();
        assert!(matches!(err, FitError::NonFiniteTarget { row: 7, .. }), "{err}");

        let mut recs = toy_records(50, 5);
        recs[0].features[0] = f64::INFINITY;
        assert!(Forest::fit_records(&recs, &ForestConfig::default()).is_err());

        let empty: Vec<SpeedupRecord> = Vec::new();
        assert_eq!(
            Forest::fit_records(&empty, &ForestConfig::default()).unwrap_err(),
            FitError::EmptyTrainingSet
        );

        // clean records still fit
        let recs = toy_records(80, 6);
        let f = Forest::fit_records(&recs, &ForestConfig {
            num_trees: 3,
            threads: 1,
            ..Default::default()
        })
        .unwrap();
        assert!(f.predict(&recs[0].features).is_finite());
    }

    #[test]
    fn predict_batch_matches_predict_at_any_thread_count() {
        let (x, y) = toy_problem(400, 10);
        let cfg = ForestConfig { num_trees: 6, threads: 2, ..Default::default() };
        let f = Forest::fit(&x, &y, &cfg);
        let probes: Vec<Vec<f64>> = (0..257)
            .map(|i| vec![(i as f64 / 64.0) - 2.0, ((i * 7 % 257) as f64 / 64.0) - 2.0])
            .collect();
        let refs: Vec<&[f64]> = probes.iter().map(|p| p.as_slice()).collect();
        let serial: Vec<f64> = probes.iter().map(|p| f.predict(p)).collect();
        for threads in [1usize, 2, 5] {
            assert_eq!(f.predict_batch_with(&refs, threads), serial, "threads={threads}");
        }
        assert_eq!(f.predict_batch(&refs), serial);
    }

    #[test]
    fn oob_estimate_tracks_generalization() {
        let (x, y) = toy_problem(600, 11);
        let cfg = ForestConfig { num_trees: 15, threads: 2, ..Default::default() };
        let (f, oob) = Forest::fit_with_oob(&x, &y, &cfg);
        assert_eq!(f.trees.len(), 15);
        assert_eq!(oob.total, 600);
        // with 15 trees nearly every sample is OOB for some tree
        assert!(oob.covered > 550, "covered {}", oob.covered);
        // y variance is ~2.25; an OOB forest must beat the mean
        assert!(oob.mse.is_finite() && oob.mse < 1.5, "mse {}", oob.mse);
        assert!(
            oob.decision_accuracy > 0.75,
            "decision accuracy {}",
            oob.decision_accuracy
        );
        // the returned forest is the plain fit (OOB is a side estimate)
        let plain = Forest::fit(&x, &y, &cfg);
        assert_eq!(f.predict(&[0.7, 0.7]), plain.predict(&[0.7, 0.7]));
    }

    fn toy_tune_records(n: usize, seed: u64) -> Vec<TuneRecord> {
        toy_records(n, seed)
            .into_iter()
            .enumerate()
            .map(|(i, base)| {
                // wg label correlated with feature 1 so it is learnable
                let w = if base.features[1] > 0.0 { 32 } else { 4 };
                TuneRecord { base, best_wg: Some((w, 1 << (i % 3))) }
            })
            .collect()
    }

    #[test]
    fn joint_fit_matches_single_fit_on_the_primary_output() {
        let recs = toy_tune_records(300, 17);
        let bases: Vec<SpeedupRecord> =
            recs.iter().map(|r| r.base.clone()).collect();
        let cfg = ForestConfig { num_trees: 5, threads: 2, ..Default::default() };
        let joint = Forest::fit_tune_records(&recs, &cfg).unwrap();
        let single = Forest::fit_records(&bases, &cfg).unwrap();
        assert_eq!(joint.num_outputs(), 3);
        assert_eq!(single.num_outputs(), 1);
        assert_eq!(joint.trees.len(), single.trees.len());
        // identical structure and bit-identical primary predictions
        for (a, b) in joint.trees.iter().zip(&single.trees) {
            assert_eq!(a.nodes, b.nodes);
        }
        for r in recs.iter().take(25) {
            assert_eq!(joint.predict(&r.base.features), single.predict(&r.base.features));
            let (lw, lh) = joint.predict_wg_logs(&r.base.features).unwrap();
            assert!(lw.is_finite() && lh.is_finite());
        }
        assert_eq!(single.predict_wg_logs(&recs[0].base.features), None);
    }

    #[test]
    fn joint_fit_learns_the_wg_label() {
        let recs = toy_tune_records(600, 23);
        let cfg = ForestConfig { num_trees: 10, threads: 2, ..Default::default() };
        let f = Forest::fit_tune_records(&recs, &cfg).unwrap();
        // the width label is a function of feature 1: log2(32)=5 vs
        // log2(4)=2, so predictions must separate the two classes
        let mut hi = Vec::new();
        let mut lo = Vec::new();
        for r in &recs {
            let (lw, _) = f.predict_wg_logs(&r.base.features).unwrap();
            if r.base.features[1] > 0.25 {
                hi.push(lw);
            } else if r.base.features[1] < -0.25 {
                lo.push(lw);
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&hi) > 4.0, "hi mean {}", mean(&hi));
        assert!(mean(&lo) < 3.0, "lo mean {}", mean(&lo));
    }

    #[test]
    fn unlabeled_records_are_a_typed_error_for_joint_fit() {
        let mut recs = toy_tune_records(40, 29);
        recs[11].best_wg = None;
        let err = Forest::fit_tune_records(&recs, &ForestConfig::default())
            .unwrap_err();
        assert_eq!(err, FitError::MissingWgLabel { row: 11 });
        assert!(err.to_string().contains("workgroup label"));
    }
}
