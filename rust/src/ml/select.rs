//! Model selection: deterministic k-fold cross-validation over a forest
//! hyperparameter grid (`num_trees × mtry × min_samples_leaf`).
//!
//! The OpenCL autotuning literature (Falch & Elster 1506.00842; Cummins
//! et al. 1511.02490) finds that model/hyperparameter *search*, not one
//! fixed configuration, is what makes ML auto-tuners portable across
//! workloads and devices. This module is that search for the paper's
//! Random Forest:
//!
//! * every (config, fold) cell is an independent task fanned across
//!   `util::pool::parallel_map` — order-preserving, with all RNG streams
//!   derived from fixed seeds, so every metric (and the selected best
//!   config) is **identical at any thread count**; only the wall-time
//!   columns are measurements;
//! * each cell reports both paper metrics (count-based +
//!   penalty-weighted accuracy) plus fit/predict wall time;
//! * [`write_csv`] emits the per-config table and
//!   [`save_forest_config`]/[`load_forest_config`] persist the winner in
//!   a small key=value file that `lmtuner train`/`crossdev` consume via
//!   `--forest-config`.
//!
//! Fold assignment: sample `i` goes to fold `pos_i % folds` where
//! `pos_i` is `i`'s position in a seed-shuffled permutation — balanced
//! folds, deterministic from `TuneConfig::seed` alone.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sim::exec::{SpeedupRecord, TuneRecord};
use crate::util::pool::parallel_map;
use crate::util::prng::Rng;

use super::binning::BinnedDataset;
use super::forest::{Forest, ForestConfig};
use super::metrics::AccuracyAccumulator;
use super::tree::{SplitEngine, TreeConfig};

/// The hyperparameter grid: the cross product of the three axes, in
/// `num_trees → mtry → min_samples_leaf` (row-major) order.
#[derive(Clone, Debug)]
pub struct GridSpec {
    pub num_trees: Vec<usize>,
    pub mtry: Vec<usize>,
    pub min_samples_leaf: Vec<usize>,
}

impl Default for GridSpec {
    fn default() -> Self {
        GridSpec {
            num_trees: vec![10, 20, 40],
            mtry: vec![2, 4, 8],
            min_samples_leaf: vec![1, 4],
        }
    }
}

impl GridSpec {
    /// Parse three comma-separated axis lists (the CLI surface).
    pub fn parse(num_trees: &str, mtry: &str, min_samples_leaf: &str) -> Result<GridSpec> {
        let axis = |name: &str, s: &str| -> Result<Vec<usize>> {
            let vals: Vec<usize> = s
                .split(',')
                .map(|v| {
                    v.trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}"))
                })
                .collect::<Result<_>>()?;
            if vals.is_empty() || vals.iter().any(|&v| v == 0) {
                bail!("--{name} needs positive comma-separated values, got {s:?}");
            }
            Ok(vals)
        };
        Ok(GridSpec {
            num_trees: axis("trees", num_trees)?,
            mtry: axis("mtry", mtry)?,
            min_samples_leaf: axis("min-leaf", min_samples_leaf)?,
        })
    }

    pub fn len(&self) -> usize {
        self.num_trees.len() * self.mtry.len() * self.min_samples_leaf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Materialize the grid as full forest configs on top of `base`
    /// (base supplies engine, max_bins, max_depth, seed; `threads` is
    /// forced to 1 — parallelism lives at the (config, fold) level).
    pub fn configs(&self, base: &ForestConfig) -> Vec<ForestConfig> {
        let mut out = Vec::with_capacity(self.len());
        for &trees in &self.num_trees {
            for &mtry in &self.mtry {
                for &min_leaf in &self.min_samples_leaf {
                    out.push(ForestConfig {
                        num_trees: trees,
                        tree: TreeConfig {
                            mtry,
                            min_samples_leaf: min_leaf,
                            ..base.tree
                        },
                        seed: base.seed,
                        threads: 1,
                    });
                }
            }
        }
        out
    }
}

/// Settings of one cross-validation run.
#[derive(Clone, Debug)]
pub struct TuneConfig {
    /// Folds (>= 2). Every sample is evaluated exactly once.
    pub folds: usize,
    /// Seed of the fold permutation. The forests' bagging/mtry streams
    /// are seeded by `base.seed` — `lmtuner tune` sets both from
    /// `--seed`, so one flag varies the whole run.
    pub seed: u64,
    /// Concurrent (config, fold) tasks. Affects wall time only — every
    /// metric is identical at any value.
    pub threads: usize,
    /// Template for every grid cell (engine, bins, depth, forest seed).
    pub base: ForestConfig,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            folds: 5,
            seed: 0x7E57,
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            base: ForestConfig::default(),
        }
    }
}

/// Cross-validated score of one grid cell (fold means; times are totals
/// across folds).
#[derive(Clone, Debug)]
pub struct ConfigScore {
    pub config: ForestConfig,
    /// Mean count-based accuracy over folds.
    pub count_based: f64,
    /// Population std-dev of count-based accuracy across folds.
    pub count_std: f64,
    /// Mean penalty-weighted accuracy over folds.
    pub penalty_weighted: f64,
    /// Worst per-instance penalty score seen in any fold.
    pub min_score: f64,
    /// Total fit wall time across folds (seconds).
    pub fit_seconds: f64,
    /// Total predict wall time across folds (seconds).
    pub predict_seconds: f64,
    pub folds: usize,
}

impl ConfigScore {
    /// One-line human-readable form (also used by `lmtuner tune`).
    pub fn render(&self) -> String {
        format!(
            "trees={:<3} mtry={:<2} min_leaf={:<2} count {:.3}±{:.3}  penalty {:.3}  \
             min {:.2}  fit {:.2}s predict {:.2}s",
            self.config.num_trees,
            self.config.tree.mtry,
            self.config.tree.min_samples_leaf,
            self.count_based,
            self.count_std,
            self.penalty_weighted,
            self.min_score,
            self.fit_seconds,
            self.predict_seconds
        )
    }
}

/// The full grid result. `scores` is in grid order; `best` indexes the
/// winner (highest mean count-based accuracy; ties go to the higher
/// penalty-weighted accuracy, then to the earlier — cheaper — grid
/// cell).
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub scores: Vec<ConfigScore>,
    pub best: usize,
    /// Instances cross-validated.
    pub rows: usize,
    pub folds: usize,
}

impl TuneOutcome {
    pub fn best_score(&self) -> &ConfigScore {
        &self.scores[self.best]
    }
}

struct FoldScore {
    count: f64,
    penalty: f64,
    min_score: f64,
    fit_s: f64,
    predict_s: f64,
}

/// Run the grid × k-fold cross-validation. Deterministic for a fixed
/// `cfg.seed`/`cfg.base.seed` at any `cfg.threads` (tested in
/// `rust/tests/mlcore.rs`).
pub fn cross_validate(
    records: &[TuneRecord],
    grid: &GridSpec,
    cfg: &TuneConfig,
) -> Result<TuneOutcome> {
    anyhow::ensure!(cfg.folds >= 2, "cross-validation needs >= 2 folds, got {}", cfg.folds);
    anyhow::ensure!(!grid.is_empty(), "empty hyperparameter grid");
    anyhow::ensure!(
        records.len() >= 2 * cfg.folds,
        "{} records cannot fill {} folds (need >= {})",
        records.len(),
        cfg.folds,
        2 * cfg.folds
    );
    // Fail fast on poisoned rows: one typed error up front beats one
    // per (config, fold) task. CV scores the primary (verdict) target,
    // so only the base records matter here — joint quality is graded
    // downstream by `coordinator::train`/`crossdev`.
    let bases: Vec<&SpeedupRecord> = records.iter().map(|r| &r.base).collect();
    Forest::validate_records(&bases)?;

    // Deterministic balanced fold assignment.
    let n = records.len();
    let mut order: Vec<usize> = (0..n).collect();
    Rng::new(cfg.seed ^ 0xF0_1D5).shuffle(&mut order);
    let fold_of = |pos: usize| pos % cfg.folds;

    let configs = grid.configs(&cfg.base);
    let config_ids: Vec<usize> = (0..configs.len()).collect();

    // One fold resident at a time: extract the fold's training matrix
    // and bin it ONCE (every grid config shares the columns and the
    // binning — both depend only on the data, not the hyperparameters),
    // fan the grid across the pool, then drop the fold before building
    // the next. Peak memory stays ~one training matrix regardless of
    // `folds`, and `fit_s` times exactly the per-config training work.
    struct FoldData {
        x: Vec<Vec<f64>>,
        y: Vec<f64>,
        /// `None` with the exact engine, which would ignore it anyway.
        bins: Option<BinnedDataset>,
        test: Vec<usize>,
    }
    let mut per_config: Vec<Vec<FoldScore>> =
        (0..configs.len()).map(|_| Vec::with_capacity(cfg.folds)).collect();
    for fi in 0..cfg.folds {
        let fd = {
            let train: Vec<&SpeedupRecord> = order
                .iter()
                .enumerate()
                .filter(|(pos, _)| fold_of(*pos) != fi)
                .map(|(_, &i)| &records[i].base)
                .collect();
            let test: Vec<usize> = order
                .iter()
                .enumerate()
                .filter(|(pos, _)| fold_of(*pos) == fi)
                .map(|(_, &i)| i)
                .collect();
            let (x, y) = Forest::columns(&train);
            let bins = match cfg.base.tree.engine {
                SplitEngine::Binned =>
                    Some(BinnedDataset::build(&x, cfg.base.tree.max_bins)),
                SplitEngine::Exact => None,
            };
            FoldData { x, y, bins, test }
        };

        let results: Vec<Result<FoldScore>> =
            parallel_map(&config_ids, cfg.threads, |&ci| -> Result<FoldScore> {
                let t0 = std::time::Instant::now();
                let forest = match &fd.bins {
                    Some(bins) => {
                        Forest::fit_prebinned(&fd.x, &fd.y, bins, &configs[ci])
                    }
                    None => Forest::fit(&fd.x, &fd.y, &configs[ci]),
                };
                let fit_s = t0.elapsed().as_secs_f64();

                let rows: Vec<&[f64]> = fd
                    .test
                    .iter()
                    .map(|&i| &records[i].base.features[..])
                    .collect();
                let t1 = std::time::Instant::now();
                // threads=1: parallelism lives at the grid level.
                let preds = forest.predict_batch_with(&rows, 1);
                let predict_s = t1.elapsed().as_secs_f64();

                let mut acc = AccuracyAccumulator::new();
                for (&i, p) in fd.test.iter().zip(&preds) {
                    acc.push_record(&records[i].base, *p > 0.0);
                }
                let a = acc.finish();
                Ok(FoldScore {
                    count: a.count_based,
                    penalty: a.penalty_weighted,
                    min_score: a.min_score,
                    fit_s,
                    predict_s,
                })
            });
        for (ci, r) in results.into_iter().enumerate() {
            per_config[ci].push(r?);
        }
    }

    let mut scores = Vec::with_capacity(configs.len());
    for (config, folds) in configs.into_iter().zip(per_config) {
        let k = folds.len() as f64;
        let count = folds.iter().map(|f| f.count).sum::<f64>() / k;
        let count_std = (folds
            .iter()
            .map(|f| (f.count - count) * (f.count - count))
            .sum::<f64>()
            / k)
            .sqrt();
        scores.push(ConfigScore {
            config,
            count_based: count,
            count_std,
            penalty_weighted: folds.iter().map(|f| f.penalty).sum::<f64>() / k,
            min_score: folds
                .iter()
                .map(|f| f.min_score)
                .fold(f64::INFINITY, f64::min),
            fit_seconds: folds.iter().map(|f| f.fit_s).sum(),
            predict_seconds: folds.iter().map(|f| f.predict_s).sum(),
            folds: cfg.folds,
        });
    }

    // Winner: strict improvement only, so grid order breaks exact ties
    // toward the earlier (cheaper) cell.
    let mut best = 0usize;
    for (i, s) in scores.iter().enumerate().skip(1) {
        let b = &scores[best];
        if s.count_based > b.count_based
            || (s.count_based == b.count_based
                && s.penalty_weighted > b.penalty_weighted)
        {
            best = i;
        }
    }

    Ok(TuneOutcome { scores, best, rows: n, folds: cfg.folds })
}

/// Write the per-config CV table. Metric columns are deterministic for
/// a fixed seed; the two `*_seconds` columns are wall-clock
/// measurements.
pub fn write_csv(out: &TuneOutcome, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
    }
    let mut s = String::from(
        "trees,mtry,min_leaf,folds,count_based,count_std,penalty_weighted,\
         min_score,fit_seconds,predict_seconds,best\n",
    );
    for (i, c) in out.scores.iter().enumerate() {
        s.push_str(&format!(
            "{},{},{},{},{:.6},{:.6},{:.6},{:.6},{:.4},{:.4},{}\n",
            c.config.num_trees,
            c.config.tree.mtry,
            c.config.tree.min_samples_leaf,
            c.folds,
            c.count_based,
            c.count_std,
            c.penalty_weighted,
            c.min_score,
            c.fit_seconds,
            c.predict_seconds,
            (i == out.best) as u8
        ));
    }
    std::fs::write(path, s).with_context(|| format!("write {}", path.display()))
}

/// Persist a forest config as the best-config summary `lmtuner train
/// --forest-config` / `crossdev --forest-config` consume. Runtime
/// concerns (seed, threads) are deliberately not persisted.
pub fn save_forest_config(cfg: &ForestConfig, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("create {}", dir.display()))?;
        }
    }
    let body = format!(
        "lmtuner-forest-config v1\n\
         trees={}\nmtry={}\nmin_leaf={}\nmax_depth={}\nengine={}\nbins={}\n",
        cfg.num_trees,
        cfg.tree.mtry,
        cfg.tree.min_samples_leaf,
        cfg.tree.max_depth,
        cfg.tree.engine,
        cfg.tree.max_bins
    );
    std::fs::write(path, body).with_context(|| format!("write {}", path.display()))
}

/// Load a best-config summary written by [`save_forest_config`].
/// Missing keys keep their defaults; unknown keys are an error (a typo
/// must not silently fall back to defaults).
pub fn load_forest_config(path: &Path) -> Result<ForestConfig> {
    let body = std::fs::read_to_string(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = body.lines();
    let header = lines.next().context("empty forest-config file")?;
    anyhow::ensure!(
        header.trim() == "lmtuner-forest-config v1",
        "bad forest-config header {header:?}"
    );
    let mut cfg = ForestConfig::default();
    // Numeric parse failures name the file and offending line, like
    // every other error path here — a bare ParseIntError would not.
    let num = |line: &str, value: &str| -> Result<usize> {
        value.trim().parse::<usize>().map_err(|e| {
            anyhow::anyhow!("bad forest-config line {line:?} in {}: {e}", path.display())
        })
    };
    for line in lines {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .with_context(|| format!("bad forest-config line {line:?}"))?;
        match key.trim() {
            "trees" => cfg.num_trees = num(line, value)?,
            "mtry" => cfg.tree.mtry = num(line, value)?,
            "min_leaf" => cfg.tree.min_samples_leaf = num(line, value)?,
            "max_depth" => cfg.tree.max_depth = num(line, value)?,
            "engine" => {
                cfg.tree.engine = value.trim().parse().map_err(|e| {
                    anyhow::anyhow!("in {}: {e}", path.display())
                })?
            }
            "bins" => cfg.tree.max_bins = num(line, value)?,
            other => bail!("unknown forest-config key {other:?} in {}", path.display()),
        }
    }
    // The same floor GridSpec::parse enforces on the CLI axes: a
    // hand-edited zero would otherwise fit a degenerate model (0 trees
    // predicts NaN; mtry 0 grows single-leaf stumps) without any error.
    anyhow::ensure!(
        cfg.num_trees >= 1
            && cfg.tree.mtry >= 1
            && cfg.tree.min_samples_leaf >= 1
            && cfg.tree.max_depth >= 1
            && cfg.tree.max_bins >= 2,
        "degenerate forest config in {} (trees/mtry/min_leaf/max_depth \
         must be >= 1, bins >= 2)",
        path.display()
    );
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;
    use crate::ml::tree::SplitEngine;

    fn synth_records(n: usize, seed: u64) -> Vec<TuneRecord> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let mut features = [0.0; NUM_FEATURES];
                for f in features.iter_mut() {
                    *f = rng.range_f64(-1.0, 1.0);
                }
                let signal = features[0] * 1.5 - features[3] + 0.2 * rng.normal();
                let speedup = signal.exp2().clamp(0.01, 100.0);
                TuneRecord::from_v1(SpeedupRecord {
                    name: format!("cv-{i}"),
                    features,
                    speedup,
                    baseline_time: 1.0,
                    optimized_time: 1.0 / speedup,
                })
            })
            .collect()
    }

    #[test]
    fn grid_parse_and_materialize() {
        let g = GridSpec::parse("5, 10", "2,4", "1").unwrap();
        assert_eq!(g.len(), 4);
        let cfgs = g.configs(&ForestConfig::default());
        assert_eq!(cfgs.len(), 4);
        assert_eq!(cfgs[0].num_trees, 5);
        assert_eq!(cfgs[0].tree.mtry, 2);
        assert_eq!(cfgs[3].num_trees, 10);
        assert_eq!(cfgs[3].tree.mtry, 4);
        assert!(cfgs.iter().all(|c| c.threads == 1));
        assert!(GridSpec::parse("5,x", "2", "1").is_err());
        assert!(GridSpec::parse("0", "2", "1").is_err());
    }

    #[test]
    fn cross_validate_scores_the_grid() {
        let records = synth_records(400, 0xCAFE);
        let grid = GridSpec {
            num_trees: vec![3, 8],
            mtry: vec![4],
            min_samples_leaf: vec![1],
        };
        let cfg = TuneConfig { folds: 4, threads: 2, ..Default::default() };
        let out = cross_validate(&records, &grid, &cfg).unwrap();
        assert_eq!(out.scores.len(), 2);
        assert_eq!(out.rows, 400);
        for s in &out.scores {
            assert!((0.0..=1.0).contains(&s.count_based), "{}", s.count_based);
            assert!((0.0..=1.0).contains(&s.penalty_weighted));
            assert!(s.fit_seconds >= 0.0 && s.predict_seconds >= 0.0);
            assert!(!s.render().is_empty());
        }
        // the learnable signal must beat coin flipping for some config
        assert!(out.best_score().count_based > 0.6, "{}", out.best_score().count_based);
    }

    #[test]
    fn cross_validate_rejects_bad_input() {
        let records = synth_records(30, 1);
        let grid = GridSpec::default();
        assert!(cross_validate(
            &records,
            &grid,
            &TuneConfig { folds: 1, ..Default::default() }
        )
        .is_err());
        assert!(cross_validate(
            &records[..4],
            &grid,
            &TuneConfig { folds: 5, ..Default::default() }
        )
        .is_err());
        let mut poisoned = synth_records(60, 2);
        poisoned[10].base.features[0] = f64::NAN;
        let err = cross_validate(
            &poisoned,
            &grid,
            &TuneConfig { folds: 3, ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("finite"), "{err:#}");
    }

    #[test]
    fn forest_config_roundtrip() {
        let mut cfg = ForestConfig::default();
        cfg.num_trees = 37;
        cfg.tree.mtry = 6;
        cfg.tree.min_samples_leaf = 3;
        cfg.tree.max_depth = 21;
        cfg.tree.engine = SplitEngine::Exact;
        cfg.tree.max_bins = 128;
        let path = std::env::temp_dir()
            .join(format!("lmtuner-fc-{}.txt", std::process::id()));
        save_forest_config(&cfg, &path).unwrap();
        let back = load_forest_config(&path).unwrap();
        assert_eq!(back.num_trees, 37);
        assert_eq!(back.tree.mtry, 6);
        assert_eq!(back.tree.min_samples_leaf, 3);
        assert_eq!(back.tree.max_depth, 21);
        assert_eq!(back.tree.engine, SplitEngine::Exact);
        assert_eq!(back.tree.max_bins, 128);
        // unknown keys are loud
        std::fs::write(&path, "lmtuner-forest-config v1\nforests=2\n").unwrap();
        assert!(load_forest_config(&path).is_err());
        // degenerate values are rejected like the CLI grid axes (a
        // 0-tree forest would predict NaN without any error)
        std::fs::write(&path, "lmtuner-forest-config v1\ntrees=0\n").unwrap();
        assert!(load_forest_config(&path).is_err());
        std::fs::write(&path, "lmtuner-forest-config v1\nmtry=0\n").unwrap();
        assert!(load_forest_config(&path).is_err());
        // bad header is loud
        std::fs::write(&path, "not a config\n").unwrap();
        assert!(load_forest_config(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn csv_has_one_row_per_config_plus_header() {
        let records = synth_records(120, 9);
        let grid = GridSpec {
            num_trees: vec![3],
            mtry: vec![2, 4],
            min_samples_leaf: vec![1],
        };
        let out = cross_validate(
            &records,
            &grid,
            &TuneConfig { folds: 3, threads: 1, ..Default::default() },
        )
        .unwrap();
        let path = std::env::temp_dir()
            .join(format!("lmtuner-tunecsv-{}.csv", std::process::id()));
        write_csv(&out, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 1 + 2);
        assert!(lines[0].starts_with("trees,mtry,min_leaf,folds,count_based"));
        // exactly one row is flagged best
        let bests = lines[1..]
            .iter()
            .filter(|l| l.ends_with(",1"))
            .count();
        assert_eq!(bests, 1);
        std::fs::remove_file(&path).ok();
    }
}
