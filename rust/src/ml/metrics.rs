//! The paper's two accuracy metrics (§5.1).
//!
//! * **Count-based accuracy**: fraction of instances where the model's
//!   use/don't-use decision matches the oracle decision.
//! * **Penalty-weighted accuracy**: a mis-prediction scores the
//!   performance ratio achieved/optimal (in (0,1)) instead of 0 — the
//!   percentage of oracle performance the model's decisions deliver.
//!   Reported with min/max per-instance scores (the Fig. 6 error bars).

use crate::sim::exec::SpeedupRecord;

#[derive(Clone, Copy, Debug, Default)]
pub struct Accuracy {
    pub count_based: f64,
    pub penalty_weighted: f64,
    /// Worst per-instance penalty-weighted score.
    pub min_score: f64,
    /// Best per-instance penalty-weighted score.
    pub max_score: f64,
    pub n: usize,
    /// Instances dropped by the skip-and-count guard: non-finite or
    /// <= 0 speedups carry no usable oracle label, so they are excluded
    /// from every metric and tallied here instead of poisoning the
    /// means with NaN.
    pub skipped: usize,
}

/// Per-instance penalty-weighted score of deciding `use_lmem` when the
/// true speedup is `speedup` (= t_base / t_opt):
///   correct        -> 1
///   said yes, lost -> t_best / t_chosen = speedup (< 1)
///   said no, lost  -> 1 / speedup       (< 1)
///
/// The score is only defined for finite, strictly positive speedups
/// (both branches take a ratio or compare against 1.0). An invalid
/// speedup returns an *explicit* NaN so accidental use stays loud;
/// streaming callers never see it — [`AccuracyAccumulator::push`]
/// skips-and-counts invalid instances before scoring.
pub fn instance_score(speedup: f64, use_lmem: bool) -> f64 {
    if !(speedup.is_finite() && speedup > 0.0) {
        return f64::NAN;
    }
    let oracle = speedup > 1.0;
    if use_lmem == oracle {
        1.0
    } else if use_lmem {
        speedup.min(1.0)
    } else {
        (1.0 / speedup).min(1.0)
    }
}

/// Streaming accuracy accumulator: push one (record, decision) pair at
/// a time, read the metrics out at the end. O(1) memory, which is what
/// lets the sharded training pipeline evaluate millions of instances
/// without holding any of them. `evaluate` and `evaluate_model` are
/// thin wrappers over this.
#[derive(Clone, Copy, Debug, Default)]
pub struct AccuracyAccumulator {
    correct: usize,
    sum: f64,
    min: f64,
    max: f64,
    n: usize,
    skipped: usize,
}

impl AccuracyAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Score one instance: the true measured speedup and the model's
    /// use/don't-use decision.
    ///
    /// Guard policy (**skip-and-count**): a non-finite or <= 0 speedup
    /// has no oracle label and no defined penalty score, so the
    /// instance is excluded from every metric and counted in
    /// [`Accuracy::skipped`] — it never contributes NaN or a negative
    /// "score" to the reported accuracy.
    pub fn push(&mut self, speedup: f64, use_lmem: bool) {
        if !(speedup.is_finite() && speedup > 0.0) {
            self.skipped += 1;
            return;
        }
        let oracle = speedup > 1.0;
        if use_lmem == oracle {
            self.correct += 1;
        }
        let s = instance_score(speedup, use_lmem);
        self.sum += s;
        self.min = if self.n == 0 { s } else { self.min.min(s) };
        self.max = if self.n == 0 { s } else { self.max.max(s) };
        self.n += 1;
    }

    pub fn push_record(&mut self, rec: &SpeedupRecord, use_lmem: bool) {
        self.push(rec.speedup, use_lmem);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Instances rejected by the skip-and-count guard so far.
    pub fn skipped(&self) -> usize {
        self.skipped
    }

    pub fn finish(&self) -> Accuracy {
        if self.n == 0 {
            return Accuracy { skipped: self.skipped, ..Accuracy::default() };
        }
        Accuracy {
            count_based: self.correct as f64 / self.n as f64,
            penalty_weighted: self.sum / self.n as f64,
            min_score: self.min,
            max_score: self.max,
            n: self.n,
            skipped: self.skipped,
        }
    }
}

/// Workgroup-size recommendations are graded top-k: a hit means the
/// measured-best shape is among the k shapes nearest the model's
/// prediction. k = 3 mirrors the paper's practice of trying a small
/// shortlist of configurations at install time.
pub const WG_TOP_K: usize = 3;

/// The `k` valid workgroup shapes nearest a predicted (log2 w, log2 h)
/// point. Candidates are every power-of-two rectangle within the
/// device-portfolio thread budget (w*h <= 1024, i.e. exponents i+j <= 10),
/// ranked by squared distance in log2 space with deterministic
/// (score, (w, h)) tie-breaking.
pub fn wg_candidates(log2_w: f64, log2_h: f64, k: usize) -> Vec<(u32, u32)> {
    let mut scored: Vec<(f64, (u32, u32))> = Vec::with_capacity(66);
    for i in 0..=10u32 {
        for j in 0..=(10 - i) {
            let dw = i as f64 - log2_w;
            let dh = j as f64 - log2_h;
            scored.push((dw * dw + dh * dh, (1 << i, 1 << j)));
        }
    }
    scored.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    scored.truncate(k);
    scored.into_iter().map(|(_, wg)| wg).collect()
}

/// Snap a predicted (log2 w, log2 h) to the single nearest valid shape.
pub fn snap_wg(log2_w: f64, log2_h: f64) -> (u32, u32) {
    wg_candidates(log2_w, log2_h, 1)[0]
}

/// Joint accuracy for schema-v2 (multi-output) models: the local-memory
/// verdict metrics plus how often the workgroup recommendation lands.
#[derive(Clone, Copy, Debug, Default)]
pub struct JointAccuracy {
    /// The paper's verdict metrics, unchanged.
    pub verdict: Accuracy,
    /// Fraction of instances whose measured-best workgroup shape is in
    /// the model's top-k shortlist.
    pub wg_hit_rate: f64,
    /// Fraction where BOTH the verdict is correct AND the workgroup
    /// shortlist hits — the "full recommendation is right" rate.
    pub joint: f64,
    /// The k used for the shortlist ([`WG_TOP_K`] unless overridden).
    pub top_k: usize,
    pub n: usize,
    /// Instances without a usable (speedup, wg-label) pair.
    pub skipped: usize,
}

/// Streaming accumulator for [`JointAccuracy`]. Same O(1)-memory,
/// skip-and-count contract as [`AccuracyAccumulator`]: an instance with
/// an invalid speedup OR no workgroup label is excluded from every
/// joint metric (including the verdict component, so `verdict.n == n`).
#[derive(Clone, Debug)]
pub struct JointAccumulator {
    verdict: AccuracyAccumulator,
    wg_hits: usize,
    joint_hits: usize,
    top_k: usize,
    n: usize,
    skipped: usize,
}

impl Default for JointAccumulator {
    fn default() -> Self {
        Self::new()
    }
}

impl JointAccumulator {
    pub fn new() -> Self {
        JointAccumulator {
            verdict: AccuracyAccumulator::new(),
            wg_hits: 0,
            joint_hits: 0,
            top_k: WG_TOP_K,
            n: 0,
            skipped: 0,
        }
    }

    /// Score one instance: the measured speedup, the model's verdict,
    /// the measured-best workgroup shape (None = unlabeled v1 record),
    /// and the model's predicted (log2 w, log2 h).
    pub fn push(
        &mut self,
        speedup: f64,
        use_lmem: bool,
        label_wg: Option<(u32, u32)>,
        pred_logs: (f64, f64),
    ) {
        let label = match label_wg {
            Some(wg) if speedup.is_finite() && speedup > 0.0 => wg,
            _ => {
                self.skipped += 1;
                return;
            }
        };
        self.verdict.push(speedup, use_lmem);
        let hit = wg_candidates(pred_logs.0, pred_logs.1, self.top_k)
            .contains(&label);
        let verdict_correct = use_lmem == (speedup > 1.0);
        if hit {
            self.wg_hits += 1;
            if verdict_correct {
                self.joint_hits += 1;
            }
        }
        self.n += 1;
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn skipped(&self) -> usize {
        self.skipped
    }

    pub fn finish(&self) -> JointAccuracy {
        if self.n == 0 {
            return JointAccuracy {
                top_k: self.top_k,
                skipped: self.skipped,
                ..JointAccuracy::default()
            };
        }
        JointAccuracy {
            verdict: self.verdict.finish(),
            wg_hit_rate: self.wg_hits as f64 / self.n as f64,
            joint: self.joint_hits as f64 / self.n as f64,
            top_k: self.top_k,
            n: self.n,
            skipped: self.skipped,
        }
    }
}

/// Evaluate decisions against oracle records.
pub fn evaluate(records: &[&SpeedupRecord], decisions: &[bool]) -> Accuracy {
    assert_eq!(records.len(), decisions.len());
    let mut acc = AccuracyAccumulator::new();
    for (r, &d) in records.iter().zip(decisions) {
        acc.push(r.speedup, d);
    }
    acc.finish()
}

/// Evaluate a prediction function (e.g. the forest) on records.
pub fn evaluate_model<F: FnMut(&[f64]) -> bool>(
    records: &[&SpeedupRecord],
    mut decide: F,
) -> Accuracy {
    let decisions: Vec<bool> =
        records.iter().map(|r| decide(&r.features)).collect();
    evaluate(records, &decisions)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;

    fn rec(speedup: f64) -> SpeedupRecord {
        SpeedupRecord {
            name: "t".into(),
            features: [0.0; NUM_FEATURES],
            speedup,
            baseline_time: 1.0,
            optimized_time: 1.0 / speedup,
        }
    }

    #[test]
    fn perfect_decisions_score_one() {
        let rs = [rec(2.0), rec(0.5), rec(10.0)];
        let refs: Vec<&SpeedupRecord> = rs.iter().collect();
        let a = evaluate(&refs, &[true, false, true]);
        assert_eq!(a.count_based, 1.0);
        assert_eq!(a.penalty_weighted, 1.0);
        assert_eq!(a.min_score, 1.0);
    }

    #[test]
    fn wrong_yes_scores_speedup() {
        // speedup 0.5, said yes: we run at half the oracle's speed.
        assert_eq!(instance_score(0.5, true), 0.5);
        // speedup 4, said no: we forgo 4x.
        assert_eq!(instance_score(4.0, false), 0.25);
    }

    #[test]
    fn penalty_weighted_exceeds_count_based() {
        // All decisions wrong but mildly: count = 0, penalty > 0.
        let rs = [rec(1.25), rec(0.8)];
        let refs: Vec<&SpeedupRecord> = rs.iter().collect();
        let a = evaluate(&refs, &[false, true]);
        assert_eq!(a.count_based, 0.0);
        assert!(a.penalty_weighted > 0.75);
        assert!(a.penalty_weighted < 1.0);
    }

    #[test]
    fn min_max_track_extremes() {
        let rs = [rec(10.0), rec(2.0), rec(0.9)];
        let refs: Vec<&SpeedupRecord> = rs.iter().collect();
        // miss the 10x, hit the others
        let a = evaluate(&refs, &[false, true, false]);
        assert!((a.min_score - 0.1).abs() < 1e-12);
        assert_eq!(a.max_score, 1.0);
    }

    #[test]
    fn empty_input_is_zeroed() {
        let a = evaluate(&[], &[]);
        assert_eq!(a.n, 0);
        assert_eq!(a.count_based, 0.0);
    }

    #[test]
    fn invalid_speedups_are_skipped_and_counted() {
        // NaN / inf / 0 / negative speedups must not poison the metrics:
        // the documented skip-and-count policy excludes them entirely.
        let mut acc = AccuracyAccumulator::new();
        acc.push(2.0, true); // valid, correct
        acc.push(f64::NAN, true);
        acc.push(f64::INFINITY, false);
        acc.push(0.0, false);
        acc.push(-3.0, true);
        acc.push(0.5, false); // valid, correct
        let a = acc.finish();
        assert_eq!(a.n, 2);
        assert_eq!(a.skipped, 4);
        assert_eq!(acc.skipped(), 4);
        assert_eq!(a.count_based, 1.0);
        assert_eq!(a.penalty_weighted, 1.0);
        assert!(a.min_score.is_finite() && a.max_score.is_finite());

        // all-invalid input degrades to the zeroed default + the tally
        let mut bad = AccuracyAccumulator::new();
        bad.push(f64::NEG_INFINITY, true);
        let b = bad.finish();
        assert_eq!(b.n, 0);
        assert_eq!(b.skipped, 1);
        assert_eq!(b.count_based, 0.0);
    }

    #[test]
    fn instance_score_is_nan_for_invalid_speedups() {
        for s in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.5] {
            assert!(instance_score(s, true).is_nan(), "{s}");
            assert!(instance_score(s, false).is_nan(), "{s}");
        }
        // valid inputs are untouched by the guard
        assert_eq!(instance_score(2.0, true), 1.0);
        assert_eq!(instance_score(0.5, true), 0.5);
    }

    #[test]
    fn wg_candidates_rank_by_log2_distance_with_stable_ties() {
        // Exact prediction: the labeled shape ranks first.
        assert_eq!(snap_wg(5.0, 3.0), (32, 8));
        // Between two shapes: both appear, smaller (w, h) first on ties.
        let c = wg_candidates(4.5, 3.0, 2);
        assert_eq!(c, vec![(16, 8), (32, 8)]);
        // The thread budget binds: exponents sum to <= 10.
        for k in 1..=10 {
            for &(w, h) in &wg_candidates(10.0, 10.0, k) {
                assert!(w as u64 * h as u64 <= 1024);
                assert!(w.is_power_of_two() && h.is_power_of_two());
            }
        }
        // Requesting more than all 66 shapes just returns all of them.
        assert_eq!(wg_candidates(0.0, 0.0, 1000).len(), 66);
    }

    #[test]
    fn joint_accumulator_composes_verdict_and_wg_hits() {
        let mut acc = JointAccumulator::new();
        // verdict right + wg in top-3 -> joint hit
        acc.push(2.0, true, Some((32, 8)), (5.0, 3.0));
        // verdict right, wg far off -> wg miss
        acc.push(2.0, true, Some((1, 1)), (5.0, 3.0));
        // verdict wrong, wg exact -> wg hit but no joint hit
        acc.push(2.0, false, Some((32, 8)), (5.0, 3.0));
        // unlabeled and invalid rows are skipped, even with a verdict
        acc.push(2.0, true, None, (5.0, 3.0));
        acc.push(f64::NAN, true, Some((32, 8)), (5.0, 3.0));
        let j = acc.finish();
        assert_eq!(j.n, 3);
        assert_eq!(j.skipped, 2);
        assert_eq!(j.verdict.n, 3);
        assert!((j.verdict.count_based - 2.0 / 3.0).abs() < 1e-12);
        assert!((j.wg_hit_rate - 2.0 / 3.0).abs() < 1e-12);
        assert!((j.joint - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(j.top_k, WG_TOP_K);

        let empty = JointAccumulator::new().finish();
        assert_eq!(empty.n, 0);
        assert_eq!(empty.joint, 0.0);
    }

    #[test]
    fn accumulator_matches_batch_evaluate() {
        let rs = [rec(10.0), rec(2.0), rec(0.9), rec(0.3), rec(1.1)];
        let ds = [false, true, false, true, true];
        let refs: Vec<&SpeedupRecord> = rs.iter().collect();
        let batch = evaluate(&refs, &ds);
        let mut acc = AccuracyAccumulator::new();
        for (r, &d) in rs.iter().zip(&ds) {
            acc.push_record(r, d);
        }
        let streamed = acc.finish();
        assert_eq!(streamed.count_based, batch.count_based);
        assert_eq!(streamed.penalty_weighted, batch.penalty_weighted);
        assert_eq!(streamed.min_score, batch.min_score);
        assert_eq!(streamed.max_score, batch.max_score);
        assert_eq!(streamed.n, batch.n);
        assert_eq!(acc.n(), 5);
    }
}
