//! Machine-learning substrate built from scratch: CART regression trees
//! (exact + pre-binned split engines), Random Forest (paper §5.1: 20
//! trees, 4 attributes/node), the paper's two accuracy metrics,
//! deterministic k-fold model selection (`select`), tensor export for
//! the PJRT inference path, and model persistence.
pub mod binning;
pub mod export;
pub mod forest;
pub mod io;
pub mod metrics;
pub mod select;
pub mod tree;
