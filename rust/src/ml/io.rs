//! Forest persistence: a compact line-oriented text format (serde is not
//! available). One header line, then one line per node per tree.
//!
//! Format v1 (single-output forests — written bit-for-bit as before):
//!   lmtuner-forest v1 trees=<T>
//!   tree <i> nodes=<n>
//!   S <feature> <threshold> <left> <right> <mean>
//!   L <value>
//!   ...
//!
//! Format v2 (multi-output forests, dataset schema v2): the header
//! declares the output arity and every node line appends the K-1 extra
//! per-node means after the primary fields:
//!   lmtuner-forest v2 trees=<T> outputs=<K>
//!   S <feature> <threshold> <left> <right> <mean> <extra_1> .. <extra_{K-1}>
//!   L <value> <extra_1> .. <extra_{K-1}>

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::forest::Forest;
use super::tree::{Node, Tree};

/// Upper bound on the persisted output arity: far above anything the
/// label plane produces (3), low enough that a hostile header cannot
/// drive per-node allocations.
const MAX_OUTPUTS: usize = 16;

/// A model whose output arity does not match what the caller's dataset
/// schema requires — e.g. evaluating a single-output (v1) forest against
/// a joint (schema v2) dataset, or vice versa. Typed so the CLI can
/// reject the pair with a clear message instead of silently scoring
/// garbage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArityMismatch {
    pub model_outputs: usize,
    pub expected: usize,
    pub at: String,
}

impl std::fmt::Display for ArityMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "output arity mismatch at {}: model predicts {} output(s), \
             dataset schema needs {}",
            self.at, self.model_outputs, self.expected
        )
    }
}

impl std::error::Error for ArityMismatch {}

/// Reject a forest whose output arity disagrees with `expected` (the
/// dataset schema's `outputs()`).
pub fn ensure_output_arity(forest: &Forest, expected: usize, at: &str) -> Result<()> {
    let model_outputs = forest.num_outputs();
    if model_outputs != expected {
        bail!(ArityMismatch { model_outputs, expected, at: at.to_string() });
    }
    Ok(())
}

pub fn save(forest: &Forest, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let outputs = forest.num_outputs();
    if outputs == 1 {
        writeln!(w, "lmtuner-forest v1 trees={}", forest.trees.len())?;
    } else {
        writeln!(
            w,
            "lmtuner-forest v2 trees={} outputs={outputs}",
            forest.trees.len()
        )?;
    }
    writeln!(w, "# {}", forest.config_summary)?;
    for (i, t) in forest.trees.iter().enumerate() {
        writeln!(w, "tree {i} nodes={}", t.nodes.len())?;
        for (ni, n) in t.nodes.iter().enumerate() {
            match n {
                Node::Split { feature, threshold, left, right, mean } => {
                    write!(w, "S {feature} {threshold:e} {left} {right} {mean:e}")?;
                }
                Node::Leaf { value } => write!(w, "L {value:e}")?,
            }
            for plane in &t.extra {
                write!(w, " {:e}", plane[ni])?;
            }
            writeln!(w)?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Close the in-flight tree, enforcing its declared node count — a
/// truncated or concatenated file must never load as a silently-wrong
/// model (e.g. a 5-node tree collapsed to its first leaf would still
/// pass `validate()`).
fn close_tree(
    trees: &mut Vec<Tree>,
    current: Option<(usize, Vec<Node>, Vec<Vec<f64>>)>,
) -> Result<()> {
    if let Some((declared, nodes, extra)) = current {
        if nodes.len() != declared {
            bail!(
                "tree {}: declared {declared} nodes, found {} — truncated \
                 or corrupt forest file",
                trees.len(),
                nodes.len()
            );
        }
        trees.push(Tree { nodes, extra });
    }
    Ok(())
}

/// Parse the header line into (tree count, output arity).
fn parse_header(header: &str) -> Result<(usize, usize)> {
    if let Some(rest) = header.strip_prefix("lmtuner-forest v1 trees=") {
        return Ok((rest.parse()?, 1));
    }
    if let Some(rest) = header.strip_prefix("lmtuner-forest v2 trees=") {
        let (t_part, o_part) = rest
            .split_once(" outputs=")
            .with_context(|| format!("bad v2 header {header:?}"))?;
        let outputs: usize = o_part.parse()?;
        if outputs < 2 || outputs > MAX_OUTPUTS {
            bail!("bad output arity {outputs} in header {header:?}");
        }
        return Ok((t_part.parse()?, outputs));
    }
    bail!("bad header {header:?}")
}

pub fn load(path: &Path) -> Result<Forest> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().context("empty forest file")??;
    let (trees_expected, outputs) = parse_header(&header)?;
    let num_extra = outputs - 1;
    // Declared counts are untrusted (the file may be corrupt or hostile):
    // cap the pre-allocation so a bogus header cannot trigger a
    // capacity-overflow panic or a multi-GB allocation. Real counts are
    // re-checked against the parsed content below.
    const MAX_PREALLOC: usize = 1 << 20;
    let mut trees: Vec<Tree> = Vec::with_capacity(trees_expected.min(MAX_PREALLOC));
    let mut summary: Option<String> = None;
    let mut current: Option<(usize, Vec<Node>, Vec<Vec<f64>>)> = None;
    for line in lines {
        let line = line?;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            // The first comment line is the persisted config summary.
            if summary.is_none() {
                summary = Some(rest.trim().to_string());
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("tree ") {
            close_tree(&mut trees, current.take())?;
            let (idx_part, n_part) = rest
                .split_once(" nodes=")
                .with_context(|| format!("bad tree line {line:?}"))?;
            let idx: usize = idx_part
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("bad tree index in {line:?}: {e}"))?;
            if idx != trees.len() {
                bail!(
                    "tree index {idx} out of order (expected {}) — forest \
                     file corrupt or spliced",
                    trees.len()
                );
            }
            let n: usize = n_part.parse()?;
            current = Some((
                n,
                Vec::with_capacity(n.min(MAX_PREALLOC)),
                vec![Vec::with_capacity(n.min(MAX_PREALLOC)); num_extra],
            ));
        } else if let Some((_, ref mut nodes, ref mut extra)) = current {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("S") => {
                    let feature: usize = it.next().context("S feature")?.parse()?;
                    let threshold: f64 = it.next().context("S thr")?.parse()?;
                    // f64::parse happily accepts "NaN"/"inf", but a
                    // non-finite threshold can never come from training
                    // and would poison routing (NaN compares false, so
                    // every row silently goes right).
                    if !threshold.is_finite() {
                        bail!("non-finite split threshold {threshold} in {line:?}");
                    }
                    let left: usize = it.next().context("S left")?.parse()?;
                    let right: usize = it.next().context("S right")?.parse()?;
                    let mean: f64 = it.next().context("S mean")?.parse()?;
                    nodes.push(Node::Split { feature, threshold, left, right, mean });
                }
                Some("L") => {
                    let value: f64 = it.next().context("L value")?.parse()?;
                    nodes.push(Node::Leaf { value });
                }
                other => bail!("bad node line {other:?}"),
            }
            for plane in extra.iter_mut() {
                let v: f64 = it
                    .next()
                    .with_context(|| {
                        format!("node line missing extra output: {line:?}")
                    })?
                    .parse()?;
                plane.push(v);
            }
        } else {
            bail!("node line before any tree header: {line:?}");
        }
    }
    close_tree(&mut trees, current.take())?;
    if trees.len() != trees_expected {
        bail!("expected {trees_expected} trees, found {}", trees.len());
    }
    for (i, t) in trees.iter().enumerate() {
        t.validate().map_err(|e| anyhow::anyhow!("tree {i}: {e}"))?;
    }
    // Restore the persisted config summary; legacy files without the
    // `#` header line fall back to a provenance note.
    let config_summary =
        summary.unwrap_or_else(|| format!("loaded from {}", path.display()));
    Ok(Forest { trees, config_summary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::forest::ForestConfig;
    use crate::util::prng::Rng;

    fn toy_forest() -> Forest {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..200).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..200).map(|i| x[0][i] * 2.0 + x[2][i]).collect();
        Forest::fit(&x, &y, &ForestConfig { num_trees: 4, threads: 1, ..Default::default() })
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lmtuner-forest-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_predictions_and_summary() {
        let f = toy_forest();
        let path = tmp("rt");
        save(&f, &path).unwrap();
        let g = load(&path).unwrap();
        assert_eq!(f.trees.len(), g.trees.len());
        // the persisted `#` header line restores the config summary
        // (it used to come back as "loaded from <path>")
        assert_eq!(f.config_summary, g.config_summary);
        assert!(g.config_summary.contains("trees=4"), "{}", g.config_summary);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let p = [
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
            ];
            assert!((f.predict(&p) - g.predict(&p)).abs() < 1e-12);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn legacy_files_without_summary_get_a_provenance_note() {
        let path = tmp("legacy");
        std::fs::write(&path, "lmtuner-forest v1 trees=1\ntree 0 nodes=1\nL 0.5\n")
            .unwrap();
        let g = load(&path).unwrap();
        assert!(g.config_summary.contains("loaded from"), "{}", g.config_summary);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn absurd_declared_counts_are_rejected_without_allocating() {
        // A hostile header must neither panic (capacity overflow) nor
        // reserve gigabytes — it fails the count re-check instead.
        let path = tmp("huge");
        let huge = usize::MAX;
        std::fs::write(
            &path,
            format!("lmtuner-forest v1 trees=1\ntree 0 nodes={huge}\nL 0.5\n"),
        )
        .unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, format!("lmtuner-forest v1 trees={huge}\n")).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_forest_files_are_rejected() {
        let f = toy_forest();
        let path = tmp("trunc");
        save(&f, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = body.lines().collect();
        // Chop the file at several points: every prefix that ends
        // mid-tree must fail the declared-node-count check instead of
        // loading a silently smaller model.
        for keep in [lines.len() - 1, lines.len() - 3, 2 * lines.len() / 3] {
            let cut = lines[..keep].join("\n");
            std::fs::write(&path, &cut).unwrap();
            assert!(
                load(&path).is_err(),
                "truncation to {keep}/{} lines was accepted",
                lines.len()
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn declared_node_count_is_enforced() {
        let path = tmp("count");
        // 5 declared, 1 present, next tree header follows: the old
        // loader accepted this as a 1-leaf tree that passes validate().
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=2\n\
             tree 0 nodes=5\nL 0.5\n\
             tree 1 nodes=1\nL 0.25\n",
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("declared 5"), "{err:#}");
        // over-long trees are rejected the same way
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=1\ntree 0 nodes=1\nL 0.5\nL 0.6\n",
        )
        .unwrap();
        assert!(load(&path).is_err(), "extra node accepted");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tree_indices_must_be_sequential() {
        let path = tmp("order");
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=2\n\
             tree 1 nodes=1\nL 0.5\n\
             tree 0 nodes=1\nL 0.25\n",
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("out of order"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, "not a forest\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "lmtuner-forest v1 trees=2\ntree 0 nodes=1\nL 0.5\n")
            .unwrap();
        assert!(load(&path).is_err(), "tree count mismatch accepted");
        std::fs::remove_file(&path).ok();
    }

    fn toy_joint() -> Forest {
        let mut rng = Rng::new(17);
        let x: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..200).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> = (0..200).map(|i| x[0][i] * 2.0 + x[2][i]).collect();
        let lw: Vec<f64> =
            (0..200).map(|i| if x[1][i] > 0.0 { 5.0 } else { 2.0 }).collect();
        let lh: Vec<f64> =
            (0..200).map(|i| if x[2][i] > 0.0 { 3.0 } else { 0.0 }).collect();
        Forest::fit_multi(
            &x,
            &y,
            &[lw, lh],
            &ForestConfig { num_trees: 4, threads: 1, ..Default::default() },
        )
    }

    #[test]
    fn joint_roundtrip_preserves_every_output() {
        let f = toy_joint();
        let path = tmp("joint");
        save(&f, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(
            body.starts_with("lmtuner-forest v2 trees=4 outputs=3\n"),
            "{}",
            body.lines().next().unwrap()
        );
        let g = load(&path).unwrap();
        assert_eq!(g.num_outputs(), 3);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let p = [
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
            ];
            assert!((f.predict(&p) - g.predict(&p)).abs() < 1e-12);
            assert!((f.predict_extra(&p, 0) - g.predict_extra(&p, 0)).abs() < 1e-12);
            assert!((f.predict_extra(&p, 1) - g.predict_extra(&p, 1)).abs() < 1e-12);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_output_forests_still_save_as_v1() {
        let f = toy_forest();
        let path = tmp("stillv1");
        save(&f, &path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("lmtuner-forest v1 trees=4\n"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v2_node_lines_must_carry_the_declared_extras() {
        let path = tmp("shortline");
        std::fs::write(
            &path,
            "lmtuner-forest v2 trees=1 outputs=3\n\
             tree 0 nodes=1\nL 0.5 1.0\n",
        )
        .unwrap();
        let err = load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("missing extra"), "{err:#}");
        // absurd arities are rejected before any per-node allocation
        std::fs::write(&path, "lmtuner-forest v2 trees=1 outputs=9999\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn output_arity_mismatches_are_typed() {
        let single = toy_forest();
        let joint = toy_joint();
        assert!(ensure_output_arity(&single, 1, "test").is_ok());
        assert!(ensure_output_arity(&joint, 3, "test").is_ok());
        let err = ensure_output_arity(&single, 3, "eval --model m.txt").unwrap_err();
        let m = err.downcast_ref::<ArityMismatch>().expect("typed error");
        assert_eq!(m.model_outputs, 1);
        assert_eq!(m.expected, 3);
        assert!(format!("{m}").contains("arity mismatch"), "{m}");
        assert!(ensure_output_arity(&joint, 1, "test").is_err());
    }

    #[test]
    fn hand_corrupted_model_files_cannot_reach_the_executors() {
        let path = tmp("corrupt");
        // Non-finite thresholds parse fine as f64 ("NaN"/"inf") but are
        // rejected at load with a pointed error.
        for bad in ["NaN", "inf", "-inf"] {
            std::fs::write(
                &path,
                format!(
                    "lmtuner-forest v1 trees=1\ntree 0 nodes=3\n\
                     S 0 {bad} 1 2 0.0\nL -1.0\nL 1.0\n"
                ),
            )
            .unwrap();
            let err = load(&path).unwrap_err();
            assert!(format!("{err:#}").contains("non-finite"), "{bad}: {err:#}");
        }
        // An out-of-range feature index is structurally fine per tree
        // (the text format does not know the contract width), so it
        // loads — but the hardened encoded-forest validation rejects it
        // before any executor is built on top.
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=1\ntree 0 nodes=3\n\
             S 99 0.5 1 2 0.0\nL -1.0\nL 1.0\n",
        )
        .unwrap();
        let g = load(&path).unwrap();
        let enc = crate::ml::export::encode(
            &g,
            crate::ml::export::ExportContract::default(),
        );
        let err = enc.validate().unwrap_err();
        assert!(err.contains("feature index"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_validates_structure() {
        let path = tmp("cycle");
        // split pointing at itself -> invalid
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=1\ntree 0 nodes=1\nS 0 0.0 0 0 0.0\n",
        )
        .unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
