//! Forest persistence: a compact line-oriented text format (serde is not
//! available). One header line, then one line per node per tree.
//!
//! Format v1:
//!   lmtuner-forest v1 trees=<T>
//!   tree <i> nodes=<n>
//!   S <feature> <threshold> <left> <right> <mean>
//!   L <value>
//!   ...

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::forest::Forest;
use super::tree::{Node, Tree};

pub fn save(forest: &Forest, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "lmtuner-forest v1 trees={}", forest.trees.len())?;
    writeln!(w, "# {}", forest.config_summary)?;
    for (i, t) in forest.trees.iter().enumerate() {
        writeln!(w, "tree {i} nodes={}", t.nodes.len())?;
        for n in &t.nodes {
            match n {
                Node::Split { feature, threshold, left, right, mean } => {
                    writeln!(w, "S {feature} {threshold:e} {left} {right} {mean:e}")?;
                }
                Node::Leaf { value } => writeln!(w, "L {value:e}")?,
            }
        }
    }
    w.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<Forest> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header = lines.next().context("empty forest file")??;
    let trees_expected: usize = header
        .strip_prefix("lmtuner-forest v1 trees=")
        .with_context(|| format!("bad header {header:?}"))?
        .parse()?;
    let mut trees: Vec<Tree> = Vec::with_capacity(trees_expected);
    let mut current: Option<(usize, Vec<Node>)> = None;
    for line in lines {
        let line = line?;
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix("tree ") {
            if let Some((_, nodes)) = current.take() {
                trees.push(Tree { nodes });
            }
            let nodes_part = rest
                .split_once(" nodes=")
                .with_context(|| format!("bad tree line {line:?}"))?;
            let n: usize = nodes_part.1.parse()?;
            current = Some((n, Vec::with_capacity(n)));
        } else if let Some((_, ref mut nodes)) = current {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("S") => {
                    let feature: usize = it.next().context("S feature")?.parse()?;
                    let threshold: f64 = it.next().context("S thr")?.parse()?;
                    let left: usize = it.next().context("S left")?.parse()?;
                    let right: usize = it.next().context("S right")?.parse()?;
                    let mean: f64 = it.next().context("S mean")?.parse()?;
                    nodes.push(Node::Split { feature, threshold, left, right, mean });
                }
                Some("L") => {
                    let value: f64 = it.next().context("L value")?.parse()?;
                    nodes.push(Node::Leaf { value });
                }
                other => bail!("bad node line {other:?}"),
            }
        } else {
            bail!("node line before any tree header: {line:?}");
        }
    }
    if let Some((_, nodes)) = current.take() {
        trees.push(Tree { nodes });
    }
    if trees.len() != trees_expected {
        bail!("expected {trees_expected} trees, found {}", trees.len());
    }
    for (i, t) in trees.iter().enumerate() {
        t.validate().map_err(|e| anyhow::anyhow!("tree {i}: {e}"))?;
    }
    Ok(Forest { trees, config_summary: format!("loaded from {}", path.display()) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::forest::ForestConfig;
    use crate::util::prng::Rng;

    fn toy_forest() -> Forest {
        let mut rng = Rng::new(3);
        let x: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..200).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..200).map(|i| x[0][i] * 2.0 + x[2][i]).collect();
        Forest::fit(&x, &y, &ForestConfig { num_trees: 4, threads: 1, ..Default::default() })
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("lmtuner-forest-{name}-{}", std::process::id()))
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let f = toy_forest();
        let path = tmp("rt");
        save(&f, &path).unwrap();
        let g = load(&path).unwrap();
        assert_eq!(f.trees.len(), g.trees.len());
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let p = [
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
                rng.range_f64(-1.0, 1.0),
            ];
            assert!((f.predict(&p) - g.predict(&p)).abs() < 1e-12);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, "not a forest\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "lmtuner-forest v1 trees=2\ntree 0 nodes=1\nL 0.5\n")
            .unwrap();
        assert!(load(&path).is_err(), "tree count mismatch accepted");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_validates_structure() {
        let path = tmp("cycle");
        // split pointing at itself -> invalid
        std::fs::write(
            &path,
            "lmtuner-forest v1 trees=1\ntree 0 nodes=1\nS 0 0.0 0 0 0.0\n",
        )
        .unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
