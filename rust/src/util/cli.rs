//! CLI argument-parsing substrate (clap is not available).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Typed getters with defaults; `finish()` rejects unknown flags so typos
//! fail loudly instead of silently using defaults.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: BTreeSet<String>,
    positional: Vec<String>,
    consumed: BTreeSet<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Result<Args, String> {
        let mut a = Args::default();
        let mut it = it.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminates option parsing
                    a.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else {
                    // value-taking iff the next token is not another option
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            a.opts.insert(body.to_string(), v);
                        }
                        _ => {
                            a.flags.insert(body.to_string());
                        }
                    }
                }
            } else {
                a.positional.push(tok);
            }
        }
        Ok(a)
    }

    pub fn parse_env() -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// First positional argument — typically the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.insert(name.to_string());
        self.flags.contains(name)
    }

    pub fn opt_str(&mut self, name: &str) -> Option<String> {
        self.consumed.insert(name.to_string());
        self.opts.get(name).cloned()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or_else(|| default.to_string())
    }

    pub fn get<T: std::str::FromStr>(&mut self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt_str(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{name}={s}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&mut self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(name)?.unwrap_or(default))
    }

    /// Error on any provided option/flag never consumed by the command.
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<&String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !self.consumed.contains(k.as_str()))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {}",
                unknown
                    .iter()
                    .map(|k| format!("--{k}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse_from(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_styles() {
        let mut a = args(&["train", "--trees", "20", "--mtry=4", "--verbose"]);
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_or("trees", 0usize).unwrap(), 20);
        assert_eq!(a.get_or("mtry", 0usize).unwrap(), 4);
        assert!(a.flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = args(&["x"]);
        assert_eq!(a.get_or("scale", 0.5f64).unwrap(), 0.5);
        assert_eq!(a.str_or("out", "data/x.csv"), "data/x.csv");
        assert!(!a.flag("full"));
    }

    #[test]
    fn bad_value_is_error() {
        let mut a = args(&["--trees", "twenty"]);
        assert!(a.get::<usize>("trees").is_err());
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = args(&["--trees", "20", "--oops", "1"]);
        let _ = a.get::<usize>("trees").unwrap();
        assert!(a.finish().is_err());
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = args(&["run", "--", "--not-a-flag"]);
        assert_eq!(a.positional(), &["run", "--not-a-flag"]);
    }

    #[test]
    fn negative_numbers_as_values() {
        let mut a = args(&["--lo=-5.5"]);
        assert_eq!(a.get_or("lo", 0.0f64).unwrap(), -5.5);
    }
}
