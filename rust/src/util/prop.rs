//! Property-testing substrate (proptest is not available).
//!
//! `check` runs a predicate over many seeded random cases and reports the
//! first failing seed; `forall_shrink` additionally shrinks a failing u64
//! parameter toward zero. Tests across the crate use this for invariant
//! checks (routing, batching, simulator monotonicity, tree validity).

use super::prng::Rng;

pub const DEFAULT_CASES: usize = 256;

/// Run `f` on `cases` independently-seeded RNGs; panic with the seed on
/// the first failure so the case can be replayed deterministically.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, cases: usize, f: F) {
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Check a predicate over a u64 drawn from [0, bound); on failure, shrink
/// the input toward 0 by halving and report the smallest failing value.
pub fn forall_shrink<F: Fn(u64) -> Result<(), String>>(
    name: &str,
    bound: u64,
    cases: usize,
    f: F,
) {
    let mut rng = Rng::new(0xF0CA_CC1A);
    for _ in 0..cases {
        let x0 = rng.below(bound.max(1));
        if let Err(first) = f(x0) {
            // shrink
            let mut lo_fail = x0;
            let mut msg = first;
            let mut cur = x0;
            while cur > 0 {
                let cand = cur / 2;
                match f(cand) {
                    Err(m) => {
                        lo_fail = cand;
                        msg = m;
                        cur = cand;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed at {x0}, shrunk to {lo_fail}: {msg}"
            );
        }
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 64, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            prop_assert!(a + b == b + a, "{a} {b}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check("always-false", 4, |_| Err("nope".into()));
    }

    #[test]
    #[should_panic(expected = "shrunk to 0")]
    fn shrink_reaches_minimum() {
        forall_shrink("never", 1 << 20, 8, |_| Err("always fails".into()));
    }

    #[test]
    fn shrink_passes_when_ok() {
        forall_shrink("le-bound", 100, 32, |x| {
            if x < 100 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }
}
