//! Deterministic PRNG substrate (no external crates are available).
//!
//! `SplitMix64` seeds `Xoshiro256StarStar` (Blackman & Vigna), the same
//! construction the `rand` ecosystem uses. Everything downstream
//! (synthetic-kernel sampling, bootstrap bagging, feature subsampling,
//! property tests) draws from this, so runs are reproducible from a seed.

/// SplitMix64 — used for seeding and as a cheap standalone generator.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-tree / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) — Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform integer in [lo, hi] inclusive (usize convenience).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample `k` distinct indices from 0..n without materializing the
    /// index range: the same partial Fisher–Yates as [`Self::sample_indices`]
    /// (identical draws, identical output for the same generator state)
    /// but tracking only the displaced entries in a map, so time and
    /// memory are O(k) instead of O(n). This is what keeps
    /// `LaunchSweep::sampled_balanced` from touching every launch in the
    /// sweep on every call.
    pub fn sample_indices_sparse(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut displaced: std::collections::HashMap<usize, usize> =
            std::collections::HashMap::with_capacity(2 * k);
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            let vj = displaced.get(&j).copied().unwrap_or(j);
            let vi = displaced.get(&i).copied().unwrap_or(i);
            // swap(i, j) in the virtual identity array
            displaced.insert(j, vi);
            out.push(vj);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(11);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                x => panic!("out of range {x}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }

    #[test]
    fn sparse_sampling_matches_dense_exactly() {
        // Same algorithm, same draws: for equal generator states the two
        // implementations must return identical index sequences.
        for (n, k) in [(1usize, 1usize), (50, 20), (50, 50), (1000, 3), (7, 0)] {
            let mut a = Rng::new(777);
            let mut b = Rng::new(777);
            assert_eq!(
                a.sample_indices(n, k),
                b.sample_indices_sparse(n, k),
                "n={n} k={k}"
            );
            // and the generators end in the same state
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn sparse_sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(21);
        let s = r.sample_indices_sparse(64, 48);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 48);
        assert!(d.iter().all(|&i| i < 64));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
