//! Statistics substrate: histograms (Fig. 1 rendering), summary stats,
//! percentiles. No external crates.

/// A fixed-bin histogram over log2(speedup), matching the paper's Fig. 1
/// x-axis style (speedups spanning 0.03x .. 49.6x are only legible in log
/// space).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Self { lo, hi, bins: vec![0; nbins], underflow: 0, overflow: 0, count: 0 }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let w = (self.hi - self.lo) / self.bins.len() as f64;
            let i = ((x - self.lo) / w) as usize;
            let i = i.min(self.bins.len() - 1);
            self.bins[i] += 1;
        }
    }

    pub fn bin_edges(&self, i: usize) -> (f64, f64) {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        (self.lo + i as f64 * w, self.lo + (i + 1) as f64 * w)
    }

    /// Fraction of samples in [lo, hi) bins (excludes under/overflow).
    pub fn fraction_in_range(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let inside: u64 = self.bins.iter().sum();
        inside as f64 / self.count as f64
    }
}

/// Running summary statistics (Welford) — used all over the benches.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Exact percentile over a sample (sorts a copy; linear interpolation).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.count, 10);
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert!((h.fraction_in_range() - 10.0 / 12.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_edges() {
        let h = Histogram::new(-5.0, 5.0, 10);
        let (a, b) = h.bin_edges(0);
        assert!((a + 5.0).abs() < 1e-12 && (b + 4.0).abs() < 1e-12);
    }

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert!((s.mean() - 4.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 10.0);
        let var = xs.iter().map(|x| (x - 4.0) * (x - 4.0)).sum::<f64>() / 4.0;
        assert!((s.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!((percentile(&xs, 62.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_reciprocals_is_one() {
        let xs = [2.0, 0.5, 4.0, 0.25];
        assert!((geomean(&xs) - 1.0).abs() < 1e-12);
    }
}
