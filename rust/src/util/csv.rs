//! Tiny CSV substrate for dataset persistence (header + f64 columns).
//!
//! Two layers:
//!
//! * `RowWriter` / `RowReader` — incremental, row-at-a-time streaming.
//!   The sharded dataset sinks write millions of rows through these
//!   without ever materializing a table, and the streaming evaluation
//!   pass reads them back the same way (peak memory: one row).
//! * `write_table` / `read_table` — whole-table convenience wrappers
//!   over the streaming layer, used for small reports and models.
//!
//! Files may carry metadata as `# key=value` comment lines *before* the
//! header (`RowWriter::create_with_meta` writes them, `RowReader::meta`
//! exposes them). The dataset layer uses this to stamp which simulated
//! device a dataset was measured on; files without metadata lines parse
//! exactly as before.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Lines, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Render one `# key=value` metadata line (no trailing newline).
/// Keys must be non-empty and free of '=' and newlines; values must be
/// free of newlines. This is the single canonical encoder — every layer
/// that stamps metadata onto a text file goes through it.
pub fn format_meta_line(key: &str, value: &str) -> Result<String> {
    if key.is_empty() || key.contains('=') || key.contains('\n') || value.contains('\n') {
        bail!("invalid metadata entry '{key}={value}'");
    }
    Ok(format!("# {key}={value}"))
}

/// Parse one leading file line as metadata. Returns `None` when the
/// line is not a comment (i.e. the header has started), `Some(Ok)` for
/// a well-formed `# key=value` line, and `Some(Err)` for a comment that
/// does not parse as metadata. Shared by [`RowReader`] and the shard
/// inspector so both agree on what counts as metadata.
pub fn parse_meta_line(line: &str) -> Option<Result<(String, String)>> {
    let body = line.strip_prefix('#')?;
    Some(match body.trim().split_once('=') {
        Some((k, v)) if !k.trim().is_empty() => {
            Ok((k.trim().to_string(), v.trim().to_string()))
        }
        _ => Err(anyhow::anyhow!(
            "malformed metadata line '{line}' (expected '# key=value')"
        )),
    })
}

/// Append one f64 to `line` using the compact dataset format (integers
/// without a trailing `.0`, everything else via the shortest roundtrip
/// float formatting).
fn push_number(line: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        line.push_str(&format!("{}", x as i64));
    } else {
        line.push_str(&format!("{x}"));
    }
}

/// Incremental writer: header on creation, then one numeric row at a
/// time. Rows are width-checked against the header.
pub struct RowWriter {
    w: BufWriter<std::fs::File>,
    width: usize,
    path: PathBuf,
    rows: u64,
    line: String,
}

impl RowWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        Self::create_with_meta(path, header, &[])
    }

    /// Create with `# key=value` metadata lines ahead of the header.
    /// Keys and values must not contain newlines; keys must not be
    /// empty or contain '='.
    pub fn create_with_meta(
        path: &Path,
        header: &[&str],
        meta: &[(&str, &str)],
    ) -> Result<Self> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        for (k, v) in meta {
            let line = format_meta_line(k, v)
                .with_context(|| format!("{}", path.display()))?;
            writeln!(w, "{line}")?;
        }
        writeln!(w, "{}", header.join(","))?;
        Ok(RowWriter {
            w,
            width: header.len(),
            path: path.to_path_buf(),
            rows: 0,
            line: String::with_capacity(header.len() * 12),
        })
    }

    pub fn write_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.width {
            bail!(
                "{}: row width {} != header width {}",
                self.path.display(),
                row.len(),
                self.width
            );
        }
        self.line.clear();
        for (i, x) in row.iter().enumerate() {
            if i > 0 {
                self.line.push(',');
            }
            push_number(&mut self.line, *x);
        }
        writeln!(self.w, "{}", self.line)?;
        self.rows += 1;
        Ok(())
    }

    /// Rows written so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Flush buffered output to disk.
    pub fn finish(&mut self) -> Result<()> {
        self.w
            .flush()
            .with_context(|| format!("flush {}", self.path.display()))
    }
}

/// Incremental reader: parses the header on open, then yields one
/// numeric row per `next_row` call (None at EOF). Blank lines are
/// skipped; ragged rows and non-numeric cells are errors.
pub struct RowReader {
    lines: Lines<BufReader<std::fs::File>>,
    header: Vec<String>,
    meta: BTreeMap<String, String>,
    path: PathBuf,
    lineno: usize,
}

impl RowReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("open {}", path.display()))?;
        let mut lines = BufReader::new(f).lines();
        // Leading `# key=value` lines are file metadata; the first
        // non-comment line is the header.
        let mut meta = BTreeMap::new();
        let mut lineno = 0usize;
        let header_line = loop {
            let line = match lines.next() {
                Some(l) => l?,
                None => bail!("{}: empty file", path.display()),
            };
            lineno += 1;
            match parse_meta_line(&line) {
                Some(parsed) => {
                    let (k, v) = parsed.with_context(|| {
                        format!("{}:{}", path.display(), lineno)
                    })?;
                    meta.insert(k, v);
                }
                None => break line,
            }
        };
        let header: Vec<String> =
            header_line.split(',').map(|s| s.trim().to_string()).collect();
        Ok(RowReader {
            lines,
            header,
            meta,
            path: path.to_path_buf(),
            lineno,
        })
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    /// Metadata parsed from the leading `# key=value` lines (empty for
    /// files without them).
    pub fn meta(&self) -> &BTreeMap<String, String> {
        &self.meta
    }

    pub fn next_row(&mut self) -> Result<Option<Vec<f64>>> {
        loop {
            let line = match self.lines.next() {
                Some(l) => l?,
                None => return Ok(None),
            };
            self.lineno += 1;
            if line.trim().is_empty() {
                continue;
            }
            let row: Result<Vec<f64>, _> =
                line.split(',').map(|s| s.trim().parse::<f64>()).collect();
            let row = row.with_context(|| {
                format!("{}:{}: bad number", self.path.display(), self.lineno)
            })?;
            if row.len() != self.header.len() {
                bail!(
                    "{}:{}: width {} != header {}",
                    self.path.display(),
                    self.lineno,
                    row.len(),
                    self.header.len()
                );
            }
            return Ok(Some(row));
        }
    }
}

/// Write a numeric table with a header row.
pub fn write_table(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    let mut w = RowWriter::create(path, header)?;
    for row in rows {
        w.write_row(row)?;
    }
    w.finish()
}

/// Read a numeric table; returns (header, rows).
pub fn read_table(path: &Path) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let mut r = RowReader::open(path)?;
    let header = r.header().to_vec();
    let mut rows = Vec::new();
    while let Some(row) = r.next_row()? {
        rows.push(row);
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lmtuner-csv-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt");
        let header = ["a", "b", "c"];
        let rows = vec![vec![1.0, -2.5, 3.0], vec![4.0, 5.0, 6.25]];
        write_table(&path, &header, &rows).unwrap();
        let (h, r) = read_table(&path).unwrap();
        assert_eq!(h, header);
        assert_eq!(r, rows);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let path = tmp("ragged");
        std::fs::write(&path, "a,b\n1,2\n3\n").unwrap();
        assert!(read_table(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_numbers() {
        let path = tmp("nan");
        std::fs::write(&path, "a\nxyz\n").unwrap();
        assert!(read_table(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_rejects_width_mismatch() {
        let path = tmp("width");
        assert!(write_table(&path, &["a", "b"], &[vec![1.0]]).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn row_reader_streams_and_counts_rows() {
        let path = tmp("stream");
        let header = ["x", "y"];
        let mut w = RowWriter::create(&path, &header).unwrap();
        for i in 0..100 {
            w.write_row(&[i as f64, (i * i) as f64]).unwrap();
        }
        assert_eq!(w.rows(), 100);
        w.finish().unwrap();

        let mut r = RowReader::open(&path).unwrap();
        assert_eq!(r.header(), &["x".to_string(), "y".to_string()]);
        let mut n = 0u64;
        while let Some(row) = r.next_row().unwrap() {
            assert_eq!(row[1], row[0] * row[0]);
            n += 1;
        }
        assert_eq!(n, 100);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn row_writer_rejects_wrong_width_row() {
        let path = tmp("rw-width");
        let mut w = RowWriter::create(&path, &["a", "b"]).unwrap();
        assert!(w.write_row(&[1.0]).is_err());
        assert!(w.write_row(&[1.0, 2.0]).is_ok());
        w.finish().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metadata_roundtrips_and_plain_files_have_none() {
        let path = tmp("meta");
        let mut w = RowWriter::create_with_meta(
            &path,
            &["a", "b"],
            &[("device", "m2090"), ("schema", "features18+speedup")],
        )
        .unwrap();
        w.write_row(&[1.0, 2.0]).unwrap();
        w.finish().unwrap();
        let mut r = RowReader::open(&path).unwrap();
        assert_eq!(r.meta().get("device").map(String::as_str), Some("m2090"));
        assert_eq!(
            r.meta().get("schema").map(String::as_str),
            Some("features18+speedup")
        );
        assert_eq!(r.header(), &["a".to_string(), "b".to_string()]);
        assert_eq!(r.next_row().unwrap(), Some(vec![1.0, 2.0]));
        assert_eq!(r.next_row().unwrap(), None);
        std::fs::remove_file(&path).ok();

        // files without metadata lines parse exactly as before
        let plain = tmp("plainmeta");
        std::fs::write(&plain, "a,b\n1,2\n").unwrap();
        let r = RowReader::open(&plain).unwrap();
        assert!(r.meta().is_empty());
        std::fs::remove_file(&plain).ok();
    }

    #[test]
    fn malformed_metadata_is_rejected() {
        let path = tmp("badmeta");
        std::fs::write(&path, "# deviceonly\na,b\n1,2\n").unwrap();
        assert!(RowReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();

        let path2 = tmp("badmeta2");
        assert!(RowWriter::create_with_meta(&path2, &["a"], &[("", "x")]).is_err());
        assert!(
            RowWriter::create_with_meta(&path2, &["a"], &[("k=v", "x")]).is_err()
        );
        std::fs::remove_file(&path2).ok();
    }

    #[test]
    fn meta_line_helpers_are_the_shared_codec() {
        let line = format_meta_line("device", "m2090").unwrap();
        assert_eq!(line, "# device=m2090");
        let (k, v) = parse_meta_line(&line).unwrap().unwrap();
        assert_eq!((k.as_str(), v.as_str()), ("device", "m2090"));

        assert!(format_meta_line("", "x").is_err());
        assert!(format_meta_line("k=v", "x").is_err());
        assert!(format_meta_line("k", "a\nb").is_err());

        assert!(parse_meta_line("a,b,c").is_none());
        assert!(parse_meta_line("# deviceonly").unwrap().is_err());
    }

    #[test]
    fn row_reader_skips_blank_lines() {
        let path = tmp("blank");
        std::fs::write(&path, "a,b\n1,2\n\n3,4\n").unwrap();
        let mut r = RowReader::open(&path).unwrap();
        assert_eq!(r.next_row().unwrap(), Some(vec![1.0, 2.0]));
        assert_eq!(r.next_row().unwrap(), Some(vec![3.0, 4.0]));
        assert_eq!(r.next_row().unwrap(), None);
        std::fs::remove_file(&path).ok();
    }
}
