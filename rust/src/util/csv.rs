//! Tiny CSV substrate for dataset persistence (header + f64 columns).
//!
//! The instance datasets (features + measured speedup) are written once by
//! `lmtuner generate` and re-read by `train`/`eval`; files can reach a few
//! hundred MB at full scale, so reading is buffered and allocation-light.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Write a numeric table with a header row.
pub fn write_table(path: &Path, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{}", header.join(","))?;
    let mut line = String::with_capacity(header.len() * 12);
    for row in rows {
        if row.len() != header.len() {
            bail!("row width {} != header width {}", row.len(), header.len());
        }
        line.clear();
        for (i, x) in row.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if x.fract() == 0.0 && x.abs() < 1e15 {
                line.push_str(&format!("{}", *x as i64));
            } else {
                line.push_str(&format!("{x}"));
            }
        }
        writeln!(w, "{line}")?;
    }
    w.flush()?;
    Ok(())
}

/// Read a numeric table; returns (header, rows).
pub fn read_table(path: &Path) -> Result<(Vec<String>, Vec<Vec<f64>>)> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?;
    let mut lines = std::io::BufReader::new(f).lines();
    let header_line = match lines.next() {
        Some(l) => l?,
        None => bail!("{}: empty file", path.display()),
    };
    let header: Vec<String> =
        header_line.split(',').map(|s| s.trim().to_string()).collect();
    let mut rows = Vec::new();
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let row: Result<Vec<f64>, _> =
            line.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let row = row.with_context(|| {
            format!("{}:{}: bad number", path.display(), lineno + 2)
        })?;
        if row.len() != header.len() {
            bail!(
                "{}:{}: width {} != header {}",
                path.display(),
                lineno + 2,
                row.len(),
                header.len()
            );
        }
        rows.push(row);
    }
    Ok((header, rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lmtuner-csv-{name}-{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip() {
        let path = tmp("rt");
        let header = ["a", "b", "c"];
        let rows = vec![vec![1.0, -2.5, 3.0], vec![4.0, 5.0, 6.25]];
        write_table(&path, &header, &rows).unwrap();
        let (h, r) = read_table(&path).unwrap();
        assert_eq!(h, header);
        assert_eq!(r, rows);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_ragged_rows() {
        let path = tmp("ragged");
        std::fs::write(&path, "a,b\n1,2\n3\n").unwrap();
        assert!(read_table(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_numbers() {
        let path = tmp("nan");
        std::fs::write(&path, "a\nxyz\n").unwrap();
        assert!(read_table(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn write_rejects_width_mismatch() {
        let path = tmp("width");
        assert!(write_table(&path, &["a", "b"], &[vec![1.0]]).is_err());
        std::fs::remove_file(&path).ok();
    }
}
