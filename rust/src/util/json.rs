//! Minimal JSON substrate (serde is not available in this environment).
//!
//! Writer: builds values programmatically and serializes with correct
//! escaping. Parser: a small recursive-descent reader — enough to load
//! `artifacts/manifest.json` and our own result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_strs<S: AsRef<str>>(xs: &[S]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.as_ref().to_string())).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn dump_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (handles UTF-8 transparently)
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("lm\"tuner\n".into()))
            .set("n", Json::Num(42.0))
            .set("xs", Json::from_f64s(&[1.5, -2.0]))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_manifest_like() {
        let text = r#"{"num_trees": 20, "artifacts": ["a.hlo.txt", "b.hlo.txt"],
                       "stencil": {"img": 256, "patterns": {"rect": 9}}}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("num_trees").unwrap().as_usize(), Some(20));
        assert_eq!(j.get("artifacts").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            j.get("stencil").unwrap().get("patterns").unwrap()
                .get("rect").unwrap().as_usize(),
            Some(9)
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn pretty_output_parses_back() {
        let mut j = Json::obj();
        j.set("a", Json::Arr(vec![Json::Num(1.0), Json::obj()]));
        let back = Json::parse(&j.dump_pretty()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn numbers_render_integers_cleanly() {
        assert_eq!(Json::Num(20.0).dump(), "20");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse("\"a\\u0041b\"").unwrap();
        assert_eq!(j.as_str(), Some("aAb"));
    }
}
