//! Micro-benchmark harness substrate (criterion is not available).
//!
//! `cargo bench` targets use `harness = false` and call into this module:
//! warmup, then timed iterations until both a minimum iteration count and a
//! minimum wall time are reached; reports mean/p50/p95 per iteration.

use std::time::{Duration, Instant};

use super::stats::percentile;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub total: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub min_time: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(100),
            max_iters: 50,
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let started = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            let done_iters = samples.len() >= self.min_iters;
            let done_time = started.elapsed() >= self.min_time;
            if (done_iters && done_time) || samples.len() >= self.max_iters {
                break;
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(percentile(&samples, 95.0)),
            min: Duration::from_secs_f64(
                samples.iter().cloned().fold(f64::INFINITY, f64::min),
            ),
            total: started.elapsed(),
        }
    }
}

/// Standard one-line report used by all bench binaries.
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>6} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
        r.name, r.iters, r.mean, r.p50, r.p95
    );
}

pub fn report_throughput(r: &BenchResult, items: f64, unit: &str) {
    println!(
        "{:<44} {:>6} iters  mean {:>12?}  {:>12.0} {unit}/s",
        r.name,
        r.iters,
        r.mean,
        r.throughput(items)
    );
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iters() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 5,
            min_time: Duration::from_millis(0),
            max_iters: 100,
        };
        let mut count = 0usize;
        let r = b.run("noop", || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters);
    }

    #[test]
    fn bench_respects_max_iters() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 1,
            min_time: Duration::from_secs(30),
            max_iters: 7,
        };
        let r = b.run("noop", || {});
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn throughput_sane() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 3,
            min_time: Duration::from_millis(0),
            max_iters: 10,
        };
        let r = b.run("sleepless", || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.throughput(1000.0) > 0.0);
        assert!(r.p95 >= r.p50);
        assert!(r.mean >= r.min);
    }
}
