//! Micro-benchmark harness substrate (criterion is not available).
//!
//! `cargo bench` targets use `harness = false` and call into this module:
//! warmup, then timed iterations until both a minimum iteration count and a
//! minimum wall time are reached; reports mean/p50/p95 per iteration.
//!
//! Perf benches additionally collect their results into a [`JsonReport`]
//! and drop a machine-readable `BENCH_<name>.json` in the working
//! directory, so CI and EXPERIMENTS.md tooling can diff runs without
//! scraping stdout.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::percentile;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub total: Duration,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub min_time: Duration,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// Quick profile for expensive end-to-end benches.
    pub fn coarse() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(100),
            max_iters: 50,
        }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let started = Instant::now();
        loop {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            let done_iters = samples.len() >= self.min_iters;
            let done_time = started.elapsed() >= self.min_time;
            if (done_iters && done_time) || samples.len() >= self.max_iters {
                break;
            }
        }
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(mean),
            p50: Duration::from_secs_f64(percentile(&samples, 50.0)),
            p95: Duration::from_secs_f64(percentile(&samples, 95.0)),
            min: Duration::from_secs_f64(
                samples.iter().cloned().fold(f64::INFINITY, f64::min),
            ),
            total: started.elapsed(),
        }
    }
}

/// Standard one-line report used by all bench binaries.
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>6} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
        r.name, r.iters, r.mean, r.p50, r.p95
    );
}

pub fn report_throughput(r: &BenchResult, items: f64, unit: &str) {
    println!(
        "{:<44} {:>6} iters  mean {:>12?}  {:>12.0} {unit}/s",
        r.name,
        r.iters,
        r.mean,
        r.throughput(items)
    );
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Accumulates [`BenchResult`]s for one bench binary and serializes them
/// as `BENCH_<name>.json` (via [`crate::util::json`]). The `record_*`
/// variants also print the usual one-line report, so a bench swaps
/// `report(&r)` for `rep.record(&r)` and loses nothing on stdout.
pub struct JsonReport {
    bench: String,
    entries: Vec<Json>,
    sections: Vec<(String, Json)>,
}

impl JsonReport {
    pub fn new(bench: &str) -> Self {
        JsonReport { bench: bench.to_string(), entries: Vec::new(), sections: Vec::new() }
    }

    fn entry(r: &BenchResult, throughput: Option<(f64, &str)>) -> Json {
        let mut e = Json::obj();
        e.set("name", Json::Str(r.name.clone()))
            .set("iters", Json::Num(r.iters as f64))
            .set("mean_s", Json::Num(r.mean.as_secs_f64()))
            .set("p50_s", Json::Num(r.p50.as_secs_f64()))
            .set("p95_s", Json::Num(r.p95.as_secs_f64()))
            .set("min_s", Json::Num(r.min.as_secs_f64()));
        if let Some((items, unit)) = throughput {
            e.set("items_per_iter", Json::Num(items))
                .set("throughput_per_s", Json::Num(r.throughput(items)))
                .set("unit", Json::Str(unit.to_string()));
        }
        e
    }

    pub fn record(&mut self, r: &BenchResult) {
        report(r);
        self.entries.push(Self::entry(r, None));
    }

    pub fn record_throughput(&mut self, r: &BenchResult, items: f64, unit: &str) {
        report_throughput(r, items, unit);
        self.entries.push(Self::entry(r, Some((items, unit))));
    }

    /// Attach a free-form scalar (a derived ratio, a config knob) to the
    /// report alongside the timed entries.
    pub fn note(&mut self, key: &str, value: f64) {
        let mut e = Json::obj();
        e.set("name", Json::Str(key.to_string())).set("value", Json::Num(value));
        self.entries.push(e);
    }

    /// Attach a whole JSON document under a top-level key — e.g. a
    /// `crate::obs::metrics::MetricsRegistry::to_json()` dump under
    /// `"metrics"`, so live telemetry and bench snapshots share one
    /// file format. Re-setting a key replaces it.
    pub fn set_section(&mut self, key: &str, value: Json) {
        if let Some(slot) = self.sections.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.sections.push((key.to_string(), value));
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("bench", Json::Str(self.bench.clone()))
            .set("results", Json::Arr(self.entries.clone()));
        for (k, v) in &self.sections {
            j.set(k, v.clone());
        }
        j
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the file path.
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json().dump_pretty())?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into the current working directory.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(std::path::Path::new("."))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_minimum_iters() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 5,
            min_time: Duration::from_millis(0),
            max_iters: 100,
        };
        let mut count = 0usize;
        let r = b.run("noop", || count += 1);
        assert!(r.iters >= 5);
        assert_eq!(count, r.iters);
    }

    #[test]
    fn bench_respects_max_iters() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 1,
            min_time: Duration::from_secs(30),
            max_iters: 7,
        };
        let r = b.run("noop", || {});
        assert_eq!(r.iters, 7);
    }

    #[test]
    fn json_report_roundtrips_and_writes() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 2,
            min_time: Duration::from_millis(0),
            max_iters: 4,
        };
        let mut rep = JsonReport::new("unit");
        let r = b.run("noop", || {});
        rep.record(&r);
        rep.record_throughput(&r, 100.0, "rows");
        rep.note("speedup", 2.5);
        let j = rep.to_json();
        assert_eq!(j.get("bench").unwrap().as_str(), Some("unit"));
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("noop"));
        assert!(results[1].get("throughput_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(results[1].get("unit").unwrap().as_str(), Some("rows"));
        assert_eq!(results[2].get("value").unwrap().as_f64(), Some(2.5));

        let dir = std::env::temp_dir()
            .join(format!("lmtuner-bench-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = rep.write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit.json"));
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(back, rep.to_json());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn throughput_sane() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 3,
            min_time: Duration::from_millis(0),
            max_iters: 10,
        };
        let r = b.run("sleepless", || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.throughput(1000.0) > 0.0);
        assert!(r.p95 >= r.p50);
        assert!(r.mean >= r.min);
    }
}
