//! Substrate utilities built in-tree (no external crates beyond `xla` +
//! `anyhow` exist in this environment): PRNG, statistics, JSON, thread
//! pool, CLI parsing, bench harness, property testing, CSV I/O.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;
