//! Thread-pool substrate (rayon/tokio are not available).
//!
//! A fixed-size worker pool with a shared injector queue, plus a
//! `parallel_map` helper used by the sweep simulator and forest trainer.
//! On the 1-core CI box this degrades gracefully to near-sequential
//! execution; the abstractions still structure the coordinator.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: Mutex<bool>,
    in_flight: AtomicUsize,
    idle: Condvar,
}

/// Fixed-size thread pool with `spawn` + `wait_idle` semantics.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("lmtuner-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (but at least 1).
    pub fn for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(f));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Block until every spawned job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.queue.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break j;
                }
                if *sh.shutdown.lock().unwrap() {
                    return;
                }
                q = sh.available.wait(q).unwrap();
            }
        };
        job();
        if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            sh.idle.notify_all();
        }
    }
}

/// Chunked parallel map: applies `f` to every element of `items`, preserving
/// order. Spawns scoped threads so `f` may borrow from the environment.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> =
        (0..items.len()).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker produced result"))
        .collect()
}

/// Streamed chunked parallel map: `items` are processed in chunks of
/// `chunk` elements; each chunk is mapped in parallel across `threads`
/// workers, then `consume` receives that chunk's results *in input
/// order* on the calling thread. Peak memory is ~two chunks of
/// results, independent of `items.len()` — this is the fan-out
/// primitive behind the streaming dataset builder, where the full
/// result set would not fit in memory at paper scale.
///
/// Runs with a one-chunk lookahead: while `consume` handles chunk N on
/// the calling thread (e.g. serializing records to disk shards), a
/// background worker computes chunk N+1, so sink I/O and simulation
/// overlap instead of summing.
///
/// An `Err` from `consume` aborts the stream; beyond the in-flight
/// lookahead chunk, no further chunks are computed.
pub fn parallel_map_streamed<T, R, E, F, C>(
    items: &[T],
    threads: usize,
    chunk: usize,
    f: F,
    mut consume: C,
) -> Result<(), E>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
    C: FnMut(usize, Vec<R>) -> Result<(), E>,
{
    let chunk = chunk.max(1);
    let mut chunks = items.chunks(chunk);
    let first = match chunks.next() {
        Some(c) => c,
        None => return Ok(()),
    };
    let f = &f;
    std::thread::scope(|scope| {
        let mut current = parallel_map(first, threads, f);
        let mut base = 0usize;
        loop {
            let next = chunks
                .next()
                .map(|c| scope.spawn(move || parallel_map(c, threads, f)));
            let len = current.len();
            consume(base, std::mem::take(&mut current))?;
            base += len;
            match next {
                Some(h) => current = h.join().expect("lookahead chunk panicked"),
                None => return Ok(()),
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |&x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |&x| x).is_empty());
    }

    #[test]
    fn streamed_map_equals_plain_map() {
        let items: Vec<u64> = (0..257).collect();
        let mut streamed: Vec<u64> = Vec::new();
        parallel_map_streamed::<_, _, (), _, _>(&items, 4, 10, |&x| x * 3, |base, rs| {
            assert_eq!(base % 10, 0);
            streamed.extend(rs);
            Ok(())
        })
        .unwrap();
        assert_eq!(streamed, parallel_map(&items, 4, |&x| x * 3));
    }

    #[test]
    fn streamed_map_bounds_chunk_size() {
        let items: Vec<u64> = (0..100).collect();
        let mut max_chunk = 0usize;
        parallel_map_streamed::<_, _, (), _, _>(&items, 2, 7, |&x| x, |_, rs| {
            max_chunk = max_chunk.max(rs.len());
            Ok(())
        })
        .unwrap();
        assert_eq!(max_chunk, 7);
    }

    #[test]
    fn streamed_map_consume_error_aborts() {
        let items: Vec<u64> = (0..100).collect();
        let computed = AtomicU64::new(0);
        let mut chunks = 0usize;
        let r = parallel_map_streamed(
            &items,
            2,
            10,
            |&x| {
                computed.fetch_add(1, Ordering::SeqCst);
                x
            },
            |_, _| {
                chunks += 1;
                if chunks == 2 { Err("stop") } else { Ok(()) }
            },
        );
        assert_eq!(r, Err("stop"));
        assert_eq!(chunks, 2);
        // the two consumed chunks plus the one in-flight lookahead
        // chunk were computed; the remaining seven never started
        assert_eq!(computed.load(Ordering::SeqCst), 30);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        pool.spawn(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        drop(pool); // must not hang or panic
    }
}
