//! Request/response/control types of the prediction service.

use std::time::Instant;

use crate::kernelmodel::features::NUM_FEATURES;

/// One auto-tuning query: "should this kernel instance use local memory?"
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub id: u64,
    pub features: [f64; NUM_FEATURES],
}

#[derive(Clone, Debug)]
pub struct PredictResponse {
    pub id: u64,
    /// Predicted log2(speedup).
    pub score: f64,
    /// The tuning decision: apply the optimization?
    pub use_local_memory: bool,
    /// Joint (schema v2) models only: predicted (log2 wg_w, log2 wg_h)
    /// workgroup shape, from the same traversal as `score`. `None` when
    /// the backend serves a single-output model.
    pub wg_logs: Option<(f64, f64)>,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue + inference latency.
    pub latency: std::time::Duration,
}

/// Typed failure delivered to a client whose batch failed, instead of
/// silently dropping its reply channel.
#[derive(Clone, Debug)]
pub struct PredictError {
    pub id: u64,
    pub reason: String,
}

impl std::fmt::Display for PredictError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {}: batch inference failed: {}", self.id, self.reason)
    }
}

impl std::error::Error for PredictError {}

/// What a client receives on its reply channel.
pub type PredictReply = Result<PredictResponse, PredictError>;

/// Internal queue entry.
pub(crate) struct Pending {
    pub req: PredictRequest,
    pub enqueued: Instant,
    pub reply: std::sync::mpsc::Sender<PredictReply>,
}

/// Control protocol between the service façade and its shard workers.
/// Shutdown is an explicit message, not a channel-disconnect side effect,
/// so live client handles can never keep a worker alive.
pub(crate) enum WorkerMsg {
    Job(Pending),
    /// Serve everything already queued, then exit.
    Shutdown,
}
