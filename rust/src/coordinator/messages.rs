//! Request/response types of the prediction service.

use std::time::Instant;

use crate::kernelmodel::features::NUM_FEATURES;

/// One auto-tuning query: "should this kernel instance use local memory?"
#[derive(Clone, Debug)]
pub struct PredictRequest {
    pub id: u64,
    pub features: [f64; NUM_FEATURES],
}

#[derive(Clone, Debug)]
pub struct PredictResponse {
    pub id: u64,
    /// Predicted log2(speedup).
    pub score: f64,
    /// The tuning decision: apply the optimization?
    pub use_local_memory: bool,
    /// Size of the batch this request was served in.
    pub batch_size: usize,
    /// Queue + inference latency.
    pub latency: std::time::Duration,
}

/// Internal queue entry.
pub(crate) struct Pending {
    pub req: PredictRequest,
    pub enqueued: Instant,
    pub reply: std::sync::mpsc::Sender<PredictResponse>,
}
