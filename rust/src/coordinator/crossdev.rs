//! Cross-device generalization: train on device A, test on device B.
//!
//! The paper trains and evaluates on a single Tesla M2090; whether the
//! learned decision survives a change of hardware is exactly the
//! performance-portability question the OpenCL autotuning literature
//! (Falch & Elster; Cummins et al.) asks of such models. This module
//! produces the train-on-A/test-on-B accuracy matrix over the device
//! portfolio (`gpu::registry`):
//!
//! * every device gets its own dataset — same seed, same synthetic
//!   template population, measured on *that* device's simulated testbed —
//!   split into train/test the same way;
//! * one forest is fitted per device and registered in a
//!   `runtime::executor::ForestRegistry` (the same per-device model
//!   registry the serving layer routes by);
//! * every (model, testbed) pair is graded with the paper's two accuracy
//!   metrics, batched through the registry's native executors.
//!
//! The diagonal is the paper's single-device setting; the off-diagonal
//! cells measure how much accuracy a model loses on hardware it never
//! saw. `lmtuner crossdev` writes the count-based matrix to CSV for
//! EXPERIMENTS.md.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::features::NUM_FEATURES;
use crate::ml::metrics::{Accuracy, AccuracyAccumulator, JointAccumulator};
use crate::runtime::executor::{BatchExecutor, ForestRegistry};
use crate::sim::exec::{Schema, SpeedupRecord, TuneRecord};
use crate::synth::binfmt::ShardFormat;
use crate::synth::sink::{MemorySink, RecordSink, ShardedSink};
use crate::synth::{dataset, generator, sweep::LaunchSweep};
use crate::util::prng::Rng;

use super::train::{self, TrainConfig};

/// Optional raw-dataset dump alongside the accuracy matrix: every
/// device's measured stream is sharded under `dir/<device-key>/` in the
/// requested format, so one crossdev run doubles as a multi-device
/// dataset-generation pass.
#[derive(Clone, Debug)]
pub struct DumpSpec {
    pub dir: PathBuf,
    pub format: ShardFormat,
    pub shards: usize,
}

/// Configuration of one cross-device run.
#[derive(Clone, Debug)]
pub struct CrossDevConfig {
    /// Shared phase-1 settings (scale, configs/kernel, forest, seed).
    pub base: TrainConfig,
    /// The portfolio: one model and one testbed per entry (>= 2).
    pub devices: Vec<DeviceSpec>,
    /// Also persist each device's dataset as disk shards.
    pub dump: Option<DumpSpec>,
}

/// The train-on-A/test-on-B result grid. Row index = the device the
/// model was trained on, column index = the device whose held-out
/// instances it was graded on; `devices` gives both orders.
#[derive(Clone, Debug)]
pub struct CrossDevMatrix {
    pub devices: Vec<String>,
    /// Count-based accuracy per (train, test) cell.
    pub count_based: Vec<Vec<f64>>,
    /// Penalty-weighted accuracy per (train, test) cell.
    pub penalty_weighted: Vec<Vec<f64>>,
    /// Joint accuracy (verdict correct AND measured-best workgroup in
    /// the model's top-k shortlist) per cell; populated only for schema
    /// v2 runs.
    pub joint: Option<Vec<Vec<f64>>>,
    /// Held-out rows graded per test device.
    pub test_rows: Vec<usize>,
}

impl CrossDevMatrix {
    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// Mean count-based accuracy of the same-device (diagonal) cells.
    pub fn diagonal_mean(&self) -> f64 {
        let n = self.n().max(1);
        (0..self.n()).map(|i| self.count_based[i][i]).sum::<f64>() / n as f64
    }

    /// Mean count-based accuracy of the cross-device (off-diagonal)
    /// cells.
    pub fn off_diagonal_mean(&self) -> f64 {
        let n = self.n();
        if n < 2 {
            return 0.0;
        }
        let mut sum = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    sum += self.count_based[i][j];
                }
            }
        }
        sum / (n * (n - 1)) as f64
    }

    /// Write the count-based matrix as CSV: one row per training device,
    /// one column per test device.
    pub fn to_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create {}", dir.display()))?;
            }
        }
        let mut s = String::from("train_device");
        for d in &self.devices {
            s.push(',');
            s.push_str(d);
        }
        if self.joint.is_some() {
            for d in &self.devices {
                s.push_str(&format!(",joint_{d}"));
            }
        }
        s.push('\n');
        for (i, d) in self.devices.iter().enumerate() {
            s.push_str(d);
            for j in 0..self.n() {
                s.push_str(&format!(",{:.4}", self.count_based[i][j]));
            }
            if let Some(jm) = &self.joint {
                for j in 0..self.n() {
                    s.push_str(&format!(",{:.4}", jm[i][j]));
                }
            }
            s.push('\n');
        }
        std::fs::write(path, s)
            .with_context(|| format!("write {}", path.display()))
    }

    /// Human-readable table: count-based (penalty-weighted) per cell.
    pub fn render(&self) -> String {
        let mut out = String::from("train\\test   ");
        for d in &self.devices {
            out.push_str(&format!("{d:>16}"));
        }
        out.push('\n');
        for (i, d) in self.devices.iter().enumerate() {
            out.push_str(&format!("{d:<13}"));
            for j in 0..self.n() {
                out.push_str(&format!(
                    "  {:5.1}% ({:4.1}%)",
                    100.0 * self.count_based[i][j],
                    100.0 * self.penalty_weighted[i][j],
                ));
            }
            out.push('\n');
        }
        if let Some(jm) = &self.joint {
            out.push_str("joint (verdict x wg top-k) accuracy\n");
            for (i, d) in self.devices.iter().enumerate() {
                out.push_str(&format!("{d:<13}"));
                for j in 0..self.n() {
                    out.push_str(&format!("  {:13.1}%", 100.0 * jm[i][j]));
                }
                out.push('\n');
            }
        }
        out.push_str(&format!(
            "diagonal mean {:.1}%  off-diagonal mean {:.1}%\n",
            100.0 * self.diagonal_mean(),
            100.0 * self.off_diagonal_mean()
        ));
        out
    }
}

/// Run the full cross-device experiment: per-device datasets and models,
/// then the (model x testbed) accuracy grid.
pub fn run(cfg: &CrossDevConfig) -> Result<CrossDevMatrix> {
    run_with_progress(cfg, |_| {})
}

/// [`run`] with a per-stage progress callback (stage description).
pub fn run_with_progress(
    cfg: &CrossDevConfig,
    mut progress: impl FnMut(&str),
) -> Result<CrossDevMatrix> {
    anyhow::ensure!(
        cfg.devices.len() >= 2,
        "cross-device evaluation needs >= 2 devices, got {}",
        cfg.devices.len()
    );
    {
        let mut keys: Vec<&str> = cfg.devices.iter().map(|d| d.key).collect();
        keys.sort_unstable();
        keys.dedup();
        anyhow::ensure!(
            keys.len() == cfg.devices.len(),
            "duplicate devices in the cross-device portfolio"
        );
    }

    let base = &cfg.base;
    let sweep = LaunchSweep::new(2048, 2048);
    let build = train::build_config(base);

    // Each device's stream lands in memory for the fit, optionally
    // teeing to disk shards when a dump was requested.
    enum GenSink {
        Plain(MemorySink),
        Dumped(MemorySink, ShardedSink),
    }
    impl RecordSink for GenSink {
        fn accept(&mut self, rec: &TuneRecord) -> Result<()> {
            match self {
                GenSink::Plain(m) => m.accept(rec),
                GenSink::Dumped(m, s) => {
                    m.accept(rec)?;
                    s.accept(rec)
                }
            }
        }
        fn finish(&mut self) -> Result<()> {
            match self {
                GenSink::Plain(m) => m.finish(),
                GenSink::Dumped(m, s) => {
                    m.finish()?;
                    s.finish()
                }
            }
        }
    }

    // Phase 1: one generation pass measures every template on every
    // device in the portfolio (the per-device streams are bit-identical
    // to per-device builds — see `dataset::build_multi_device`), then
    // each device's records split identically and fit one forest each.
    progress(&format!(
        "building datasets for {} devices in one pass",
        cfg.devices.len()
    ));
    let mut rng = Rng::new(base.seed);
    let templates = generator::generate(&mut rng, base.scale);
    let mut sinks: Vec<GenSink> = Vec::with_capacity(cfg.devices.len());
    for dev in &cfg.devices {
        sinks.push(match &cfg.dump {
            None => GenSink::Plain(MemorySink::new()),
            Some(spec) => GenSink::Dumped(
                MemorySink::new(),
                ShardedSink::create(
                    &spec.dir.join(dev.key),
                    spec.shards,
                    dev.key,
                    base.schema,
                    spec.format,
                )?,
            ),
        });
    }
    dataset::build_multi_device(
        &templates,
        &sweep,
        &cfg.devices,
        &build,
        &mut sinks,
        None,
    )?;

    let mut registry = ForestRegistry::new();
    let mut tests: Vec<Vec<TuneRecord>> = Vec::with_capacity(cfg.devices.len());
    for (dev, sink) in cfg.devices.iter().zip(sinks) {
        progress(&format!("fitting the {} model", dev.key));
        let records = match sink {
            GenSink::Plain(m) | GenSink::Dumped(m, _) => m.records,
        };
        anyhow::ensure!(
            !records.is_empty(),
            "{}: empty dataset at scale {}",
            dev.key,
            base.scale
        );
        let (train_split, test_split) =
            dataset::split(&records, base.train_fraction, base.seed);
        let forest = match base.schema {
            Schema::V1 => {
                let bases: Vec<&SpeedupRecord> =
                    train_split.iter().map(|r| &r.base).collect();
                crate::ml::forest::Forest::fit_records(&bases, &base.forest)?
            }
            Schema::V2 => crate::ml::forest::Forest::fit_tune_records(
                &train_split,
                &base.forest,
            )?,
        };
        registry.insert(dev.key, train::encode_default(&forest))?;
        tests.push(test_split.into_iter().cloned().collect());
    }

    // The grid: model i graded on device j's held-out instances, batched
    // through the per-device registry executors. Row matrices depend
    // only on the test set, so they are materialized once, not per model.
    let row_sets: Vec<Vec<Vec<f64>>> = tests
        .iter()
        .map(|test_set| {
            test_set
                .iter()
                .map(|r| r.base.features[..NUM_FEATURES].to_vec())
                .collect()
        })
        .collect();
    let n = cfg.devices.len();
    let mut count = vec![vec![0.0; n]; n];
    let mut penalty = vec![vec![0.0; n]; n];
    let mut joint = match base.schema {
        Schema::V1 => None,
        Schema::V2 => Some(vec![vec![0.0; n]; n]),
    };
    for (i, train_dev) in cfg.devices.iter().enumerate() {
        progress(&format!("grading the {} model", train_dev.key));
        let exec = registry
            .executor_for(train_dev.key)
            .expect("model registered above");
        for (j, test_set) in tests.iter().enumerate() {
            let decisions = exec.decide(&row_sets[j])?;
            let wgs = match &joint {
                Some(_) => Some(exec.predict_wg_logs(&row_sets[j])?),
                None => None,
            };
            let mut acc = AccuracyAccumulator::new();
            let mut jacc = JointAccumulator::new();
            for (k, (rec, d)) in test_set.iter().zip(&decisions).enumerate() {
                acc.push_record(&rec.base, *d);
                if let Some(w) = &wgs {
                    jacc.push(rec.base.speedup, *d, rec.best_wg, w[k]);
                }
            }
            let a: Accuracy = acc.finish();
            count[i][j] = a.count_based;
            penalty[i][j] = a.penalty_weighted;
            if let Some(jm) = joint.as_mut() {
                jm[i][j] = jacc.finish().joint;
            }
        }
    }

    Ok(CrossDevMatrix {
        devices: cfg.devices.iter().map(|d| d.key.to_string()).collect(),
        count_based: count,
        penalty_weighted: penalty,
        joint,
        test_rows: tests.iter().map(Vec::len).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::MeasureConfig;

    fn small_cfg(devices: Vec<DeviceSpec>) -> CrossDevConfig {
        CrossDevConfig {
            base: TrainConfig {
                scale: 0.02,
                configs_per_kernel: 4,
                train_fraction: 0.5,
                measure: MeasureConfig::deterministic(),
                ..Default::default()
            },
            devices,
            dump: None,
        }
    }

    #[test]
    fn matrix_has_the_right_shape_and_bounds() {
        let devices = vec![DeviceSpec::m2090(), DeviceSpec::k20()];
        let m = run(&small_cfg(devices)).unwrap();
        assert_eq!(m.devices, vec!["m2090", "k20"]);
        assert_eq!(m.count_based.len(), 2);
        assert_eq!(m.penalty_weighted.len(), 2);
        for row in m.count_based.iter().chain(&m.penalty_weighted) {
            assert_eq!(row.len(), 2);
            for &x in row {
                assert!((0.0..=1.0).contains(&x), "accuracy {x} out of range");
            }
        }
        assert!(m.test_rows.iter().all(|&r| r > 100), "{:?}", m.test_rows);
    }

    #[test]
    fn fewer_than_two_devices_is_an_error() {
        assert!(run(&small_cfg(vec![DeviceSpec::m2090()])).is_err());
        let dup = vec![DeviceSpec::m2090(), DeviceSpec::m2090()];
        let err = run(&small_cfg(dup)).unwrap_err();
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
    }

    #[test]
    fn csv_round_trips_through_the_table_shape() {
        let m = CrossDevMatrix {
            devices: vec!["a".into(), "b".into()],
            count_based: vec![vec![0.9, 0.7], vec![0.6, 0.95]],
            penalty_weighted: vec![vec![0.99, 0.9], vec![0.88, 0.97]],
            joint: None,
            test_rows: vec![10, 12],
        };
        assert!((m.diagonal_mean() - 0.925).abs() < 1e-12);
        assert!((m.off_diagonal_mean() - 0.65).abs() < 1e-12);
        let path = std::env::temp_dir()
            .join(format!("lmtuner-crossdev-{}.csv", std::process::id()));
        m.to_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let mut lines = body.lines();
        assert_eq!(lines.next(), Some("train_device,a,b"));
        assert_eq!(lines.next(), Some("a,0.9000,0.7000"));
        assert_eq!(lines.next(), Some("b,0.6000,0.9500"));
        assert_eq!(lines.next(), None);
        assert!(m.render().contains("diagonal mean"));
        assert!(!m.render().contains("joint"));
        // joint runs append joint_<dev> columns and a render block
        let jm = CrossDevMatrix {
            joint: Some(vec![vec![0.5, 0.4], vec![0.3, 0.6]]),
            ..m
        };
        jm.to_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        let mut lines = body.lines();
        assert_eq!(lines.next(), Some("train_device,a,b,joint_a,joint_b"));
        assert_eq!(lines.next(), Some("a,0.9000,0.7000,0.5000,0.4000"));
        assert_eq!(lines.next(), Some("b,0.6000,0.9500,0.3000,0.6000"));
        assert!(jm.render().contains("joint"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn joint_crossdev_populates_the_joint_grid() {
        let mut cfg = small_cfg(vec![DeviceSpec::m2090(), DeviceSpec::k20()]);
        cfg.base.schema = Schema::V2;
        let m = run(&cfg).unwrap();
        let jm = m.joint.as_ref().expect("schema v2 populates joint");
        assert_eq!(jm.len(), 2);
        for (i, row) in jm.iter().enumerate() {
            assert_eq!(row.len(), 2);
            for (j, &x) in row.iter().enumerate() {
                assert!((0.0..=1.0).contains(&x), "joint {x} out of range");
                // joint accuracy cannot beat the verdict accuracy
                assert!(x <= m.count_based[i][j] + 1e-12);
            }
        }
        // the models actually learned something about workgroup shapes
        assert!(
            (0..2).any(|i| jm[i][i] > 0.0),
            "joint diagonal all zero: {jm:?}"
        );
    }

    #[test]
    fn dump_writes_per_device_shards_in_one_pass() {
        use crate::synth::sink;
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-crossdev-dump-{}", std::process::id()));
        let mut cfg = small_cfg(vec![DeviceSpec::m2090(), DeviceSpec::k20()]);
        cfg.dump = Some(DumpSpec {
            dir: dir.clone(),
            format: ShardFormat::Bin,
            shards: 2,
        });
        let m = run(&cfg).unwrap();
        assert_eq!(m.devices, vec!["m2090", "k20"]);
        for key in ["m2090", "k20"] {
            let (recs, stream) = sink::load_sharded_tagged(&dir.join(key)).unwrap();
            assert_eq!(stream.device.as_deref(), Some(key));
            assert_eq!(stream.format, ShardFormat::Bin);
            assert!(!recs.is_empty(), "{key}: empty dump");
            // the dump is the stream the model fitted on: same records
            // the single-device reference build measures on this device
            let dev = if key == "m2090" {
                DeviceSpec::m2090()
            } else {
                DeviceSpec::k20()
            };
            let reference = train::build_records(&dev, &cfg.base);
            assert_eq!(recs.len(), reference.len());
            assert_eq!(
                recs[0].base.features.map(|x| x as f32),
                reference[0].base.features.map(|x| x as f32)
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn labels_flip_between_devices_for_some_kernel() {
        // The premise of the whole experiment: the same kernel instance
        // can favor local memory on one device and not on another, while
        // its feature vector stays finite on both.
        use crate::sim::exec::measure;
        use crate::sim::timing::{simulate, Variant};
        let a = DeviceSpec::m2090();
        let b = DeviceSpec::k20();
        let mut rng = Rng::new(0x0DD5);
        let templates = generator::generate_n(&mut rng, 2);
        let sweep = LaunchSweep::new(2048, 2048);
        let cfg = MeasureConfig::deterministic();
        let mut lrng = Rng::new(42);
        let mut flips = 0usize;
        let mut compared = 0usize;
        for t in &templates {
            for launch in sweep.sampled_balanced(&mut lrng, 3) {
                let da = t.descriptor(&launch, &a);
                let db = t.descriptor(&launch, &b);
                if !simulate(&da, &a, Variant::Baseline).feasible()
                    || !simulate(&db, &b, Variant::Baseline).feasible()
                {
                    continue;
                }
                let ra = measure(&da, &a, &cfg);
                let rb = measure(&db, &b, &cfg);
                assert!(ra.features.iter().all(|x| x.is_finite()), "{}", ra.name);
                assert!(rb.features.iter().all(|x| x.is_finite()), "{}", rb.name);
                compared += 1;
                flips += (ra.beneficial() != rb.beneficial()) as usize;
            }
        }
        assert!(compared > 100, "only {compared} comparable instances");
        assert!(
            flips > 0,
            "no kernel's oracle label flipped between {} and {} \
             ({compared} instances compared)",
            a.key,
            b.key
        );
    }

    // The full-portfolio diagonal-vs-off-diagonal acceptance assertion
    // lives in rust/tests/crossdev.rs (one expensive run per CI pass,
    // not two).
}
