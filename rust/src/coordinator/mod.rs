//! The coordinator: phase-1 training pipeline and the phase-2 batched
//! prediction service (paper Fig. 2, both halves).
//!
//! Training runs either fully in memory ([`train::run`]) or as the
//! paper-scale streaming pipeline ([`train::run_sharded`]): one build
//! pass shards the dataset to CSV on disk while reservoir-sampling the
//! training split, then a second streaming pass over the shards grades
//! every held-out instance — peak memory stays bounded at any scale.
//!
//! ```no_run
//! use lmtuner::coordinator::train::{self, ShardedTrainConfig, TrainConfig};
//! use lmtuner::gpu::spec::DeviceSpec;
//!
//! let dev = DeviceSpec::m2090();
//! let cfg = ShardedTrainConfig::new(
//!     TrainConfig { scale: 1.0, ..Default::default() },
//!     "data/shards".into(),
//! );
//! let out = train::run_sharded(&dev, &cfg, None).unwrap();
//! println!("{} instances, trained on {}", out.summary.records, out.train_size);
//! ```
//! Cross-device generalization lives in [`crossdev`]: per-device
//! datasets and models over the `gpu::registry` portfolio, graded as a
//! train-on-A/test-on-B accuracy matrix. Serving routes prediction
//! batches by device through [`service::DeviceRouter`].
pub mod crossdev;
pub mod messages;
pub mod service;
pub mod train;
