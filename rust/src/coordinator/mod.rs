//! The coordinator: phase-1 training pipeline and the phase-2 batched
//! prediction service (paper Fig. 2, both halves).
pub mod messages;
pub mod service;
pub mod train;
