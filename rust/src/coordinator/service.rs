//! The prediction service: phase 2 of the paper's framework (Fig. 2,
//! right side) as a serving system.
//!
//! Clients submit feature vectors through one [`ServiceHandle`]; requests
//! are round-robined across N sharded worker threads, each owning a
//! [`BatchExecutor`]. A worker drains its queue, batches up to
//! `max_batch` rows or `max_wait`, and ships the batch to its backend —
//! the flattened `runtime::fastexec` hot path by default (shards share
//! one compiled [`FlatForest`]), or the PJRT artifact path. Joint
//! (schema v2) models fill `PredictResponse::wg_logs` from the same
//! single traversal as the verdict. Bounded queues give backpressure; a
//! failed batch produces typed [`PredictError`] replies (never dropped
//! channels); shutdown is an explicit control message, so live client
//! handles cannot hang it.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::kernelmodel::features::NUM_FEATURES;
use crate::ml::export::EncodedForest;
use crate::obs::metrics::{Histogram, MetricsRegistry};
use crate::runtime::executor::{BatchExecutor, ForestRegistry};
use crate::runtime::fastexec::{FlatForest, FlatForestExecutor};
use crate::runtime::forest_exec::ForestExecutor;
use crate::runtime::pjrt::Engine;

use super::messages::{
    Pending, PredictError, PredictReply, PredictRequest, PredictResponse, WorkerMsg,
};

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Maximum rows per backend batch (clamped to the backend's limit).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Bounded per-shard queue depth (backpressure beyond this).
    pub queue_depth: usize,
    /// Number of sharded worker threads (each owns one executor).
    pub workers: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 4096,
            max_wait: Duration::from_micros(200),
            queue_depth: 16 * 1024,
            workers: 1,
        }
    }
}

/// Serving metrics for one worker shard (or, after [`ServiceStats::absorb`],
/// a sum over shards). The histograms use the `obs` log2 buckets, so
/// absorbing is exact and merge-order independent: the merged
/// p50/p90/p99 read the same whether computed per shard or after the
/// fold.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub served: u64,
    pub batches: u64,
    /// Requests answered with a typed error (failed batches).
    pub rejected: u64,
    /// Per-request wait from enqueue to batch formation, seconds.
    pub queue_wait: Histogram,
    /// Per-batch backend execution time, seconds.
    pub exec_time: Histogram,
    /// Batch-size distribution (rows per backend call).
    pub batch_rows: Histogram,
}

impl ServiceStats {
    /// Fold another shard's stats in (counter sums + exact histogram
    /// merges).
    pub fn absorb(&mut self, other: &ServiceStats) {
        self.served += other.served;
        self.batches += other.batches;
        self.rejected += other.rejected;
        self.queue_wait.merge(&other.queue_wait);
        self.exec_time.merge(&other.exec_time);
        self.batch_rows.merge(&other.batch_rows);
    }

    /// Export under `prefix` (e.g. `serve` or `serve.worker0`):
    /// counters `.served`/`.batches`/`.rejected`, histograms
    /// `.queue_wait_s`/`.exec_s`/`.batch_rows`.
    pub fn export(&self, prefix: &str, reg: &mut MetricsRegistry) {
        reg.add(&format!("{prefix}.served"), self.served);
        reg.add(&format!("{prefix}.batches"), self.batches);
        reg.add(&format!("{prefix}.rejected"), self.rejected);
        reg.merge_histogram(&format!("{prefix}.queue_wait_s"), &self.queue_wait);
        reg.merge_histogram(&format!("{prefix}.exec_s"), &self.exec_time);
        reg.merge_histogram(&format!("{prefix}.batch_rows"), &self.batch_rows);
    }

    /// One-line human summary (the serve snapshot printer and the
    /// per-worker shutdown breakdown both use this).
    pub fn summary_line(&self) -> String {
        let us = |h: &Histogram, p: f64| h.percentile(p) * 1e6;
        format!(
            "served {} rejected {} batches {} | exec p50/p90/p99 \
             {:.0}/{:.0}/{:.0}us | queue-wait p50/p90/p99 {:.0}/{:.0}/{:.0}us",
            self.served,
            self.rejected,
            self.batches,
            us(&self.exec_time, 50.0),
            us(&self.exec_time, 90.0),
            us(&self.exec_time, 99.0),
            us(&self.queue_wait, 50.0),
            us(&self.queue_wait, 90.0),
            us(&self.queue_wait, 99.0),
        )
    }
}

/// Handle used by clients; cheap to clone. Holding a clone never blocks
/// service shutdown.
#[derive(Clone)]
pub struct ServiceHandle {
    shards: Arc<Vec<SyncSender<WorkerMsg>>>,
    next: Arc<AtomicUsize>,
    /// Set by shutdown before the control message, so handles stop
    /// accepting work that the draining workers might never see.
    stopped: Arc<AtomicBool>,
}

fn into_job(msg: WorkerMsg) -> Pending {
    match msg {
        WorkerMsg::Job(p) => p,
        WorkerMsg::Shutdown => unreachable!("handles only send jobs"),
    }
}

impl ServiceHandle {
    /// Round-robin the request to a shard; on a full shard, fail over to
    /// the others before reporting backpressure.
    fn enqueue(&self, pending: Pending) -> Result<()> {
        if self.stopped.load(Ordering::Acquire) {
            return Err(anyhow!("service stopped"));
        }
        let n = self.shards.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        let mut pending = pending;
        let mut saw_full = false;
        for k in 0..n {
            let tx = &self.shards[(start + k) % n];
            match tx.try_send(WorkerMsg::Job(pending)) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Full(m)) => {
                    saw_full = true;
                    pending = into_job(m);
                }
                Err(TrySendError::Disconnected(m)) => {
                    pending = into_job(m);
                }
            }
        }
        if saw_full {
            Err(anyhow!("queue full (backpressure)"))
        } else {
            Err(anyhow!("service stopped"))
        }
    }

    /// Submit one request and wait for its response (blocking). A failed
    /// batch surfaces as the typed [`PredictError`], not a channel error.
    pub fn predict(&self, features: [f64; NUM_FEATURES]) -> Result<PredictResponse> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.enqueue(Pending {
            req: PredictRequest { id: 0, features },
            enqueued: Instant::now(),
            reply: reply_tx,
        })?;
        match reply_rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(e.into()),
            Err(_) => Err(anyhow!("service stopped before replying")),
        }
    }

    /// Fire a request with an async reply channel (for load generators).
    pub fn submit(
        &self,
        id: u64,
        features: [f64; NUM_FEATURES],
        reply: std::sync::mpsc::Sender<PredictReply>,
    ) -> Result<()> {
        self.enqueue(Pending {
            req: PredictRequest { id, features },
            enqueued: Instant::now(),
            reply,
        })
    }
}

/// Read-only view of a service's live per-shard stats (see
/// [`Service::stats_observer`]). Slightly stale by design — each
/// worker republishes after completing a batch.
#[derive(Clone)]
pub struct StatsObserver {
    live: Arc<Vec<Mutex<ServiceStats>>>,
}

impl StatsObserver {
    /// Point-in-time copy of every shard's stats, in shard order.
    pub fn per_worker(&self) -> Vec<ServiceStats> {
        self.live.iter().map(|slot| slot.lock().unwrap().clone()).collect()
    }

    /// Merged live stats across shards.
    pub fn total(&self) -> ServiceStats {
        let mut total = ServiceStats::default();
        for s in self.per_worker() {
            total.absorb(&s);
        }
        total
    }
}

/// The running service. `shutdown()` (or drop) stops every shard via the
/// explicit control message and joins them.
pub struct Service {
    handle: ServiceHandle,
    workers: Vec<JoinHandle<ServiceStats>>,
    /// Per-shard live stats: each worker republishes its counters after
    /// every batch, so observers (the serve snapshot printer) read
    /// consistent point-in-time copies without touching worker state.
    live: Arc<Vec<Mutex<ServiceStats>>>,
}

impl Service {
    /// Start with the artifact-free default backend: the forest is
    /// compiled once into the flat hot-path layout and every shard gets
    /// a [`FlatForestExecutor`] sharing those tables. A corrupt encoding
    /// fails here, before any worker spawns.
    pub fn start_native(forest: EncodedForest, cfg: ServiceConfig) -> Result<Service> {
        let shards = cfg.workers.max(1);
        let flat = Arc::new(FlatForest::compile(&forest)?);
        // Split the host's cores across shards so concurrent batches
        // don't oversubscribe (each shard batches independently).
        let host = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let per_shard = (host / shards).max(1);
        let execs: Vec<FlatForestExecutor> = (0..shards)
            .map(|_| FlatForestExecutor::from_shared(flat.clone()).threads(per_shard))
            .collect();
        Self::start_sharded(execs, cfg)
    }

    /// Start with the PJRT backend: one [`ForestExecutor`] per shard over
    /// a shared engine (the compiled-executable cache is shared).
    pub fn start_pjrt(
        engine: Arc<Engine>,
        forest: EncodedForest,
        cfg: ServiceConfig,
    ) -> Result<Service> {
        let shards = cfg.workers.max(1);
        let execs: Vec<ForestExecutor> = (0..shards)
            .map(|_| ForestExecutor::new(engine.clone(), &forest))
            .collect::<Result<_>>()?;
        Self::start_sharded(execs, cfg)
    }

    /// Start one worker thread per executor. Executor construction
    /// happens before any thread spawns, so backend init errors surface
    /// here instead of as silently-dead workers.
    pub fn start_sharded<E: BatchExecutor + 'static>(
        execs: Vec<E>,
        cfg: ServiceConfig,
    ) -> Result<Service> {
        anyhow::ensure!(!execs.is_empty(), "need at least one executor");
        let live: Arc<Vec<Mutex<ServiceStats>>> =
            Arc::new((0..execs.len()).map(|_| Mutex::new(ServiceStats::default())).collect());
        let mut shards = Vec::with_capacity(execs.len());
        let mut workers = Vec::with_capacity(execs.len());
        for (i, exec) in execs.into_iter().enumerate() {
            let (tx, rx) = sync_channel::<WorkerMsg>(cfg.queue_depth.max(1));
            let worker_cfg = cfg.clone();
            let live = Arc::clone(&live);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lmtuner-batcher-{i}"))
                    .spawn(move || worker_loop(exec, worker_cfg, rx, &live[i]))?,
            );
            shards.push(tx);
        }
        Ok(Service {
            handle: ServiceHandle {
                shards: Arc::new(shards),
                next: Arc::new(AtomicUsize::new(0)),
                stopped: Arc::new(AtomicBool::new(false)),
            },
            workers,
            live,
        })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    pub fn num_shards(&self) -> usize {
        self.handle.shards.len()
    }

    /// Point-in-time copy of every shard's live stats, in shard order.
    /// Slightly stale by design (each worker republishes after a batch
    /// completes), but internally consistent per shard.
    pub fn per_worker_snapshot(&self) -> Vec<ServiceStats> {
        self.stats_observer().per_worker()
    }

    /// Merged live stats across shards (the serve snapshot printer).
    pub fn stats_snapshot(&self) -> ServiceStats {
        self.stats_observer().total()
    }

    /// Detached read-only view of the live stats: cloneable and
    /// `Send`, so a background snapshot printer can poll while the
    /// `Service` value stays with the thread that will shut it down.
    pub fn stats_observer(&self) -> StatsObserver {
        StatsObserver { live: Arc::clone(&self.live) }
    }

    /// Stop every shard and collect summed stats. Safe to call while
    /// clients still hold handles: shutdown is a control message, not a
    /// channel disconnect, so it cannot hang on live clones. Handles are
    /// flagged stopped first, then each worker serves what is already
    /// queued before exiting; enqueues after the flag get "service
    /// stopped". A submit racing the flag itself may instead observe a
    /// closed reply channel, which the blocking `predict` reports as
    /// "service stopped before replying".
    pub fn shutdown(self) -> ServiceStats {
        self.shutdown_per_worker().0
    }

    /// [`Service::shutdown`], but keeping the per-shard breakdown (in
    /// shard order) next to the merged total — a dead or slow shard is
    /// visible as an outlier row instead of vanishing into the sum.
    pub fn shutdown_per_worker(mut self) -> (ServiceStats, Vec<ServiceStats>) {
        self.initiate_shutdown();
        let per_worker: Vec<ServiceStats> = self
            .workers
            .drain(..)
            .map(|w| w.join().unwrap_or_default())
            .collect();
        let mut total = ServiceStats::default();
        for s in &per_worker {
            total.absorb(s);
        }
        (total, per_worker)
    }

    fn initiate_shutdown(&self) {
        self.handle.stopped.store(true, Ordering::Release);
        for tx in self.handle.shards.iter() {
            // Blocking send: the worker is draining its queue, so space
            // frees up; if the worker already died, send errors cleanly.
            let _ = tx.send(WorkerMsg::Shutdown);
        }
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        if self.workers.is_empty() {
            return; // shutdown() already joined them
        }
        self.initiate_shutdown();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// One serving process, a whole device portfolio: a [`Service`] per
/// registered device behind a single routing handle. Clients name the
/// device their kernel targets and the router dispatches the request to
/// that device's model — the serving-side face of the
/// `runtime::executor::ForestRegistry`.
pub struct DeviceRouter {
    services: Vec<(String, Service)>,
    handle: RouterHandle,
}

/// Cheap-to-clone client handle that routes by device key.
#[derive(Clone)]
pub struct RouterHandle {
    handles: Arc<std::collections::BTreeMap<String, ServiceHandle>>,
}

impl RouterHandle {
    fn shard(&self, device: &str) -> Result<&ServiceHandle> {
        self.handles.get(device).ok_or_else(|| {
            anyhow!(
                "no model registered for device '{device}' (serving: {})",
                self.handles
                    .keys()
                    .map(String::as_str)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    /// Blocking predict against `device`'s model.
    pub fn predict(
        &self,
        device: &str,
        features: [f64; NUM_FEATURES],
    ) -> Result<PredictResponse> {
        self.shard(device)?.predict(features)
    }

    /// Async submit against `device`'s model.
    pub fn submit(
        &self,
        device: &str,
        id: u64,
        features: [f64; NUM_FEATURES],
        reply: std::sync::mpsc::Sender<PredictReply>,
    ) -> Result<()> {
        self.shard(device)?.submit(id, features, reply)
    }

    /// Devices this router serves, sorted.
    pub fn devices(&self) -> Vec<&str> {
        self.handles.keys().map(String::as_str).collect()
    }
}

impl DeviceRouter {
    /// Start one flat-backend [`Service`] per registry entry. Each
    /// device's shards share that device's compiled tables;
    /// `cfg.workers` applies per device.
    pub fn start_native(registry: &ForestRegistry, cfg: ServiceConfig) -> Result<DeviceRouter> {
        anyhow::ensure!(!registry.is_empty(), "empty model registry");
        let shards = cfg.workers.max(1);
        // Divide the host's cores across every shard of every device so
        // concurrent batches don't oversubscribe.
        let host = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let per_shard = (host / (shards * registry.len())).max(1);
        let mut services = Vec::with_capacity(registry.len());
        let mut handles = std::collections::BTreeMap::new();
        for device in registry.devices() {
            let execs: Vec<FlatForestExecutor> = (0..shards)
                .map(|_| {
                    registry
                        .executor_for(device)
                        .expect("device iterated from the registry")
                        .threads(per_shard)
                })
                .collect();
            let svc = Service::start_sharded(execs, cfg.clone())?;
            handles.insert(device.to_string(), svc.handle());
            services.push((device.to_string(), svc));
        }
        Ok(DeviceRouter {
            services,
            handle: RouterHandle { handles: Arc::new(handles) },
        })
    }

    pub fn handle(&self) -> RouterHandle {
        self.handle.clone()
    }

    pub fn devices(&self) -> Vec<&str> {
        self.services.iter().map(|(d, _)| d.as_str()).collect()
    }

    /// Stop every per-device service; returns (device, stats) pairs in
    /// start order.
    pub fn shutdown(self) -> Vec<(String, ServiceStats)> {
        self.services
            .into_iter()
            .map(|(d, svc)| (d, svc.shutdown()))
            .collect()
    }
}

fn worker_loop<E: BatchExecutor>(
    exec: E,
    cfg: ServiceConfig,
    rx: Receiver<WorkerMsg>,
    live: &Mutex<ServiceStats>,
) -> ServiceStats {
    let max_batch = cfg.max_batch.min(exec.max_batch()).max(1);
    let mut stats = ServiceStats::default();
    let mut batch: Vec<Pending> = Vec::with_capacity(max_batch.min(4096));
    let mut shutting_down = false;
    loop {
        batch.clear();
        // Block for the first request (or the shutdown marker).
        match rx.recv() {
            Ok(WorkerMsg::Job(p)) => batch.push(p),
            Ok(WorkerMsg::Shutdown) | Err(_) => shutting_down = true,
        }
        if !shutting_down {
            // Drain up to max_batch or until max_wait expires.
            let deadline = Instant::now() + cfg.max_wait;
            while batch.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(WorkerMsg::Job(p)) => batch.push(p),
                    Ok(WorkerMsg::Shutdown) => {
                        shutting_down = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }
        }
        if !batch.is_empty() {
            serve_batch(&exec, &mut batch, &mut stats);
            *live.lock().unwrap() = stats.clone();
        }
        if shutting_down {
            // Serve whatever is already queued (handles were flagged
            // stopped before the Shutdown marker, so only a send racing
            // that flag can still slip in behind this drain), then exit.
            loop {
                batch.clear();
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(WorkerMsg::Job(p)) => batch.push(p),
                        Ok(WorkerMsg::Shutdown) => {}
                        Err(_) => break,
                    }
                }
                if batch.is_empty() {
                    break;
                }
                serve_batch(&exec, &mut batch, &mut stats);
            }
            *live.lock().unwrap() = stats.clone();
            return stats;
        }
    }
}

fn serve_batch<E: BatchExecutor>(
    exec: &E,
    batch: &mut Vec<Pending>,
    stats: &mut ServiceStats,
) {
    // Propagate a failure to every waiting client as a typed error
    // response instead of dropping their reply channels.
    fn fail_batch(batch: &mut Vec<Pending>, stats: &mut ServiceStats, reason: String) {
        stats.rejected += batch.len() as u64;
        for p in batch.drain(..) {
            let _ = p.reply.send(Err(PredictError {
                id: p.req.id,
                reason: reason.clone(),
            }));
        }
    }

    // Batch formation is complete: everything each request waited for
    // beyond this point is execution, so queue-wait is sampled here.
    let formed = Instant::now();
    for p in batch.iter() {
        stats
            .queue_wait
            .observe_duration(formed.saturating_duration_since(p.enqueued));
    }
    stats.batch_rows.observe(batch.len() as f64);

    let rows: Vec<Vec<f64>> = batch.iter().map(|p| p.req.features.to_vec()).collect();
    // One traversal fills every output plane: the verdict score and, for
    // joint (schema v2) models, the workgroup-shape logs.
    let k = exec.num_outputs().max(1);
    let exec_started = Instant::now();
    let outcome = exec.predict_outputs(&rows);
    stats.exec_time.observe_duration(exec_started.elapsed());
    match outcome {
        Ok(outs) if outs.len() == rows.len() * k => {
            let bsize = batch.len();
            for (i, p) in batch.drain(..).enumerate() {
                let score = outs[i * k];
                let resp = PredictResponse {
                    id: p.req.id,
                    score,
                    use_local_memory: score > 0.0,
                    wg_logs: (k >= 3).then(|| (outs[i * k + 1], outs[i * k + 2])),
                    batch_size: bsize,
                    latency: p.enqueued.elapsed(),
                };
                let _ = p.reply.send(Ok(resp));
                stats.served += 1;
            }
            stats.batches += 1;
        }
        Ok(outs) => fail_batch(
            batch,
            stats,
            format!(
                "backend '{}' returned {} outputs for {} rows x {k} planes",
                exec.backend(),
                outs.len(),
                rows.len()
            ),
        ),
        Err(err) => fail_batch(batch, stats, format!("{err:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::export::{encode, ExportContract};
    use crate::ml::forest::{Forest, ForestConfig};
    use crate::util::prng::Rng;

    fn toy_encoded(seed: u64) -> EncodedForest {
        let nf = NUM_FEATURES;
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..nf)
            .map(|_| (0..300).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..300).map(|i| if x[0][i] > 0.0 { 1.0 } else { -1.0 }).collect();
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig { num_trees: 20, threads: 1, ..Default::default() },
        );
        encode(&f, ExportContract::default())
    }

    fn random_features(rng: &mut Rng) -> [f64; NUM_FEATURES] {
        let mut feats = [0.0; NUM_FEATURES];
        for f in feats.iter_mut() {
            *f = rng.range_f64(-1.0, 1.0);
        }
        feats
    }

    #[test]
    fn service_roundtrip_and_batching_native() {
        let enc = toy_encoded(7);
        let svc = Service::start_native(
            enc.clone(),
            ServiceConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        )
        .unwrap();
        let h = svc.handle();

        // Concurrent clients.
        let mut threads = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            let enc = enc.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..50 {
                    let feats = random_features(&mut rng);
                    let resp = h.predict(feats).unwrap();
                    let want = enc.predict(&feats);
                    assert!((resp.score - want).abs() < 1e-9);
                    assert_eq!(resp.use_local_memory, want > 0.0);
                    assert!(resp.batch_size >= 1);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let stats = svc.shutdown();
        assert_eq!(stats.served, 200);
        assert_eq!(stats.rejected, 0);
        assert!(stats.batches <= 200);
        // Telemetry: one queue-wait sample per request, one execution /
        // batch-size sample per batch, ordered percentiles.
        assert_eq!(stats.queue_wait.count(), 200);
        assert_eq!(stats.exec_time.count(), stats.batches);
        assert_eq!(stats.batch_rows.count(), stats.batches);
        assert!(stats.exec_time.percentile(50.0) > 0.0);
        assert!(
            stats.exec_time.percentile(99.0) >= stats.exec_time.percentile(50.0)
        );
        assert!(stats.batch_rows.max() <= 64.0);
        let line = stats.summary_line();
        assert!(line.contains("served 200"), "{line}");
        assert!(line.contains("queue-wait p50/p90/p99"), "{line}");
    }

    #[test]
    fn sharded_workers_serve_everything() {
        let enc = toy_encoded(9);
        let svc = Service::start_native(
            enc,
            ServiceConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
                workers: 3,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(svc.num_shards(), 3);
        let h = svc.handle();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut rng = Rng::new(10);
        let total = 500u64;
        for i in 0..total {
            h.submit(i, random_features(&mut rng), tx.clone()).unwrap();
        }
        drop(tx);
        let mut seen = 0u64;
        while let Ok(reply) = rx.recv() {
            reply.unwrap();
            seen += 1;
        }
        assert_eq!(seen, total);
        let stats = svc.shutdown();
        assert_eq!(stats.served, total);
    }

    #[test]
    fn shutdown_with_live_handles_does_not_hang() {
        let enc = toy_encoded(11);
        let svc = Service::start_native(enc, ServiceConfig::default()).unwrap();
        let h = svc.handle();
        let _second = h.clone(); // two live client handles

        // Run shutdown on another thread so a regression (the old
        // clone-and-drop hack waiting on channel disconnect) fails the
        // test instead of hanging the suite.
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = done_tx.send(svc.shutdown());
        });
        let stats = done_rx
            .recv_timeout(Duration::from_secs(10))
            .expect("shutdown hung while client handles were alive");
        assert_eq!(stats.served, 0);

        // The held handle now sees a stopped service, not a hang.
        let err = h.predict([0.0; NUM_FEATURES]).unwrap_err();
        assert!(format!("{err}").contains("service stopped"), "{err}");
    }

    struct FailingExec;

    impl BatchExecutor for FailingExec {
        fn backend(&self) -> &'static str {
            "failing"
        }
        fn max_batch(&self) -> usize {
            8
        }
        fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
            anyhow::bail!("injected backend failure ({} rows)", rows.len())
        }
    }

    #[test]
    fn failed_batches_return_typed_errors_and_count_rejected() {
        let svc = Service::start_sharded(
            vec![FailingExec],
            ServiceConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
                ..Default::default()
            },
        )
        .unwrap();
        let h = svc.handle();

        // Blocking path: typed error, not an opaque RecvError.
        let err = h.predict([0.5; NUM_FEATURES]).unwrap_err();
        assert!(
            format!("{err:#}").contains("injected backend failure"),
            "{err:#}"
        );

        // Async path: every submitted request gets an Err reply.
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..20u64 {
            h.submit(i, [0.25; NUM_FEATURES], tx.clone()).unwrap();
        }
        drop(tx);
        let mut errors = 0;
        while let Ok(reply) = rx.recv() {
            let e = reply.unwrap_err();
            assert!(e.reason.contains("injected backend failure"));
            errors += 1;
        }
        assert_eq!(errors, 20);

        let stats = svc.shutdown();
        assert_eq!(stats.served, 0);
        assert_eq!(stats.rejected, 21);
        // Failed batches still record queue-wait and execution time.
        assert_eq!(stats.queue_wait.count(), 21);
        assert!(stats.exec_time.count() >= 1);
    }

    #[test]
    fn per_worker_breakdown_surfaces_uneven_load() {
        // Shard 0 is "dead" (every batch fails); shard 1 is healthy.
        // The merged blob hides this; the per-worker breakdown must not.
        struct MaybeFailing {
            fail: bool,
        }
        impl BatchExecutor for MaybeFailing {
            fn backend(&self) -> &'static str {
                "maybe"
            }
            fn max_batch(&self) -> usize {
                8
            }
            fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
                if self.fail {
                    anyhow::bail!("dead shard")
                }
                Ok(rows.iter().map(|r| r[0]).collect())
            }
        }
        let svc = Service::start_sharded(
            vec![MaybeFailing { fail: true }, MaybeFailing { fail: false }],
            ServiceConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
                ..Default::default()
            },
        )
        .unwrap();
        let h = svc.handle();
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..100u64 {
            h.submit(i, [0.5; NUM_FEATURES], tx.clone()).unwrap();
        }
        drop(tx);
        let (mut ok, mut failed) = (0u64, 0u64);
        while let Ok(reply) = rx.recv() {
            match reply {
                Ok(_) => ok += 1,
                Err(_) => failed += 1,
            }
        }
        assert_eq!(ok + failed, 100);
        assert!(ok > 0 && failed > 0, "round-robin must hit both shards");

        let (total, per_worker) = svc.shutdown_per_worker();
        assert_eq!(per_worker.len(), 2);
        assert_eq!(per_worker[0].served, 0, "dead shard must serve nothing");
        assert!(per_worker[0].rejected > 0);
        assert!(per_worker[1].served > 0);
        assert_eq!(per_worker[1].rejected, 0);
        // The merged blob is exactly the fold of the breakdown.
        assert_eq!(total.served, per_worker[0].served + per_worker[1].served);
        assert_eq!(total.rejected, per_worker[0].rejected + per_worker[1].rejected);
        assert_eq!(
            total.queue_wait.count(),
            per_worker[0].queue_wait.count() + per_worker[1].queue_wait.count()
        );
        assert_eq!(total.queue_wait.count(), 100);
        assert!(per_worker[1].exec_time.count() >= 1);
    }

    #[test]
    fn live_snapshot_converges_to_final_stats() {
        let enc = toy_encoded(17);
        let svc = Service::start_native(
            enc,
            ServiceConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(100),
                workers: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let h = svc.handle();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut rng = Rng::new(31);
        for i in 0..200u64 {
            h.submit(i, random_features(&mut rng), tx.clone()).unwrap();
        }
        drop(tx);
        let mut seen = 0;
        while let Ok(reply) = rx.recv() {
            reply.unwrap();
            seen += 1;
        }
        assert_eq!(seen, 200);
        // Workers republish after each batch; the last publish can
        // trail the final reply briefly.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let snap = svc.stats_snapshot();
            if snap.served == 200 {
                assert_eq!(snap.queue_wait.count(), 200);
                assert_eq!(svc.per_worker_snapshot().len(), 2);
                break;
            }
            assert!(Instant::now() < deadline, "live snapshot never converged");
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = svc.shutdown();
        assert_eq!(stats.served, 200);
    }

    #[test]
    fn device_router_routes_requests_to_the_right_model() {
        let enc_a = toy_encoded(21);
        let enc_b = toy_encoded(23);
        let mut reg = ForestRegistry::new();
        reg.insert("m2090", enc_a.clone()).unwrap();
        reg.insert("k20", enc_b.clone()).unwrap();
        let router = DeviceRouter::start_native(
            &reg,
            ServiceConfig {
                max_batch: 32,
                max_wait: Duration::from_micros(100),
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(router.devices(), vec!["k20", "m2090"]);
        let h = router.handle();
        assert_eq!(h.devices(), vec!["k20", "m2090"]);

        let mut rng = Rng::new(55);
        let mut disagreements = 0usize;
        for _ in 0..40 {
            let feats = random_features(&mut rng);
            let ra = h.predict("m2090", feats).unwrap();
            let rb = h.predict("k20", feats).unwrap();
            assert!((ra.score - enc_a.predict(&feats)).abs() < 1e-9);
            assert!((rb.score - enc_b.predict(&feats)).abs() < 1e-9);
            disagreements += (ra.score != rb.score) as usize;
        }
        assert!(disagreements > 0, "models never disagreed; routing unproven");

        // unknown device: typed routing error naming the portfolio
        let err = h.predict("gtx9000", [0.0; NUM_FEATURES]).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("gtx9000") && msg.contains("m2090"), "{msg}");

        let stats = router.shutdown();
        assert_eq!(stats.len(), 2);
        let served: u64 = stats.iter().map(|(_, s)| s.served).sum();
        assert_eq!(served, 80);
    }

    #[test]
    fn device_router_async_submit_and_shutdown() {
        let mut reg = ForestRegistry::new();
        reg.insert("gtx480", toy_encoded(29)).unwrap();
        let router =
            DeviceRouter::start_native(&reg, ServiceConfig::default()).unwrap();
        let h = router.handle();
        let (tx, rx) = std::sync::mpsc::channel();
        let mut rng = Rng::new(77);
        for i in 0..50u64 {
            h.submit("gtx480", i, random_features(&mut rng), tx.clone())
                .unwrap();
        }
        drop(tx);
        let mut seen = 0;
        while let Ok(reply) = rx.recv() {
            reply.unwrap();
            seen += 1;
        }
        assert_eq!(seen, 50);
        router.shutdown();
        // after shutdown the handle reports a stopped service
        assert!(h.predict("gtx480", [0.0; NUM_FEATURES]).is_err());
    }

    #[test]
    fn drop_stops_workers_without_shutdown_call() {
        let enc = toy_encoded(13);
        let svc = Service::start_native(enc, ServiceConfig::default()).unwrap();
        let h = svc.handle();
        drop(svc); // must join workers, not hang
        assert!(h.predict([0.0; NUM_FEATURES]).is_err());
    }
}
