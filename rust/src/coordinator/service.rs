//! The prediction service: phase 2 of the paper's framework (Fig. 2,
//! right side) as a serving system.
//!
//! Clients submit feature vectors; a dynamic batcher drains the queue,
//! pads to the nearest compiled batch-size variant, and runs the batch
//! through the PJRT forest executable. Bounded queue gives backpressure;
//! batching policy = "wait up to `max_wait` for `max_batch` requests,
//! ship what you have" (the classic serving tradeoff).

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::kernelmodel::features::NUM_FEATURES;
use crate::ml::export::EncodedForest;
use crate::runtime::forest_exec::ForestExecutor;
use crate::runtime::pjrt::Engine;

use super::messages::{Pending, PredictRequest, PredictResponse};

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Maximum rows per PJRT batch (clamped to the largest artifact).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub max_wait: Duration,
    /// Bounded-queue depth (backpressure beyond this).
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 4096,
            max_wait: Duration::from_micros(200),
            queue_depth: 16 * 1024,
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub served: u64,
    pub batches: u64,
    pub rejected: u64,
}

/// Handle used by clients; cheap to clone.
#[derive(Clone)]
pub struct ServiceHandle {
    tx: SyncSender<Pending>,
}

impl ServiceHandle {
    /// Submit one request and wait for its response (blocking).
    pub fn predict(&self, features: [f64; NUM_FEATURES]) -> Result<PredictResponse> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let req = PredictRequest { id: 0, features };
        self.tx
            .try_send(Pending { req, enqueued: Instant::now(), reply: reply_tx })
            .map_err(|e| match e {
                TrySendError::Full(_) => anyhow::anyhow!("queue full (backpressure)"),
                TrySendError::Disconnected(_) => anyhow::anyhow!("service stopped"),
            })?;
        Ok(reply_rx.recv()?)
    }

    /// Fire a request with an async reply channel (for load generators).
    pub fn submit(
        &self,
        id: u64,
        features: [f64; NUM_FEATURES],
        reply: std::sync::mpsc::Sender<PredictResponse>,
    ) -> Result<()> {
        self.tx
            .try_send(Pending {
                req: PredictRequest { id, features },
                enqueued: Instant::now(),
                reply,
            })
            .map_err(|e| match e {
                TrySendError::Full(_) => anyhow::anyhow!("queue full (backpressure)"),
                TrySendError::Disconnected(_) => anyhow::anyhow!("service stopped"),
            })
    }
}

/// The running service; dropping it stops the worker.
pub struct Service {
    handle: ServiceHandle,
    worker: Option<JoinHandle<ServiceStats>>,
}

impl Service {
    /// Start the batcher/worker thread. The engine and forest are owned
    /// by the worker for its lifetime.
    pub fn start(
        engine: Arc<Engine>,
        forest: EncodedForest,
        cfg: ServiceConfig,
    ) -> Result<Service> {
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        let worker = std::thread::Builder::new()
            .name("lmtuner-batcher".into())
            .spawn(move || worker_loop(engine, forest, cfg, rx))?;
        Ok(Service { handle: ServiceHandle { tx }, worker: Some(worker) })
    }

    pub fn handle(&self) -> ServiceHandle {
        self.handle.clone()
    }

    /// Stop and collect stats.
    pub fn shutdown(mut self) -> ServiceStats {
        let ServiceHandle { tx } = self.handle.clone();
        drop(tx);
        // Drop our handle so the channel closes once all clients are done.
        self.handle = ServiceHandle { tx: sync_channel(1).0 };
        self.worker
            .take()
            .map(|w| w.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

fn worker_loop(
    engine: Arc<Engine>,
    forest: EncodedForest,
    cfg: ServiceConfig,
    rx: Receiver<Pending>,
) -> ServiceStats {
    let exec = match ForestExecutor::new(&engine, &forest) {
        Ok(e) => e,
        Err(err) => {
            eprintln!("forest executor init failed: {err:#}");
            return ServiceStats::default();
        }
    };
    let max_batch = cfg.max_batch.min(exec.max_batch());
    let mut stats = ServiceStats::default();
    let mut batch: Vec<Pending> = Vec::with_capacity(max_batch);
    loop {
        batch.clear();
        // Block for the first request.
        match rx.recv() {
            Ok(p) => batch.push(p),
            Err(_) => break, // all senders gone
        }
        // Drain up to max_batch or until max_wait expires.
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => batch.push(p),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let rows: Vec<Vec<f64>> =
            batch.iter().map(|p| p.req.features.to_vec()).collect();
        match exec.predict(&rows) {
            Ok(preds) => {
                let bsize = batch.len();
                for (p, score) in batch.drain(..).zip(preds) {
                    let resp = PredictResponse {
                        id: p.req.id,
                        score,
                        use_local_memory: score > 0.0,
                        batch_size: bsize,
                        latency: p.enqueued.elapsed(),
                    };
                    let _ = p.reply.send(resp);
                    stats.served += 1;
                }
                stats.batches += 1;
            }
            Err(err) => {
                eprintln!("batch inference failed: {err:#}");
                stats.rejected += batch.len() as u64;
                batch.clear();
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::export::{encode, ExportContract};
    use crate::ml::forest::{Forest, ForestConfig};
    use crate::util::prng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn toy_encoded(engine: &Engine) -> EncodedForest {
        let nf = NUM_FEATURES;
        let mut rng = Rng::new(7);
        let x: Vec<Vec<f64>> = (0..nf)
            .map(|_| (0..300).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..300).map(|i| if x[0][i] > 0.0 { 1.0 } else { -1.0 }).collect();
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig { num_trees: 20, threads: 1, ..Default::default() },
        );
        encode(
            &f,
            ExportContract {
                num_trees: engine.manifest.num_trees,
                max_nodes: engine.manifest.max_nodes,
                max_depth: engine.manifest.max_depth,
                num_features: nf,
            },
        )
    }

    #[test]
    fn service_roundtrip_and_batching() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
        let enc = toy_encoded(&engine);
        let svc = Service::start(
            engine,
            enc.clone(),
            ServiceConfig {
                max_batch: 64,
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
        )
        .unwrap();
        let h = svc.handle();

        // Concurrent clients.
        let mut threads = Vec::new();
        for t in 0..4 {
            let h = h.clone();
            let enc = enc.clone();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(100 + t);
                for _ in 0..50 {
                    let mut feats = [0.0; NUM_FEATURES];
                    for f in feats.iter_mut() {
                        *f = rng.range_f64(-1.0, 1.0);
                    }
                    let resp = h.predict(feats).unwrap();
                    let want = enc.predict(&feats);
                    assert!((resp.score - want).abs() < 1e-4);
                    assert_eq!(resp.use_local_memory, want > 0.0);
                    assert!(resp.batch_size >= 1);
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        drop(h);
        let stats = svc.shutdown();
        assert_eq!(stats.served, 200);
        assert!(stats.batches <= 200);
    }
}
