//! Phase 1 of the paper's framework (Fig. 2, left side) as a pipeline:
//! generate synthetic kernels -> sweep launches -> measure on the
//! simulated testbed -> train the Random Forest -> evaluate both metrics
//! -> persist model + dataset.
//!
//! Two pipelines share the same deterministic record stream:
//!
//! * [`run`] — the in-memory pipeline: every record is materialized,
//!   split by random permutation, and evaluated in one pass. Right for
//!   toy/CI scales.
//! * [`run_sharded`] — the paper-scale pipeline: one streaming build
//!   pass shards the dataset to disk while reservoir-sampling the
//!   training split, the forest fits on the sample, and a second
//!   streaming pass over the shards evaluates the held-out instances
//!   through `metrics::AccuracyAccumulator`. Peak memory is bounded by
//!   (reservoir capacity + two build chunks) regardless of `scale`.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::features::NUM_FEATURES;
use crate::ml::forest::{Forest, ForestConfig, OobEstimate};
use crate::ml::metrics::{self, Accuracy, AccuracyAccumulator, JointAccumulator, JointAccuracy};
use crate::ml::{export, io};
use crate::obs::metrics::MetricsRegistry;
use crate::sim::exec::{MeasureConfig, Schema, SpeedupRecord, TuneRecord};
use crate::synth::binfmt::ShardFormat;
use crate::synth::dataset::BuildProgress;
use crate::synth::pipeline::{PipelineSpec, StageCounters, StagedSink};
use crate::synth::sink::{self, DatasetSummary, MemorySink, ReservoirSink, ShardedSink, Tee};
use crate::synth::{dataset, generator, sweep::LaunchSweep};
use crate::util::pool::parallel_map;
use crate::util::prng::Rng;
use crate::workloads;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Fraction of the paper's 100 context tuples (1.0 = paper scale).
    pub scale: f64,
    /// Launch configurations sampled per synthetic kernel.
    pub configs_per_kernel: usize,
    /// Fraction of instances used for training (paper: 0.10).
    pub train_fraction: f64,
    pub forest: ForestConfig,
    pub measure: MeasureConfig,
    pub seed: u64,
    /// Also compute the out-of-bag estimate during the fit (one extra
    /// traversal pass over the training split; off by default). The OOB
    /// pass grades the primary (verdict) output only, so joint (schema
    /// v2) runs skip it and report `oob: None`.
    pub compute_oob: bool,
    /// Dataset/label schema: v1 trains the paper's single-output verdict
    /// forest; v2 trains the joint verdict × workgroup-size forest and
    /// additionally reports [`TrainOutcome::joint`].
    pub schema: Schema,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            scale: 0.2,
            configs_per_kernel: 24,
            train_fraction: 0.10,
            forest: ForestConfig::default(),
            measure: MeasureConfig::default(),
            seed: 0x5EED,
            compute_oob: false,
            schema: Schema::V1,
        }
    }
}

/// Options for the sharded streaming pipeline on top of a base
/// [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct ShardedTrainConfig {
    pub base: TrainConfig,
    /// Directory receiving `shard-NNNNN.{csv,bin}` files.
    pub out_dir: PathBuf,
    /// Number of shards.
    pub shards: usize,
    /// Reservoir capacity for the training split. Plays the role of
    /// `train_fraction` when the stream length is unknown: the forest
    /// fits on a uniform sample of this size, everything else is test.
    pub train_capacity: usize,
    /// On-disk shard format. Defaults to CSV: the text format preserves
    /// f64 speedups exactly, while the binary format quantizes columns
    /// to f32 (fine for training, but callers opt in explicitly).
    pub format: ShardFormat,
    /// Per-record stages (validate / dedup) between the generator and
    /// the shards + reservoir. Records a stage drops are neither
    /// persisted nor eligible for the training sample.
    pub stages: PipelineSpec,
}

impl ShardedTrainConfig {
    pub fn new(base: TrainConfig, out_dir: PathBuf) -> Self {
        ShardedTrainConfig {
            base,
            out_dir,
            shards: 8,
            train_capacity: 50_000,
            format: ShardFormat::Csv,
            stages: PipelineSpec::default(),
        }
    }
}

/// Wall time and throughput of one pipeline phase. The pipelines report
/// generate / fit / grade separately — a single folded rows/sec figure
/// hides which phase regressed (and grading time used to go entirely
/// unreported in the sharded pipeline).
#[derive(Clone, Debug, PartialEq)]
pub struct PhaseStat {
    pub name: &'static str,
    pub seconds: f64,
    /// Work items this phase processed (records generated, rows fitted
    /// on, rows graded).
    pub items: u64,
}

impl PhaseStat {
    pub fn per_second(&self) -> f64 {
        if self.seconds > 0.0 {
            self.items as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// Export phase stats into a registry: gauge `train.<phase>_s`, counter
/// `train.<phase>_items`, gauge `train.<phase>_per_s` per phase.
fn export_phases(phases: &[PhaseStat], reg: &mut MetricsRegistry) {
    for p in phases {
        reg.set_gauge(&format!("train.{}_s", p.name), p.seconds);
        reg.add(&format!("train.{}_items", p.name), p.items);
        reg.set_gauge(&format!("train.{}_per_s", p.name), p.per_second());
    }
}

/// Export stage counters (validate / dedup) into a registry. Counters
/// add on re-export, so multi-device runs can fold every sink's stages
/// into one registry.
pub fn export_stages(stages: &[StageCounters], reg: &mut MetricsRegistry) {
    for s in stages {
        reg.add(&format!("stage.{}.seen", s.name), s.seen);
        reg.add(&format!("stage.{}.kept", s.name), s.kept);
        reg.add(&format!("stage.{}.dropped", s.name), s.dropped);
        reg.add(&format!("stage.{}.replaced", s.name), s.replaced);
        for (reason, n) in &s.rejects {
            reg.add(&format!("stage.{}.reject.{reason}", s.name), *n);
        }
    }
}

pub struct TrainOutcome {
    pub forest: Forest,
    /// Key of the simulated device the dataset was measured on; stamped
    /// into every dataset/shard this outcome persists.
    pub device: String,
    /// Schema the pipeline ran under (drives how `records` persist and
    /// whether `joint` is populated).
    pub schema: Schema,
    /// Materialized records (in-memory pipeline only; empty when the
    /// dataset streamed to disk shards).
    pub records: Vec<TuneRecord>,
    /// Stream statistics of the full dataset, accumulated during the
    /// build pass.
    pub summary: DatasetSummary,
    pub synth_accuracy: Accuracy,
    pub per_benchmark: Vec<(String, Accuracy)>,
    pub train_size: usize,
    pub gen_seconds: f64,
    pub fit_seconds: f64,
    /// Out-of-bag estimate of the fitted forest (only when
    /// `TrainConfig::compute_oob` is set and the schema is v1).
    pub oob: Option<OobEstimate>,
    /// Joint verdict × workgroup metrics over the held-out split
    /// (schema v2 runs only).
    pub joint: Option<JointAccuracy>,
    /// Per-stage seen/kept/dropped tallies when the sharded pipeline ran
    /// with validate/dedup stages (empty otherwise, and always empty for
    /// the in-memory pipeline).
    pub stage_counters: Vec<StageCounters>,
    /// Per-phase wall time + throughput, in pipeline order:
    /// generate, fit, grade.
    pub phases: Vec<PhaseStat>,
    /// The same phase/stage/summary telemetry as a mergeable registry —
    /// what `lmtuner train --metrics-out` writes.
    pub metrics: MetricsRegistry,
}

/// Fit the forest on a training split, with the optional OOB pass.
/// Propagates `FitError` typed: the simulator only emits finite
/// features and clamped-positive speedups (asserted by the crossdev
/// label-flip test), but an empty split (e.g. a zero-capacity
/// reservoir) is a legitimate runtime condition, not a panic.
fn fit_split<R: std::borrow::Borrow<TuneRecord>>(
    records: &[R],
    cfg: &ForestConfig,
    compute_oob: bool,
    schema: Schema,
) -> Result<(Forest, Option<OobEstimate>), crate::ml::forest::FitError> {
    match schema {
        // Joint fit: same tree structure (extras never influence splits),
        // no OOB pass (it grades the primary output only).
        Schema::V2 => Ok((Forest::fit_tune_records(records, cfg)?, None)),
        Schema::V1 => {
            let bases: Vec<&SpeedupRecord> =
                records.iter().map(|r| &r.borrow().base).collect();
            if compute_oob {
                let (f, oob) = Forest::fit_records_with_oob(&bases, cfg)?;
                Ok((f, Some(oob)))
            } else {
                Ok((Forest::fit_records(&bases, cfg)?, None))
            }
        }
    }
}

/// Grade the joint (verdict × workgroup) quality of a fitted joint
/// forest over held-out records.
fn joint_eval<'a, I: IntoIterator<Item = &'a TuneRecord>>(
    forest: &Forest,
    test: I,
) -> JointAccuracy {
    let mut acc = JointAccumulator::new();
    for r in test {
        let x = &r.base.features[..];
        let wg = forest.predict_wg_logs(x).unwrap_or((0.0, 0.0));
        acc.push(r.base.speedup, forest.decide(x), r.best_wg, wg);
    }
    acc.finish()
}

/// Dataset build options derived from a train config. The seed
/// derivation lives here only, so `lmtuner generate` and the train
/// pipelines produce the same record stream for the same `--seed`.
pub fn build_config(cfg: &TrainConfig) -> dataset::BuildConfig {
    dataset::BuildConfig {
        configs_per_kernel: cfg.configs_per_kernel,
        measure: cfg.measure,
        seed: cfg.seed ^ 0xDA7A,
        ..dataset::BuildConfig::default()
    }
}

/// Materialize exactly the record stream the in-memory train pipeline
/// fits on (same seed derivation via [`build_config`], same template
/// population and launch sweep). `lmtuner tune` cross-validates on
/// these records, so the selected config is graded against the same
/// distribution `train` will see.
pub fn build_records(dev: &DeviceSpec, cfg: &TrainConfig) -> Vec<TuneRecord> {
    let mut rng = Rng::new(cfg.seed);
    let templates = generator::generate(&mut rng, cfg.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    dataset::build(&templates, &sweep, dev, &build_config(cfg))
}

/// Run the full phase-1 pipeline in memory.
pub fn run(dev: &DeviceSpec, cfg: &TrainConfig) -> TrainOutcome {
    run_with_progress(dev, cfg, None)
}

/// In-memory pipeline with an optional per-chunk progress callback.
pub fn run_with_progress(
    dev: &DeviceSpec,
    cfg: &TrainConfig,
    progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> TrainOutcome {
    let t0 = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let templates = generator::generate(&mut rng, cfg.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let build = build_config(cfg);
    let mut mem = MemorySink::new();
    let summary = {
        let _span = crate::span!("train.generate");
        dataset::build_streaming(&templates, &sweep, dev, &build, &mut mem, progress)
            .expect("in-memory sink cannot fail")
    };
    let records = mem.records;
    let gen_seconds = t0.elapsed().as_secs_f64();

    let (train, test) = dataset::split(&records, cfg.train_fraction, cfg.seed);
    let train_size = train.len();
    let t1 = Instant::now();
    let (forest, oob) = {
        let _span = crate::span!("train.fit");
        fit_split(&train, &cfg.forest, cfg.compute_oob, cfg.schema)
            .expect("cannot fit on the generated dataset (empty or non-finite)")
    };
    let fit_seconds = t1.elapsed().as_secs_f64();

    let t2 = Instant::now();
    let _grade_span = crate::span!("train.grade");
    let test_bases: Vec<&SpeedupRecord> = test.iter().map(|r| &r.base).collect();
    let synth_accuracy = metrics::evaluate_model(&test_bases, |x| forest.decide(x));
    drop(test_bases);
    let joint = match cfg.schema {
        Schema::V1 => None,
        Schema::V2 => Some(joint_eval(&forest, test.iter().copied())),
    };
    let graded = test.len() as u64;
    drop(train);
    drop(test);
    let per_benchmark = evaluate_real(dev, &forest, &cfg.measure);
    drop(_grade_span);
    let grade_seconds = t2.elapsed().as_secs_f64();

    let phases = vec![
        PhaseStat { name: "generate", seconds: gen_seconds, items: summary.records },
        PhaseStat { name: "fit", seconds: fit_seconds, items: train_size as u64 },
        PhaseStat { name: "grade", seconds: grade_seconds, items: graded },
    ];
    let mut reg = MetricsRegistry::new();
    export_phases(&phases, &mut reg);
    reg.add("train.records", summary.records);
    reg.add("train.train_size", train_size as u64);

    TrainOutcome {
        forest,
        device: dev.key.to_string(),
        schema: cfg.schema,
        records,
        summary,
        synth_accuracy,
        per_benchmark,
        train_size,
        gen_seconds,
        fit_seconds,
        oob,
        joint,
        stage_counters: Vec::new(),
        phases,
        metrics: reg,
    }
}

/// Run the paper-scale streaming pipeline: shard the dataset to disk,
/// fit on a reservoir sample, evaluate the held-out rows in a second
/// streaming pass. Peak memory is bounded by the reservoir capacity
/// plus two build chunks (one consumed, one lookahead), regardless of scale.
pub fn run_sharded(
    dev: &DeviceSpec,
    cfg: &ShardedTrainConfig,
    progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> Result<TrainOutcome> {
    let base = &cfg.base;
    let t0 = Instant::now();
    let mut rng = Rng::new(base.seed);
    let templates = generator::generate(&mut rng, base.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let build = build_config(base);

    // Pass 1: simulate once, streaming every record through the
    // configured stages (validate / dedup, usually none) into the disk
    // shards while the reservoir uniformly samples the training split.
    // Every shard is stamped with the device it was measured on; records
    // a stage drops reach neither the shards nor the reservoir.
    let mut shards = ShardedSink::create(
        &cfg.out_dir,
        cfg.shards,
        dev.key,
        base.schema,
        cfg.format,
    )?;
    let mut reservoir =
        ReservoirSink::new(cfg.train_capacity, base.seed ^ 0x7EA1_5A3D);
    let (summary, stage_counters) = {
        let _span = crate::span!("train.generate");
        let tee = Tee(&mut shards, &mut reservoir);
        let mut staged = StagedSink::new(tee, cfg.stages.build(base.schema));
        let summary = dataset::build_streaming(
            &templates, &sweep, dev, &build, &mut staged, progress,
        )?;
        (summary, staged.counters())
    };
    let written = shards.written();
    let gen_seconds = t0.elapsed().as_secs_f64();

    let (train_records, train_indices) = reservoir.into_sample();
    let train_size = train_records.len();
    let t1 = Instant::now();
    let (forest, oob) = {
        let _span = crate::span!("train.fit");
        fit_split(&train_records, &base.forest, base.compute_oob, base.schema)?
    };
    let fit_seconds = t1.elapsed().as_secs_f64();
    drop(train_records);

    // Pass 2: stream the shards back and grade every held-out row.
    // Rows are graded in parallel batches — a serial decide() here
    // would cap the whole pipeline at single-thread speed at paper
    // scale, after the build pass was parallelized. This pass is timed
    // as its own "grade" phase: folding it into the generate figure (or
    // not reporting it at all, as before) hides a slow eval pass behind
    // a healthy-looking build throughput.
    let t2 = Instant::now();
    let grade_span = crate::span!("train.grade");
    const EVAL_BATCH: usize = 8192;
    let train_set: HashSet<u64> = train_indices.into_iter().collect();
    let mut acc = AccuracyAccumulator::new();
    let mut joint_acc = match base.schema {
        Schema::V1 => None,
        Schema::V2 => Some(JointAccumulator::new()),
    };
    let mut batch: Vec<Vec<f64>> = Vec::with_capacity(EVAL_BATCH);
    let threads = build.threads;
    let replay = sink::stream_sharded_rows(&cfg.out_dir, |idx, schema, row| {
        anyhow::ensure!(
            schema == base.schema,
            "{}: shards replay schema {schema} but this run is {} — \
             stale files in the output directory?",
            cfg.out_dir.display(),
            base.schema
        );
        if !train_set.contains(&idx) {
            batch.push(row);
            if batch.len() == EVAL_BATCH {
                grade_rows(&mut acc, &mut joint_acc, &forest, &batch, threads);
                batch.clear();
            }
        }
        Ok(())
    })?;
    grade_rows(&mut acc, &mut joint_acc, &forest, &batch, threads);
    // Compare against what the shards actually accepted, not the raw
    // generated count: validate/dedup stages legitimately drop records
    // before they reach disk.
    anyhow::ensure!(
        replay.rows == written,
        "{}: shards replay {} records but the sink accepted {} — \
         stale files in the output directory?",
        cfg.out_dir.display(),
        replay.rows,
        written
    );
    // The shards we just wrote must replay as the device we simulated;
    // anything else means foreign files crept into the directory.
    sink::ensure_same_device(
        dev.key,
        replay.device.as_deref().unwrap_or("<unstamped>"),
        cfg.out_dir.display().to_string(),
    )?;
    anyhow::ensure!(
        acc.n() > 0,
        "training reservoir (capacity {}) swallowed the entire \
         {}-record stream, leaving nothing to evaluate; lower \
         train_capacity below the stream size or raise scale",
        cfg.train_capacity,
        written
    );

    let per_benchmark = evaluate_real(dev, &forest, &base.measure);
    drop(grade_span);
    let grade_seconds = t2.elapsed().as_secs_f64();

    let phases = vec![
        PhaseStat { name: "generate", seconds: gen_seconds, items: summary.records },
        PhaseStat { name: "fit", seconds: fit_seconds, items: train_size as u64 },
        PhaseStat { name: "grade", seconds: grade_seconds, items: acc.n() as u64 },
    ];
    let mut reg = MetricsRegistry::new();
    export_phases(&phases, &mut reg);
    export_stages(&stage_counters, &mut reg);
    reg.add("train.records", summary.records);
    reg.add("train.train_size", train_size as u64);
    reg.add("train.shard_rows", written);

    Ok(TrainOutcome {
        forest,
        device: dev.key.to_string(),
        schema: base.schema,
        records: Vec::new(),
        summary,
        synth_accuracy: acc.finish(),
        per_benchmark,
        train_size,
        gen_seconds,
        fit_seconds,
        oob,
        joint: joint_acc.map(|j| j.finish()),
        stage_counters,
        phases,
        metrics: reg,
    })
}

/// Grade one batch of raw dataset rows against the forest, fanning the
/// traversals across the thread pool. Row layout is the CSV column
/// order: features, speedup, then (schema v2, iff `joint` is live) the
/// measured-best workgroup label with its (0, 0) = unlabeled sentinel.
fn grade_rows(
    acc: &mut AccuracyAccumulator,
    joint: &mut Option<JointAccumulator>,
    forest: &Forest,
    rows: &[Vec<f64>],
    threads: usize,
) {
    let preds = parallel_map(rows, threads, |row| {
        let x = &row[..NUM_FEATURES];
        (forest.decide(x), forest.predict_wg_logs(x))
    });
    for (row, (d, wg)) in rows.iter().zip(preds) {
        acc.push(row[NUM_FEATURES], d);
        if let Some(j) = joint.as_mut() {
            let label = match (row.get(NUM_FEATURES + 1), row.get(NUM_FEATURES + 2)) {
                (Some(&w), Some(&h)) if w >= 1.0 && h >= 1.0 => {
                    Some((w as u32, h as u32))
                }
                _ => None,
            };
            j.push(row[NUM_FEATURES], d, label, wg.unwrap_or((0.0, 0.0)));
        }
    }
}

/// Evaluate a model on all eight real benchmarks (paper Fig. 6 right).
pub fn evaluate_real(
    dev: &DeviceSpec,
    forest: &Forest,
    measure: &MeasureConfig,
) -> Vec<(String, Accuracy)> {
    workloads::all()
        .into_iter()
        .map(|b| {
            let mut acc = AccuracyAccumulator::new();
            for d in (b.instances)(dev).iter() {
                let r = crate::sim::exec::measure(d, dev, measure);
                acc.push_record(&r, forest.decide(&r.features));
            }
            (b.name.to_string(), acc.finish())
        })
        .collect()
}

/// Persist everything the serving side needs. Datasets are stamped with
/// the device they were measured on.
pub fn save_outcome(out: &TrainOutcome, model_path: &Path, data_path: Option<&Path>) -> Result<()> {
    io::save(&out.forest, model_path)?;
    if let Some(p) = data_path {
        dataset::save_schema(&out.records, p, &out.device, out.schema)?;
    }
    Ok(())
}

/// Encode the trained forest under the artifact contract.
pub fn encode_for_serving(
    forest: &Forest,
    manifest: &crate::runtime::pjrt::Manifest,
) -> export::EncodedForest {
    export::encode(
        forest,
        export::ExportContract {
            num_trees: manifest.num_trees,
            max_nodes: manifest.max_nodes,
            max_depth: manifest.max_depth,
            num_features: manifest.num_features,
        },
    )
}

/// Encode under an artifact-independent contract sized to the forest,
/// for the native batched executor (no PJRT manifest required). The
/// default budget is grown to fit, so nothing is truncated.
pub fn encode_default(forest: &Forest) -> export::EncodedForest {
    let mut contract = export::ExportContract::default();
    contract.num_trees = contract.num_trees.max(forest.trees.len());
    contract.max_nodes = contract.max_nodes.max(forest.max_nodes());
    contract.max_depth = contract.max_depth.max(forest.max_depth());
    export::encode(forest, contract)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_pipeline_end_to_end() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.03, // 3 tuples
            configs_per_kernel: 6,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        assert_eq!(out.device, "m2090");
        assert!(out.records.len() > 1000, "{}", out.records.len());
        assert_eq!(out.summary.records as usize, out.records.len());
        assert!(out.synth_accuracy.count_based > 0.6,
            "count {}", out.synth_accuracy.count_based);
        assert!(out.synth_accuracy.penalty_weighted > 0.8);
        assert_eq!(out.per_benchmark.len(), 8);
        // the in-memory pipeline reports split phase timings too
        assert_eq!(
            out.phases.iter().map(|p| p.name).collect::<Vec<_>>(),
            vec!["generate", "fit", "grade"]
        );
        assert_eq!(out.phases[0].items, out.summary.records);
        assert!(out.metrics.gauge("train.generate_s").is_some());
    }

    #[test]
    fn oob_estimate_is_wired_through() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            compute_oob: true,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        let oob = out.oob.expect("oob requested via compute_oob");
        assert_eq!(oob.total, out.train_size);
        assert!(oob.covered > 0, "no OOB coverage");
        assert!(oob.mse.is_finite());
        assert!(oob.decision_accuracy > 0.5, "{}", oob.decision_accuracy);
    }

    #[test]
    fn saved_model_reloads() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        let dir = std::env::temp_dir();
        let mp = dir.join(format!("lmtuner-model-{}.txt", std::process::id()));
        save_outcome(&out, &mp, None).unwrap();
        let back = crate::ml::io::load(&mp).unwrap();
        let probe = out.records[0].base.features;
        assert!((back.predict(&probe) - out.forest.predict(&probe)).abs() < 1e-12);
        std::fs::remove_file(&mp).ok();
    }

    #[test]
    fn sharded_pipeline_end_to_end() {
        let dev = DeviceSpec::m2090();
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-shards-{}", std::process::id()));
        let cfg = ShardedTrainConfig {
            shards: 3,
            train_capacity: 400,
            ..ShardedTrainConfig::new(
                TrainConfig {
                    scale: 0.03,
                    configs_per_kernel: 6,
                    ..Default::default()
                },
                dir.clone(),
            )
        };
        let out = run_sharded(&dev, &cfg, None).unwrap();
        // dataset streamed to disk, not memory
        assert!(out.records.is_empty());
        assert_eq!(out.device, "m2090");
        assert!(out.summary.records > 1000);
        assert_eq!(out.train_size, 400);
        // every non-train row was graded
        assert_eq!(
            out.synth_accuracy.n as u64 + out.train_size as u64,
            out.summary.records
        );
        assert!(out.synth_accuracy.count_based > 0.6,
            "count {}", out.synth_accuracy.count_based);
        assert_eq!(out.per_benchmark.len(), 8);
        // the shards reload to exactly the stream the summary counted
        let back = sink::load_sharded(&dir).unwrap();
        assert_eq!(back.len() as u64, out.summary.records);
        // Regression (phase-timing split): generate, fit, and grade
        // report their own elapsed/throughput — grading is no longer
        // invisible behind the build figure.
        assert_eq!(
            out.phases.iter().map(|p| p.name).collect::<Vec<_>>(),
            vec!["generate", "fit", "grade"]
        );
        assert_eq!(out.phases[0].items, out.summary.records);
        assert_eq!(out.phases[1].items, out.train_size as u64);
        assert_eq!(out.phases[2].items, out.synth_accuracy.n as u64);
        assert!(out.phases.iter().all(|p| p.seconds > 0.0), "{:?}", out.phases);
        assert_eq!(out.phases[0].seconds, out.gen_seconds);
        assert!(out.phases[2].per_second() > 0.0);
        // the registry carries the same figures for --metrics-out
        assert_eq!(out.metrics.counter("train.records"), out.summary.records);
        assert_eq!(out.metrics.counter("train.grade_items"), out.synth_accuracy.n as u64);
        assert!(out.metrics.gauge("train.grade_s").unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_matches_in_memory_dataset() {
        // Same seed: the sharded pipeline writes exactly the records
        // the in-memory pipeline materializes.
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            ..Default::default()
        };
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-eq-{}", std::process::id()));
        let mem = run(&dev, &cfg);
        let sharded = run_sharded(
            &dev,
            &ShardedTrainConfig {
                shards: 2,
                train_capacity: 100,
                ..ShardedTrainConfig::new(cfg, dir.clone())
            },
            None,
        )
        .unwrap();
        assert_eq!(sharded.summary.records as usize, mem.records.len());
        let back = sink::load_sharded(&dir).unwrap();
        for (a, b) in back.iter().zip(&mem.records) {
            assert_eq!(a.base.features, b.base.features);
            assert!((a.base.speedup - b.base.speedup).abs() < 1e-9);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_pipeline_runs_on_binary_shards_with_stages() {
        let dev = DeviceSpec::m2090();
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-bin-{}", std::process::id()));
        let cfg = ShardedTrainConfig {
            shards: 3,
            train_capacity: 200,
            format: ShardFormat::Bin,
            stages: PipelineSpec { validate: true, dedup: true },
            ..ShardedTrainConfig::new(
                TrainConfig {
                    scale: 0.02,
                    configs_per_kernel: 4,
                    ..Default::default()
                },
                dir.clone(),
            )
        };
        let out = run_sharded(&dev, &cfg, None).unwrap();
        assert!(out.records.is_empty());
        assert_eq!(out.train_size, 200);
        // stage counters came back in pipeline order and agree with the
        // persisted stream: kept records = shards on disk.
        assert_eq!(out.stage_counters.len(), 2);
        assert_eq!(out.stage_counters[0].name, "validate");
        assert_eq!(out.stage_counters[1].name, "dedup");
        assert_eq!(out.stage_counters[0].seen, out.summary.records);
        let kept = out.stage_counters[1].seen - out.stage_counters[1].dropped;
        let stream = sink::stream_sharded_rows(&dir, |_, _, _| Ok(())).unwrap();
        assert_eq!(stream.format, ShardFormat::Bin);
        assert_eq!(stream.rows, kept);
        // every kept row was either sampled for training or graded
        assert_eq!(out.synth_accuracy.n as u64 + out.train_size as u64, kept);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn joint_pipeline_reports_the_joint_metric() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.03,
            configs_per_kernel: 6,
            schema: Schema::V2,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        assert_eq!(out.schema, Schema::V2);
        assert_eq!(out.forest.num_outputs(), 3);
        let j = out.joint.expect("schema v2 must report the joint metric");
        assert!(j.n > 0);
        assert!(j.wg_hit_rate > 0.0, "wg hit rate {}", j.wg_hit_rate);
        assert!(j.joint <= j.wg_hit_rate);
        assert!(j.joint <= j.verdict.count_based + 1e-12);
        // the verdict component grades the same rows the plain metric does
        assert_eq!(j.verdict.n, out.synth_accuracy.n);
        // v2-saved dataset round-trips with its labels
        let dir = std::env::temp_dir();
        let dp = dir.join(format!("lmtuner-train-v2-{}.csv", std::process::id()));
        save_outcome(&out, &dir.join("lmtuner-train-v2-m.txt"), Some(&dp)).unwrap();
        let (back, tag) = dataset::load_tagged(&dp).unwrap();
        assert_eq!(tag.schema, Schema::V2);
        assert_eq!(back[0].best_wg, out.records[0].best_wg);
        assert!(back[0].best_wg.is_some());
        std::fs::remove_file(&dp).ok();
        std::fs::remove_file(dir.join("lmtuner-train-v2-m.txt")).ok();
    }

    #[test]
    fn joint_sharded_pipeline_matches_in_memory_records() {
        let dev = DeviceSpec::m2090();
        let base = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            schema: Schema::V2,
            ..Default::default()
        };
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-v2eq-{}", std::process::id()));
        let mem = run(&dev, &base);
        let sharded = run_sharded(
            &dev,
            &ShardedTrainConfig {
                shards: 2,
                train_capacity: 100,
                ..ShardedTrainConfig::new(base, dir.clone())
            },
            None,
        )
        .unwrap();
        let j = sharded.joint.expect("sharded v2 reports joint");
        assert!(j.n > 0);
        assert_eq!(
            j.n as u64 + j.skipped as u64 + sharded.train_size as u64,
            sharded.summary.records
        );
        // shards carry the same joint labels the in-memory run produced
        let back = sink::load_sharded(&dir).unwrap();
        assert_eq!(back.len(), mem.records.len());
        for (a, b) in back.iter().zip(&mem.records) {
            assert_eq!(a.best_wg, b.best_wg);
            assert!(a.best_wg.is_some());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
