//! Phase 1 of the paper's framework (Fig. 2, left side) as a pipeline:
//! generate synthetic kernels -> sweep launches -> measure on the
//! simulated testbed -> train the Random Forest -> evaluate both metrics
//! -> persist model + dataset.
//!
//! Two pipelines share the same deterministic record stream:
//!
//! * [`run`] — the in-memory pipeline: every record is materialized,
//!   split by random permutation, and evaluated in one pass. Right for
//!   toy/CI scales.
//! * [`run_sharded`] — the paper-scale pipeline: one streaming build
//!   pass shards the dataset to disk while reservoir-sampling the
//!   training split, the forest fits on the sample, and a second
//!   streaming pass over the shards evaluates the held-out instances
//!   through `metrics::AccuracyAccumulator`. Peak memory is bounded by
//!   (reservoir capacity + two build chunks) regardless of `scale`.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::features::NUM_FEATURES;
use crate::ml::forest::{Forest, ForestConfig, OobEstimate};
use crate::ml::metrics::{self, Accuracy, AccuracyAccumulator};
use crate::ml::{export, io};
use crate::sim::exec::{MeasureConfig, SpeedupRecord};
use crate::synth::dataset::BuildProgress;
use crate::util::pool::parallel_map;
use crate::synth::sink::{
    self, DatasetSummary, MemorySink, ReservoirSink, ShardedCsvSink, Tee,
};
use crate::synth::{dataset, generator, sweep::LaunchSweep};
use crate::util::prng::Rng;
use crate::workloads;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Fraction of the paper's 100 context tuples (1.0 = paper scale).
    pub scale: f64,
    /// Launch configurations sampled per synthetic kernel.
    pub configs_per_kernel: usize,
    /// Fraction of instances used for training (paper: 0.10).
    pub train_fraction: f64,
    pub forest: ForestConfig,
    pub measure: MeasureConfig,
    pub seed: u64,
    /// Also compute the out-of-bag estimate during the fit (one extra
    /// traversal pass over the training split; off by default).
    pub compute_oob: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            scale: 0.2,
            configs_per_kernel: 24,
            train_fraction: 0.10,
            forest: ForestConfig::default(),
            measure: MeasureConfig::default(),
            seed: 0x5EED,
            compute_oob: false,
        }
    }
}

/// Options for the sharded streaming pipeline on top of a base
/// [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct ShardedTrainConfig {
    pub base: TrainConfig,
    /// Directory receiving `shard-NNN.csv` files.
    pub out_dir: PathBuf,
    /// Number of CSV shards.
    pub shards: usize,
    /// Reservoir capacity for the training split. Plays the role of
    /// `train_fraction` when the stream length is unknown: the forest
    /// fits on a uniform sample of this size, everything else is test.
    pub train_capacity: usize,
}

impl ShardedTrainConfig {
    pub fn new(base: TrainConfig, out_dir: PathBuf) -> Self {
        ShardedTrainConfig {
            base,
            out_dir,
            shards: 8,
            train_capacity: 50_000,
        }
    }
}

pub struct TrainOutcome {
    pub forest: Forest,
    /// Key of the simulated device the dataset was measured on; stamped
    /// into every dataset/shard this outcome persists.
    pub device: String,
    /// Materialized records (in-memory pipeline only; empty when the
    /// dataset streamed to disk shards).
    pub records: Vec<SpeedupRecord>,
    /// Stream statistics of the full dataset, accumulated during the
    /// build pass.
    pub summary: DatasetSummary,
    pub synth_accuracy: Accuracy,
    pub per_benchmark: Vec<(String, Accuracy)>,
    pub train_size: usize,
    pub gen_seconds: f64,
    pub fit_seconds: f64,
    /// Out-of-bag estimate of the fitted forest (only when
    /// `TrainConfig::compute_oob` is set).
    pub oob: Option<OobEstimate>,
}

/// Fit the forest on a training split, with the optional OOB pass.
/// Propagates `FitError` typed: the simulator only emits finite
/// features and clamped-positive speedups (asserted by the crossdev
/// label-flip test), but an empty split (e.g. a zero-capacity
/// reservoir) is a legitimate runtime condition, not a panic.
fn fit_split<R: std::borrow::Borrow<SpeedupRecord>>(
    records: &[R],
    cfg: &ForestConfig,
    compute_oob: bool,
) -> Result<(Forest, Option<OobEstimate>), crate::ml::forest::FitError> {
    if compute_oob {
        let (f, oob) = Forest::fit_records_with_oob(records, cfg)?;
        Ok((f, Some(oob)))
    } else {
        Ok((Forest::fit_records(records, cfg)?, None))
    }
}

/// Dataset build options derived from a train config. The seed
/// derivation lives here only, so `lmtuner generate` and the train
/// pipelines produce the same record stream for the same `--seed`.
pub fn build_config(cfg: &TrainConfig) -> dataset::BuildConfig {
    dataset::BuildConfig {
        configs_per_kernel: cfg.configs_per_kernel,
        measure: cfg.measure,
        seed: cfg.seed ^ 0xDA7A,
        ..dataset::BuildConfig::default()
    }
}

/// Materialize exactly the record stream the in-memory train pipeline
/// fits on (same seed derivation via [`build_config`], same template
/// population and launch sweep). `lmtuner tune` cross-validates on
/// these records, so the selected config is graded against the same
/// distribution `train` will see.
pub fn build_records(dev: &DeviceSpec, cfg: &TrainConfig) -> Vec<SpeedupRecord> {
    let mut rng = Rng::new(cfg.seed);
    let templates = generator::generate(&mut rng, cfg.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    dataset::build(&templates, &sweep, dev, &build_config(cfg))
}

/// Run the full phase-1 pipeline in memory.
pub fn run(dev: &DeviceSpec, cfg: &TrainConfig) -> TrainOutcome {
    run_with_progress(dev, cfg, None)
}

/// In-memory pipeline with an optional per-chunk progress callback.
pub fn run_with_progress(
    dev: &DeviceSpec,
    cfg: &TrainConfig,
    progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> TrainOutcome {
    let t0 = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let templates = generator::generate(&mut rng, cfg.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let build = build_config(cfg);
    let mut mem = MemorySink::new();
    let summary =
        dataset::build_streaming(&templates, &sweep, dev, &build, &mut mem, progress)
            .expect("in-memory sink cannot fail");
    let records = mem.records;
    let gen_seconds = t0.elapsed().as_secs_f64();

    let (train, test) = dataset::split(&records, cfg.train_fraction, cfg.seed);
    let train_size = train.len();
    let t1 = Instant::now();
    let (forest, oob) = fit_split(&train, &cfg.forest, cfg.compute_oob)
        .expect("cannot fit on the generated dataset (empty or non-finite)");
    let fit_seconds = t1.elapsed().as_secs_f64();

    let synth_accuracy = metrics::evaluate_model(&test, |x| forest.decide(x));
    drop(train);
    drop(test);
    let per_benchmark = evaluate_real(dev, &forest, &cfg.measure);

    TrainOutcome {
        forest,
        device: dev.key.to_string(),
        records,
        summary,
        synth_accuracy,
        per_benchmark,
        train_size,
        gen_seconds,
        fit_seconds,
        oob,
    }
}

/// Run the paper-scale streaming pipeline: shard the dataset to disk,
/// fit on a reservoir sample, evaluate the held-out rows in a second
/// streaming pass. Peak memory is bounded by the reservoir capacity
/// plus two build chunks (one consumed, one lookahead), regardless of scale.
pub fn run_sharded(
    dev: &DeviceSpec,
    cfg: &ShardedTrainConfig,
    progress: Option<&mut dyn FnMut(&BuildProgress)>,
) -> Result<TrainOutcome> {
    let base = &cfg.base;
    let t0 = Instant::now();
    let mut rng = Rng::new(base.seed);
    let templates = generator::generate(&mut rng, base.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let build = build_config(base);

    // Pass 1: simulate once, streaming every record to the CSV shards
    // while the reservoir uniformly samples the training split. Every
    // shard is stamped with the device it was measured on.
    let mut shards = ShardedCsvSink::create(&cfg.out_dir, cfg.shards, dev.key)?;
    let mut reservoir =
        ReservoirSink::new(cfg.train_capacity, base.seed ^ 0x7EA1_5A3D);
    let mut tee = Tee(&mut shards, &mut reservoir);
    let summary =
        dataset::build_streaming(&templates, &sweep, dev, &build, &mut tee, progress)?;
    let gen_seconds = t0.elapsed().as_secs_f64();

    let (train_records, train_indices) = reservoir.into_sample();
    let train_size = train_records.len();
    let t1 = Instant::now();
    let (forest, oob) = fit_split(&train_records, &base.forest, base.compute_oob)?;
    let fit_seconds = t1.elapsed().as_secs_f64();
    drop(train_records);

    // Pass 2: stream the shards back and grade every held-out row.
    // Rows are graded in parallel batches — a serial decide() here
    // would cap the whole pipeline at single-thread speed at paper
    // scale, after the build pass was parallelized.
    const EVAL_BATCH: usize = 8192;
    let train_set: HashSet<u64> = train_indices.into_iter().collect();
    let mut acc = AccuracyAccumulator::new();
    let mut batch: Vec<Vec<f64>> = Vec::with_capacity(EVAL_BATCH);
    let threads = build.threads;
    let replay = sink::stream_sharded_rows(&cfg.out_dir, |idx, row| {
        if !train_set.contains(&idx) {
            batch.push(row);
            if batch.len() == EVAL_BATCH {
                grade_rows(&mut acc, &forest, &batch, threads);
                batch.clear();
            }
        }
        Ok(())
    })?;
    grade_rows(&mut acc, &forest, &batch, threads);
    anyhow::ensure!(
        replay.rows == summary.records,
        "{}: shards replay {} records but the build streamed {} — \
         stale files in the output directory?",
        cfg.out_dir.display(),
        replay.rows,
        summary.records
    );
    // The shards we just wrote must replay as the device we simulated;
    // anything else means foreign files crept into the directory.
    sink::ensure_same_device(
        dev.key,
        replay.device.as_deref().unwrap_or("<unstamped>"),
        cfg.out_dir.display().to_string(),
    )?;
    anyhow::ensure!(
        acc.n() > 0,
        "training reservoir (capacity {}) swallowed the entire \
         {}-record stream, leaving nothing to evaluate; lower \
         train_capacity below the stream size or raise scale",
        cfg.train_capacity,
        summary.records
    );

    let per_benchmark = evaluate_real(dev, &forest, &base.measure);
    Ok(TrainOutcome {
        forest,
        device: dev.key.to_string(),
        records: Vec::new(),
        summary,
        synth_accuracy: acc.finish(),
        per_benchmark,
        train_size,
        gen_seconds,
        fit_seconds,
        oob,
    })
}

/// Grade one batch of raw dataset rows (features + speedup) against
/// the forest, fanning `decide` across the thread pool.
fn grade_rows(
    acc: &mut AccuracyAccumulator,
    forest: &Forest,
    rows: &[Vec<f64>],
    threads: usize,
) {
    let decisions =
        parallel_map(rows, threads, |row| forest.decide(&row[..NUM_FEATURES]));
    for (row, d) in rows.iter().zip(decisions) {
        acc.push(row[NUM_FEATURES], d);
    }
}

/// Evaluate a model on all eight real benchmarks (paper Fig. 6 right).
pub fn evaluate_real(
    dev: &DeviceSpec,
    forest: &Forest,
    measure: &MeasureConfig,
) -> Vec<(String, Accuracy)> {
    workloads::all()
        .into_iter()
        .map(|b| {
            let mut acc = AccuracyAccumulator::new();
            for d in (b.instances)(dev).iter() {
                let r = crate::sim::exec::measure(d, dev, measure);
                acc.push_record(&r, forest.decide(&r.features));
            }
            (b.name.to_string(), acc.finish())
        })
        .collect()
}

/// Persist everything the serving side needs. Datasets are stamped with
/// the device they were measured on.
pub fn save_outcome(out: &TrainOutcome, model_path: &Path, data_path: Option<&Path>) -> Result<()> {
    io::save(&out.forest, model_path)?;
    if let Some(p) = data_path {
        dataset::save(&out.records, p, &out.device)?;
    }
    Ok(())
}

/// Encode the trained forest under the artifact contract.
pub fn encode_for_serving(
    forest: &Forest,
    manifest: &crate::runtime::pjrt::Manifest,
) -> export::EncodedForest {
    export::encode(
        forest,
        export::ExportContract {
            num_trees: manifest.num_trees,
            max_nodes: manifest.max_nodes,
            max_depth: manifest.max_depth,
            num_features: manifest.num_features,
        },
    )
}

/// Encode under an artifact-independent contract sized to the forest,
/// for the native batched executor (no PJRT manifest required). The
/// default budget is grown to fit, so nothing is truncated.
pub fn encode_default(forest: &Forest) -> export::EncodedForest {
    let mut contract = export::ExportContract::default();
    contract.num_trees = contract.num_trees.max(forest.trees.len());
    contract.max_nodes = contract.max_nodes.max(forest.max_nodes());
    contract.max_depth = contract.max_depth.max(forest.max_depth());
    export::encode(forest, contract)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_pipeline_end_to_end() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.03, // 3 tuples
            configs_per_kernel: 6,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        assert_eq!(out.device, "m2090");
        assert!(out.records.len() > 1000, "{}", out.records.len());
        assert_eq!(out.summary.records as usize, out.records.len());
        assert!(out.synth_accuracy.count_based > 0.6,
            "count {}", out.synth_accuracy.count_based);
        assert!(out.synth_accuracy.penalty_weighted > 0.8);
        assert_eq!(out.per_benchmark.len(), 8);
    }

    #[test]
    fn oob_estimate_is_wired_through() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            compute_oob: true,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        let oob = out.oob.expect("oob requested via compute_oob");
        assert_eq!(oob.total, out.train_size);
        assert!(oob.covered > 0, "no OOB coverage");
        assert!(oob.mse.is_finite());
        assert!(oob.decision_accuracy > 0.5, "{}", oob.decision_accuracy);
    }

    #[test]
    fn saved_model_reloads() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        let dir = std::env::temp_dir();
        let mp = dir.join(format!("lmtuner-model-{}.txt", std::process::id()));
        save_outcome(&out, &mp, None).unwrap();
        let back = crate::ml::io::load(&mp).unwrap();
        let probe = out.records[0].features;
        assert!((back.predict(&probe) - out.forest.predict(&probe)).abs() < 1e-12);
        std::fs::remove_file(&mp).ok();
    }

    #[test]
    fn sharded_pipeline_end_to_end() {
        let dev = DeviceSpec::m2090();
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-shards-{}", std::process::id()));
        let cfg = ShardedTrainConfig {
            shards: 3,
            train_capacity: 400,
            ..ShardedTrainConfig::new(
                TrainConfig {
                    scale: 0.03,
                    configs_per_kernel: 6,
                    ..Default::default()
                },
                dir.clone(),
            )
        };
        let out = run_sharded(&dev, &cfg, None).unwrap();
        // dataset streamed to disk, not memory
        assert!(out.records.is_empty());
        assert_eq!(out.device, "m2090");
        assert!(out.summary.records > 1000);
        assert_eq!(out.train_size, 400);
        // every non-train row was graded
        assert_eq!(
            out.synth_accuracy.n as u64 + out.train_size as u64,
            out.summary.records
        );
        assert!(out.synth_accuracy.count_based > 0.6,
            "count {}", out.synth_accuracy.count_based);
        assert_eq!(out.per_benchmark.len(), 8);
        // the shards reload to exactly the stream the summary counted
        let back = sink::load_sharded(&dir).unwrap();
        assert_eq!(back.len() as u64, out.summary.records);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_matches_in_memory_dataset() {
        // Same seed: the sharded pipeline writes exactly the records
        // the in-memory pipeline materializes.
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            ..Default::default()
        };
        let dir = std::env::temp_dir()
            .join(format!("lmtuner-train-eq-{}", std::process::id()));
        let mem = run(&dev, &cfg);
        let sharded = run_sharded(
            &dev,
            &ShardedTrainConfig {
                shards: 2,
                train_capacity: 100,
                ..ShardedTrainConfig::new(cfg, dir.clone())
            },
            None,
        )
        .unwrap();
        assert_eq!(sharded.summary.records as usize, mem.records.len());
        let back = sink::load_sharded(&dir).unwrap();
        for (a, b) in back.iter().zip(&mem.records) {
            assert_eq!(a.features, b.features);
            assert!((a.speedup - b.speedup).abs() < 1e-9);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
