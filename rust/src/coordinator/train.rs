//! Phase 1 of the paper's framework (Fig. 2, left side) as a pipeline:
//! generate synthetic kernels -> sweep launches -> measure on the
//! simulated testbed -> train the Random Forest -> evaluate both metrics
//! -> persist model + dataset.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::gpu::spec::DeviceSpec;
use crate::ml::forest::{Forest, ForestConfig};
use crate::ml::metrics::{self, Accuracy};
use crate::ml::{export, io};
use crate::sim::exec::{MeasureConfig, SpeedupRecord};
use crate::synth::{dataset, generator, sweep::LaunchSweep};
use crate::util::prng::Rng;
use crate::workloads;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Fraction of the paper's 100 context tuples (1.0 = paper scale).
    pub scale: f64,
    /// Launch configurations sampled per synthetic kernel.
    pub configs_per_kernel: usize,
    /// Fraction of instances used for training (paper: 0.10).
    pub train_fraction: f64,
    pub forest: ForestConfig,
    pub measure: MeasureConfig,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            scale: 0.2,
            configs_per_kernel: 24,
            train_fraction: 0.10,
            forest: ForestConfig::default(),
            measure: MeasureConfig::default(),
            seed: 0x5EED,
        }
    }
}

pub struct TrainOutcome {
    pub forest: Forest,
    pub records: Vec<SpeedupRecord>,
    pub synth_accuracy: Accuracy,
    pub per_benchmark: Vec<(String, Accuracy)>,
    pub train_size: usize,
    pub gen_seconds: f64,
    pub fit_seconds: f64,
}

/// Run the full phase-1 pipeline.
pub fn run(dev: &DeviceSpec, cfg: &TrainConfig) -> TrainOutcome {
    let t0 = Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let templates = generator::generate(&mut rng, cfg.scale);
    let sweep = LaunchSweep::new(2048, 2048);
    let build = dataset::BuildConfig {
        configs_per_kernel: cfg.configs_per_kernel,
        measure: cfg.measure,
        seed: cfg.seed ^ 0xDA7A,
        ..dataset::BuildConfig::default()
    };
    let records = dataset::build(&templates, &sweep, dev, &build);
    let gen_seconds = t0.elapsed().as_secs_f64();

    let (train, test) = dataset::split(&records, cfg.train_fraction, cfg.seed);
    let train_size = train.len();
    let t1 = Instant::now();
    let forest = Forest::fit_records(&train, &cfg.forest);
    let fit_seconds = t1.elapsed().as_secs_f64();

    let synth_accuracy = metrics::evaluate_model(&test, |x| forest.decide(x));
    drop(train);
    drop(test);
    let per_benchmark = evaluate_real(dev, &forest, &cfg.measure);

    TrainOutcome {
        forest,
        records,
        synth_accuracy,
        per_benchmark,
        train_size,
        gen_seconds,
        fit_seconds,
    }
}

/// Evaluate a model on all eight real benchmarks (paper Fig. 6 right).
pub fn evaluate_real(
    dev: &DeviceSpec,
    forest: &Forest,
    measure: &MeasureConfig,
) -> Vec<(String, Accuracy)> {
    workloads::all()
        .into_iter()
        .map(|b| {
            let recs: Vec<SpeedupRecord> = (b.instances)(dev)
                .iter()
                .map(|d| crate::sim::exec::measure(d, dev, measure))
                .collect();
            let refs: Vec<&SpeedupRecord> = recs.iter().collect();
            let acc = metrics::evaluate_model(&refs, |x| forest.decide(x));
            (b.name.to_string(), acc)
        })
        .collect()
}

/// Persist everything the serving side needs.
pub fn save_outcome(out: &TrainOutcome, model_path: &Path, data_path: Option<&Path>) -> Result<()> {
    io::save(&out.forest, model_path)?;
    if let Some(p) = data_path {
        dataset::save(&out.records, p)?;
    }
    Ok(())
}

/// Encode the trained forest under the artifact contract.
pub fn encode_for_serving(
    forest: &Forest,
    manifest: &crate::runtime::pjrt::Manifest,
) -> export::EncodedForest {
    export::encode(
        forest,
        export::ExportContract {
            num_trees: manifest.num_trees,
            max_nodes: manifest.max_nodes,
            max_depth: manifest.max_depth,
            num_features: manifest.num_features,
        },
    )
}

/// Encode under an artifact-independent contract sized to the forest,
/// for the native batched executor (no PJRT manifest required). The
/// default budget is grown to fit, so nothing is truncated.
pub fn encode_default(forest: &Forest) -> export::EncodedForest {
    let mut contract = export::ExportContract::default();
    contract.num_trees = contract.num_trees.max(forest.trees.len());
    contract.max_nodes = contract.max_nodes.max(forest.max_nodes());
    contract.max_depth = contract.max_depth.max(forest.max_depth());
    export::encode(forest, contract)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_pipeline_end_to_end() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.03, // 3 tuples
            configs_per_kernel: 6,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        assert!(out.records.len() > 1000, "{}", out.records.len());
        assert!(out.synth_accuracy.count_based > 0.6,
            "count {}", out.synth_accuracy.count_based);
        assert!(out.synth_accuracy.penalty_weighted > 0.8);
        assert_eq!(out.per_benchmark.len(), 8);
    }

    #[test]
    fn saved_model_reloads() {
        let dev = DeviceSpec::m2090();
        let cfg = TrainConfig {
            scale: 0.02,
            configs_per_kernel: 4,
            ..Default::default()
        };
        let out = run(&dev, &cfg);
        let dir = std::env::temp_dir();
        let mp = dir.join(format!("lmtuner-model-{}.txt", std::process::id()));
        save_outcome(&out, &mp, None).unwrap();
        let back = crate::ml::io::load(&mp).unwrap();
        let probe = out.records[0].features;
        assert!((back.predict(&probe) - out.forest.predict(&probe)).abs() < 1e-12);
        std::fs::remove_file(&mp).ok();
    }
}
