//! RAII span timers with a hierarchical wall-time attribution tree.
//!
//! A [`Tracer`] hands out [`Span`] guards: creating one opens a timed
//! region, dropping it closes the region and folds the elapsed time
//! into an aggregation keyed by the span's *path* (parent names joined
//! with `/`, e.g. `train/train.generate/dataset.build`). The aggregate
//! is bounded by the number of distinct paths, so tracing a serve run
//! for hours costs O(paths), not O(events); individual events are only
//! materialized when (a) a line-delimited JSON sink is attached
//! ([`Tracer::set_sink`], the CLI's `--trace-out trace.jsonl`) — events
//! stream straight to the file — or (b) a test opts into
//! [`Tracer::retain_events`].
//!
//! Time comes from an injectable [`Clock`]. Production code uses
//! [`MonotonicClock`] (an `Instant` anchor, so timestamps are monotonic
//! durations since tracer construction); tests inject [`ManualClock`]
//! and advance it explicitly, making span trees byte-deterministic
//! (`rust/tests/telemetry.rs`).
//!
//! The process-wide tracer ([`global`]) starts disabled: a [`crate::span!`]
//! against a disabled tracer is one relaxed atomic load and no
//! allocation, which is what lets library code (frontend parse/extract,
//! dataset build, train phases) stay instrumented unconditionally.
//! Parentage is tracked per thread: spans nest within the thread that
//! opened them, and cross-thread work shows up as separate roots tagged
//! with the worker's thread id.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Monotonic time source. `now()` is a duration since an arbitrary
/// per-clock epoch; only differences and ordering are meaningful.
pub trait Clock: Send + Sync {
    fn now(&self) -> Duration;
}

/// Wall clock: durations since construction, via `Instant`.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Test clock: advances only when told to, so span durations in tests
/// are exact constants.
#[derive(Default)]
pub struct ManualClock {
    nanos: AtomicU64,
}

impl ManualClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn advance(&self, d: Duration) {
        self.nanos.fetch_add(d.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }
}

/// One closed span, as retained by [`Tracer::retain_events`] and as
/// serialized (one JSON object per line) into the `--trace-out` sink.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    pub id: u64,
    pub parent: Option<u64>,
    pub name: String,
    /// Full `/`-joined path from the root span on this thread.
    pub path: String,
    /// Process-local thread index (not the OS tid): stable within a
    /// run, first-use ordered.
    pub thread: u64,
    pub start: Duration,
    pub end: Duration,
}

impl SpanEvent {
    pub fn elapsed(&self) -> Duration {
        self.end.saturating_sub(self.start)
    }

    /// The `trace.jsonl` line schema (DESIGN.md §2i).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", Json::Num(self.id as f64))
            .set(
                "parent",
                match self.parent {
                    Some(p) => Json::Num(p as f64),
                    None => Json::Null,
                },
            )
            .set("name", Json::Str(self.name.clone()))
            .set("path", Json::Str(self.path.clone()))
            .set("thread", Json::Num(self.thread as f64))
            .set("start_ns", Json::Num(self.start.as_nanos() as f64))
            .set("end_ns", Json::Num(self.end.as_nanos() as f64));
        j
    }
}

/// Aggregated totals for one span path.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PathStat {
    pub count: u64,
    pub total: Duration,
}

struct TracerInner {
    /// Globally unique tracer id — keys the per-thread span stacks so
    /// independent tracers (tests) never see each other's parents.
    tid: u64,
    enabled: AtomicBool,
    clock: Box<dyn Clock>,
    next_span: AtomicU64,
    agg: Mutex<BTreeMap<String, PathStat>>,
    events: Mutex<Vec<SpanEvent>>,
    retain: AtomicBool,
    sink: Mutex<Option<std::io::BufWriter<std::fs::File>>>,
}

/// Span-timer factory; cheap to clone (shared state behind an `Arc`).
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<TracerInner>,
}

static NEXT_TRACER: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// `(tracer id, span id, path)` for every open span on this thread.
    static STACK: RefCell<Vec<(u64, u64, String)>> = const { RefCell::new(Vec::new()) };
    static THREAD_IX: RefCell<Option<u64>> = const { RefCell::new(None) };
}

fn thread_index() -> u64 {
    THREAD_IX.with(|ix| {
        let mut ix = ix.borrow_mut();
        *ix.get_or_insert_with(|| NEXT_THREAD.fetch_add(1, Ordering::Relaxed))
    })
}

impl Tracer {
    fn build(clock: Box<dyn Clock>, enabled: bool) -> Tracer {
        Tracer {
            inner: Arc::new(TracerInner {
                tid: NEXT_TRACER.fetch_add(1, Ordering::Relaxed),
                enabled: AtomicBool::new(enabled),
                clock,
                next_span: AtomicU64::new(1),
                agg: Mutex::new(BTreeMap::new()),
                events: Mutex::new(Vec::new()),
                retain: AtomicBool::new(false),
                sink: Mutex::new(None),
            }),
        }
    }

    /// An enabled tracer on the wall clock.
    pub fn new() -> Tracer {
        Self::build(Box::new(MonotonicClock::new()), true)
    }

    /// An enabled tracer on an injected clock (tests).
    pub fn with_clock(clock: Box<dyn Clock>) -> Tracer {
        Self::build(clock, true)
    }

    /// A disabled tracer (what [`global`] starts as): spans are no-ops
    /// until [`Tracer::enable`].
    pub fn disabled() -> Tracer {
        Self::build(Box::new(MonotonicClock::new()), false)
    }

    pub fn enable(&self) {
        self.inner.enabled.store(true, Ordering::Release);
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.enabled.load(Ordering::Acquire)
    }

    /// Keep closed spans in memory (unbounded — tests only).
    pub fn retain_events(&self) {
        self.inner.retain.store(true, Ordering::Release);
    }

    /// Stream every closed span as one JSON line into `path`
    /// (`--trace-out`). Implies [`Tracer::enable`].
    pub fn set_sink(&self, path: &std::path::Path) -> std::io::Result<()> {
        let f = std::fs::File::create(path)?;
        *self.inner.sink.lock().unwrap() = Some(std::io::BufWriter::new(f));
        self.enable();
        Ok(())
    }

    /// Open a span. Prefer the [`crate::span!`] macro, which routes to
    /// the global tracer by default.
    pub fn span(&self, name: &str) -> Span {
        if !self.is_enabled() {
            return Span { active: None };
        }
        let id = self.inner.next_span.fetch_add(1, Ordering::Relaxed);
        let tid = self.inner.tid;
        let (parent, path) = STACK.with(|s| {
            let mut s = s.borrow_mut();
            let parent = s.iter().rev().find(|&&(t, _, _)| t == tid);
            let (parent_id, path) = match parent {
                Some((_, pid, ppath)) => (Some(*pid), format!("{ppath}/{name}")),
                None => (None, name.to_string()),
            };
            s.push((tid, id, path.clone()));
            (parent_id, path)
        });
        Span {
            active: Some(SpanActive {
                tracer: Arc::clone(&self.inner),
                id,
                parent,
                name: name.to_string(),
                path,
                thread: thread_index(),
                start: self.inner.clock.now(),
            }),
        }
    }

    /// Aggregated `(path, stat)` rows, path-sorted.
    pub fn attribution(&self) -> Vec<(String, PathStat)> {
        self.inner
            .agg
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    /// Closed spans, in close order ([`Tracer::retain_events`] only).
    pub fn events(&self) -> Vec<SpanEvent> {
        self.inner.events.lock().unwrap().clone()
    }

    /// Flush the JSONL sink (if any). Dropped spans flush lazily via
    /// the `BufWriter`; call this before reading the file.
    pub fn flush(&self) -> std::io::Result<()> {
        if let Some(w) = self.inner.sink.lock().unwrap().as_mut() {
            w.flush()?;
        }
        Ok(())
    }

    /// Render the attribution tree: one line per path, indented by
    /// depth, with total seconds, call count, and share of the combined
    /// sibling total at that level. Children group under parents
    /// structurally (not by string sort), so names may contain any
    /// separator-free text.
    pub fn render_tree(&self) -> String {
        let agg = self.inner.agg.lock().unwrap();
        let mut out = String::new();
        render_level(&agg, "", 0, &mut out);
        out
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

fn render_level(agg: &BTreeMap<String, PathStat>, prefix: &str, depth: usize, out: &mut String) {
    // Direct children of `prefix`: paths `prefix/child` with no
    // further `/`. Collected (not streamed) so ordering is structural.
    let mut children: Vec<(&str, &PathStat)> = agg
        .iter()
        .filter_map(|(path, stat)| {
            let rest = if prefix.is_empty() {
                path.as_str()
            } else {
                path.strip_prefix(prefix)?.strip_prefix('/')?
            };
            (!rest.is_empty() && !rest.contains('/')).then_some((rest, stat))
        })
        .collect();
    children.sort_by(|a, b| b.1.total.cmp(&a.1.total));
    let parent_total: f64 = children.iter().map(|(_, s)| s.total.as_secs_f64()).sum();
    for (name, stat) in children {
        let secs = stat.total.as_secs_f64();
        let share = if parent_total > 0.0 { 100.0 * secs / parent_total } else { 0.0 };
        let _ = writeln!(
            out,
            "{:indent$}{name:<32} {secs:>10.6}s  x{:<6} {share:>5.1}%",
            "",
            stat.count,
            indent = depth * 2
        );
        let child_prefix =
            if prefix.is_empty() { name.to_string() } else { format!("{prefix}/{name}") };
        render_level(agg, &child_prefix, depth + 1, out);
    }
}

struct SpanActive {
    tracer: Arc<TracerInner>,
    id: u64,
    parent: Option<u64>,
    name: String,
    path: String,
    thread: u64,
    start: Duration,
}

/// RAII guard for one timed region; closing happens on drop.
pub struct Span {
    active: Option<SpanActive>,
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        let end = a.tracer.clock.now();
        // Unwind our stack entry. RAII drops are LIFO, but a guard can
        // be moved and dropped out of order — remove by id, not pop.
        STACK.with(|s| {
            let mut s = s.borrow_mut();
            if let Some(ix) =
                s.iter().rposition(|&(t, id, _)| t == a.tracer.tid && id == a.id)
            {
                s.remove(ix);
            }
        });
        {
            let mut agg = a.tracer.agg.lock().unwrap();
            let stat = agg.entry(a.path.clone()).or_default();
            stat.count += 1;
            stat.total += end.saturating_sub(a.start);
        }
        let ev = SpanEvent {
            id: a.id,
            parent: a.parent,
            name: a.name,
            path: a.path,
            thread: a.thread,
            start: a.start,
            end,
        };
        if let Some(w) = a.tracer.sink.lock().unwrap().as_mut() {
            let _ = writeln!(w, "{}", ev.to_json().dump());
        }
        if a.tracer.retain.load(Ordering::Acquire) {
            a.tracer.events.lock().unwrap().push(ev);
        }
    }
}

static GLOBAL: OnceLock<Tracer> = OnceLock::new();

/// The process-wide tracer. Starts disabled (spans are free); the CLI
/// enables it when `--trace-out` is passed.
pub fn global() -> &'static Tracer {
    GLOBAL.get_or_init(Tracer::disabled)
}

/// Open a span: `span!("name")` on the [`crate::obs::trace::global`]
/// tracer, or `span!(tracer, "name")` on an explicit one. Bind the
/// result (`let _span = span!(...)`) — an unbound guard drops
/// immediately and times nothing.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::obs::trace::global().span($name)
    };
    ($tracer:expr, $name:expr) => {
        $tracer.span($name)
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        t.retain_events();
        {
            let _s = t.span("noop");
        }
        assert!(t.events().is_empty());
        assert!(t.attribution().is_empty());
    }

    #[test]
    fn manual_clock_gives_exact_spans() {
        let clock = Arc::new(ManualClock::new());
        let c2 = Arc::clone(&clock);
        struct Shared(Arc<ManualClock>);
        impl Clock for Shared {
            fn now(&self) -> Duration {
                self.0.now()
            }
        }
        let t = Tracer::with_clock(Box::new(Shared(c2)));
        t.retain_events();
        {
            let _outer = t.span("outer");
            clock.advance(Duration::from_millis(10));
            {
                let _inner = t.span("inner");
                clock.advance(Duration::from_millis(5));
            }
            clock.advance(Duration::from_millis(1));
        }
        let evs = t.events();
        assert_eq!(evs.len(), 2);
        // Inner closes first.
        assert_eq!(evs[0].name, "inner");
        assert_eq!(evs[0].path, "outer/inner");
        assert_eq!(evs[0].elapsed(), Duration::from_millis(5));
        assert_eq!(evs[0].parent, Some(evs[1].id));
        assert_eq!(evs[1].name, "outer");
        assert_eq!(evs[1].elapsed(), Duration::from_millis(16));
        assert_eq!(evs[1].parent, None);
        let att = t.attribution();
        assert_eq!(att.len(), 2);
        assert_eq!(att[0].0, "outer");
        assert_eq!(att[0].1.total, Duration::from_millis(16));
        assert_eq!(att[1].0, "outer/inner");
        assert_eq!(att[1].1.count, 1);
    }

    #[test]
    fn independent_tracers_do_not_nest() {
        let a = Tracer::new();
        let b = Tracer::new();
        a.retain_events();
        b.retain_events();
        let _sa = a.span("a-root");
        {
            let _sb = b.span("b-root");
        }
        let evs = b.events();
        assert_eq!(evs[0].parent, None, "span from tracer a must not parent b's");
        assert_eq!(evs[0].path, "b-root");
    }

    #[test]
    fn render_tree_indents_children() {
        let t = Tracer::new();
        {
            let _p = t.span("parent");
            let _c = t.span("child");
        }
        let tree = t.render_tree();
        assert!(tree.contains("parent"), "{tree}");
        assert!(tree.contains("  child"), "{tree}");
    }
}
