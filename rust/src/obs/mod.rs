//! Unified telemetry plane: metrics registry, span tracing, latency
//! histograms.
//!
//! The serving north star ("as fast as the hardware allows", ROADMAP)
//! is measurement-driven, and until now the stack's only visibility
//! was ad-hoc: `ServiceStats` counted served/rejected with no
//! latencies, `StageCounters` covered only the synth pipeline, and
//! benches produced offline `BENCH_*.json` snapshots. This subsystem
//! is the live counterpart, built on the same zero-new-dependency
//! substrates (`util::json` for export, `std::sync` for sharing):
//!
//! * [`metrics`] — a [`metrics::MetricsRegistry`] of named counters,
//!   gauges, and log2-bucketed [`metrics::Histogram`]s. Registries are
//!   **thread-sharded**: each worker owns one (no locks on the hot
//!   path) and the owner merges them at join time, exactly like
//!   `synth::pipeline::StageCounters`. Histogram merges are bucket-wise
//!   sums over *fixed* boundaries, so merging is exact, associative,
//!   and commutative — p50/p90/p99/max read the same regardless of
//!   worker count or merge order.
//! * [`trace`] — RAII span timers ([`crate::span!`]) that aggregate
//!   into a hierarchical wall-time attribution tree and optionally
//!   stream a line-delimited JSON event log (`--trace-out trace.jsonl`)
//!   with monotonic timestamps, thread ids, and span parentage. Time
//!   comes from an injectable [`trace::Clock`], so tests pin spans to a
//!   [`trace::ManualClock`] and assert exact durations.
//!
//! Consumers: `coordinator::service` workers record per-batch
//! queue-wait/execution histograms into `ServiceStats`; the executors
//! record rows/sec and batch-size distributions via
//! [`metrics::ExecTelemetry`]; `coordinator::train` reports per-phase
//! (generate/fit/grade) timings; the frontend records
//! parse/extract/lint spans; and the CLI exposes it all through
//! `--metrics-out` / `--trace-out` (schema in DESIGN.md §2i).

pub mod metrics;
pub mod trace;

pub use metrics::{ExecTelemetry, Histogram, MetricsRegistry};
pub use trace::{Clock, ManualClock, MonotonicClock, Span, SpanEvent, Tracer};
