//! Thread-sharded metrics registry: counters, gauges, log2 histograms.
//!
//! The concurrency model is deliberately the same as
//! `synth::pipeline::StageCounters`: a [`MetricsRegistry`] is a plain
//! value with no interior locking. Each worker thread owns its own
//! shard, records into it lock-free, and hands it back (by return
//! value, exactly like `worker_loop` returns `ServiceStats`); the
//! owner folds the shards together with [`MetricsRegistry::merge`].
//! Because [`Histogram`] buckets sit on *fixed* power-of-two
//! boundaries, a merge is a bucket-wise integer sum — exact,
//! associative, and commutative — so any merge order over any thread
//! count produces bit-identical percentiles (`rust/tests/telemetry.rs`
//! proves this for 1/2/4-way shardings).
//!
//! Export goes through `util::json`: [`MetricsRegistry::to_json`]
//! produces the `metrics.json` schema documented in DESIGN.md §2i, and
//! [`MetricsRegistry::from_json`] round-trips it losslessly. Bench
//! binaries attach the same JSON under a `"metrics"` section of
//! `util::bench::JsonReport` (via `JsonReport::set_section`), so live
//! telemetry and offline `BENCH_*.json` snapshots share one format.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Json;

/// Number of histogram buckets. Bucket `i` (for `1 <= i < 63`) covers
/// values in `[2^(i-31), 2^(i-30))`; bucket 0 is the underflow bucket
/// (everything `< 2^-30`, including zero and negatives) and bucket 63
/// collects everything `>= 2^32`. The span 2^-30..2^32 covers
/// nanosecond-scale latencies in seconds on one end and row counts /
/// rows-per-second figures on the other.
pub const NUM_BUCKETS: usize = 64;

/// Exponent of the lower edge of bucket 1 (`2^MIN_EXP`).
pub const MIN_EXP: i32 = -30;

/// `floor(log2(v))` for positive finite `v`, computed from the IEEE-754
/// exponent bits so bucket boundaries are exact: `2^k` always lands in
/// the bucket whose lower edge is `2^k`, and the largest float below it
/// lands one bucket down. Subnormals report below [`MIN_EXP`] and clamp
/// into the underflow bucket.
fn floor_log2(v: f64) -> i32 {
    debug_assert!(v > 0.0);
    let biased = ((v.to_bits() >> 52) & 0x7ff) as i32;
    if biased == 0 {
        // Subnormal: < 2^-1022, far below any bucket edge.
        i32::MIN / 2
    } else {
        biased - 1023
    }
}

fn bucket_index(v: f64) -> usize {
    // NaN (and any non-finite) routes to the underflow bucket with
    // zero/negative values, so bucket sums always equal the count.
    if !v.is_finite() || v <= 0.0 {
        return 0;
    }
    (floor_log2(v) - MIN_EXP + 1).clamp(0, NUM_BUCKETS as i32 - 1) as usize
}

/// Lower edge of bucket `i` (`-inf` for the underflow bucket).
pub fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        f64::NEG_INFINITY
    } else {
        (2.0f64).powi(i as i32 - 1 + MIN_EXP)
    }
}

/// Upper edge (exclusive) of bucket `i` (`+inf` for the last bucket).
pub fn bucket_hi(i: usize) -> f64 {
    if i == NUM_BUCKETS - 1 {
        f64::INFINITY
    } else {
        (2.0f64).powi(i as i32 + MIN_EXP)
    }
}

/// A log2-bucketed histogram with exact, order-independent merges.
///
/// Buckets double in width, so a quantile estimate is at most 2x the
/// exact sample quantile (and never below it) for values inside the
/// bucket range — `rust/tests/telemetry.rs` asserts that bound against
/// `util::stats::percentile` on randomized samples. `count`, `sum`,
/// `min`, and `max` are exact.
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    counts: [u64; NUM_BUCKETS],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; NUM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn observe(&mut self, v: f64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn observe_duration(&mut self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Bucket-wise sum: exact, associative, commutative.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Non-empty buckets as `(index, count)` pairs (sparse export).
    pub fn nonzero_buckets(&self) -> Vec<(usize, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| (i, c))
            .collect()
    }

    /// Quantile estimate for `p` in percent (0..=100): the upper edge
    /// of the bucket holding the `ceil(p/100 * count)`-th smallest
    /// sample, clamped to the exact observed `[min, max]`. Derived
    /// purely from bucket counts + min/max, so merged histograms agree
    /// bit-for-bit regardless of merge order. Returns 0 when empty.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_hi(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }

    /// JSON shape (DESIGN.md §2i): exact scalars plus sparse buckets
    /// keyed by index, with the upper edge (`le`) denormalized for
    /// readers that don't know the bucket table.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("count", Json::Num(self.count as f64))
            .set("sum", Json::Num(self.sum))
            .set("min", Json::Num(self.min()))
            .set("max", Json::Num(self.max()))
            .set("p50", Json::Num(self.percentile(50.0)))
            .set("p90", Json::Num(self.percentile(90.0)))
            .set("p99", Json::Num(self.percentile(99.0)));
        let buckets = self
            .nonzero_buckets()
            .into_iter()
            .map(|(i, c)| {
                let mut b = Json::obj();
                let hi = bucket_hi(i);
                b.set("bucket", Json::Num(i as f64)).set(
                    "le",
                    if hi.is_finite() { Json::Num(hi) } else { Json::Str("inf".into()) },
                );
                b.set("n", Json::Num(c as f64));
                b
            })
            .collect();
        j.set("buckets", Json::Arr(buckets));
        j
    }

    pub fn from_json(j: &Json) -> Result<Histogram, String> {
        let num = |key: &str| {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("histogram: missing numeric '{key}'"))
        };
        let mut h = Histogram::new();
        h.count = num("count")? as u64;
        h.sum = num("sum")?;
        if h.count > 0 {
            h.min = num("min")?;
            h.max = num("max")?;
        }
        let buckets = j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| "histogram: missing 'buckets'".to_string())?;
        for b in buckets {
            let i = b
                .get("bucket")
                .and_then(Json::as_usize)
                .ok_or_else(|| "histogram bucket: missing 'bucket'".to_string())?;
            if i >= NUM_BUCKETS {
                return Err(format!("histogram bucket index {i} out of range"));
            }
            let n = b
                .get("n")
                .and_then(Json::as_f64)
                .ok_or_else(|| "histogram bucket: missing 'n'".to_string())?;
            h.counts[i] = n as u64;
        }
        let total: u64 = h.counts.iter().sum();
        if total != h.count {
            return Err(format!(
                "histogram: bucket counts sum to {total}, expected {}",
                h.count
            ));
        }
        Ok(h)
    }
}

/// Named counters (monotonic `u64`), gauges (`f64`, merge keeps the
/// max), and [`Histogram`]s. One per thread; merge at join.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add to a counter (creating it at zero).
    pub fn add(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Set a gauge. Gauges merge by `max` (the only order-independent
    /// fold without a timestamp) — use them for peaks and phase
    /// durations, not last-writer state.
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        let g = self.gauges.entry(name.to_string()).or_insert(f64::NEG_INFINITY);
        *g = g.max(v);
    }

    /// Record one sample into a named histogram.
    pub fn observe(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().observe(v);
    }

    /// Merge a whole pre-built histogram under `name` (e.g. a worker's
    /// `ServiceStats` histogram re-exported into the registry).
    pub fn merge_histogram(&mut self, name: &str, h: &Histogram) {
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Fold another shard in: counters add, gauges max, histograms
    /// bucket-sum. Associative and commutative, like everything above.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            let g = self.gauges.entry(k.clone()).or_insert(f64::NEG_INFINITY);
            *g = g.max(*v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// The `metrics.json` schema (DESIGN.md §2i): three top-level maps.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (k, v) in &self.counters {
            counters.set(k, Json::Num(*v as f64));
        }
        let mut gauges = Json::obj();
        for (k, v) in &self.gauges {
            gauges.set(k, Json::Num(*v));
        }
        let mut hists = Json::obj();
        for (k, h) in &self.histograms {
            hists.set(k, h.to_json());
        }
        let mut j = Json::obj();
        j.set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists);
        j
    }

    pub fn from_json(j: &Json) -> Result<MetricsRegistry, String> {
        let section = |key: &str| match j.get(key) {
            Some(Json::Obj(m)) => Ok(m),
            _ => Err(format!("metrics: missing object '{key}'")),
        };
        let mut reg = MetricsRegistry::new();
        for (k, v) in section("counters")? {
            let n = v.as_f64().ok_or_else(|| format!("counter '{k}' not numeric"))?;
            if n < 0.0 {
                return Err(format!("counter '{k}' is negative"));
            }
            reg.counters.insert(k.clone(), n as u64);
        }
        for (k, v) in section("gauges")? {
            let n = v.as_f64().ok_or_else(|| format!("gauge '{k}' not numeric"))?;
            reg.gauges.insert(k.clone(), n);
        }
        for (k, v) in section("histograms")? {
            reg.histograms
                .insert(k.clone(), Histogram::from_json(v).map_err(|e| format!("{k}: {e}"))?);
        }
        Ok(reg)
    }

    /// Write `metrics.json` (pretty-printed) for `--metrics-out`.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().dump_pretty())
    }
}

/// Shared, lock-light telemetry sink for batch executors.
///
/// Executors take predictions through `&self`, so per-executor counters
/// need interior mutability; this keeps the hot path to three relaxed
/// atomic adds plus one short mutex hold *per batch* (never per row) —
/// the `perf_inference` "telemetry overhead" section holds it to <= 3%
/// on the flat hot path. Attach with
/// `FlatForestExecutor::with_telemetry` / `NativeForestExecutor::
/// with_telemetry`; untouched executors pay one `Option` check.
#[derive(Debug, Default)]
pub struct ExecTelemetry {
    rows: AtomicU64,
    batches: AtomicU64,
    busy_ns: AtomicU64,
    hist: Mutex<ExecHists>,
}

#[derive(Debug, Default)]
struct ExecHists {
    batch_rows: Histogram,
    batch_time: Histogram,
}

impl ExecTelemetry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, rows: usize, elapsed: Duration) {
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.busy_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        let mut h = self.hist.lock().unwrap();
        h.batch_rows.observe(rows as f64);
        h.batch_time.observe(elapsed.as_secs_f64());
    }

    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    pub fn busy_seconds(&self) -> f64 {
        self.busy_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Rows served per second of executor busy time.
    pub fn rows_per_second(&self) -> f64 {
        let busy = self.busy_seconds();
        if busy > 0.0 {
            self.rows() as f64 / busy
        } else {
            0.0
        }
    }

    /// Export under `prefix` (e.g. `exec`): counters `<prefix>.rows` /
    /// `.batches`, gauge `.rows_per_s`, histograms `.batch_rows` /
    /// `.batch_time_s`.
    pub fn export(&self, prefix: &str, reg: &mut MetricsRegistry) {
        reg.add(&format!("{prefix}.rows"), self.rows());
        reg.add(&format!("{prefix}.batches"), self.batches());
        reg.set_gauge(&format!("{prefix}.rows_per_s"), self.rows_per_second());
        let h = self.hist.lock().unwrap();
        reg.merge_histogram(&format!("{prefix}.batch_rows"), &h.batch_rows);
        reg.merge_histogram(&format!("{prefix}.batch_time_s"), &h.batch_time);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_powers_of_two() {
        assert_eq!(bucket_index(1.0), 31);
        assert_eq!(bucket_lo(31), 1.0);
        assert_eq!(bucket_hi(31), 2.0);
        // The largest float below 1.0 sits one bucket down.
        let below = f64::from_bits(1.0f64.to_bits() - 1);
        assert_eq!(bucket_index(below), 30);
        // Underflow, overflow, and junk all land in real buckets.
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(-3.0), 0);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(1e300), NUM_BUCKETS - 1);
    }

    #[test]
    fn histogram_scalars_exact() {
        let mut h = Histogram::new();
        for v in [0.5, 1.0, 2.0, 4.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 7.5);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 4.0);
        assert_eq!(h.mean(), 1.875);
        let total: u64 = h.nonzero_buckets().iter().map(|&(_, c)| c).sum();
        assert_eq!(total, h.count());
    }

    #[test]
    fn merge_matches_single_stream() {
        let vals: Vec<f64> = (1..=100).map(|i| i as f64 * 0.37).collect();
        let mut whole = Histogram::new();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in vals.iter().enumerate() {
            whole.observe(v);
            if i % 2 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
        }
        let mut merged = Histogram::new();
        merged.merge(&b);
        merged.merge(&a);
        assert_eq!(merged, whole);
    }

    #[test]
    fn registry_merge_and_lookup() {
        let mut a = MetricsRegistry::new();
        a.add("served", 3);
        a.set_gauge("peak", 5.0);
        a.observe("lat", 0.01);
        let mut b = MetricsRegistry::new();
        b.add("served", 4);
        b.set_gauge("peak", 2.0);
        b.observe("lat", 0.02);
        a.merge(&b);
        assert_eq!(a.counter("served"), 7);
        assert_eq!(a.gauge("peak"), Some(5.0));
        assert_eq!(a.histogram("lat").unwrap().count(), 2);
    }

    #[test]
    fn exec_telemetry_exports() {
        let t = ExecTelemetry::new();
        t.record_batch(100, Duration::from_millis(10));
        t.record_batch(300, Duration::from_millis(30));
        assert_eq!(t.rows(), 400);
        assert_eq!(t.batches(), 2);
        assert!((t.rows_per_second() - 10_000.0).abs() / 10_000.0 < 0.05);
        let mut reg = MetricsRegistry::new();
        t.export("exec", &mut reg);
        assert_eq!(reg.counter("exec.rows"), 400);
        assert_eq!(reg.histogram("exec.batch_rows").unwrap().count(), 2);
    }
}
