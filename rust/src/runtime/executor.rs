//! Backend-agnostic batched forest inference.
//!
//! [`BatchExecutor`] is the contract the prediction service batches
//! against; it has three implementations:
//!
//!   * `runtime::fastexec::FlatForestExecutor` — the default serving
//!     backend: the forest compiled once into a compacted SoA layout
//!     with a quantized (u8-compare) fast path. See `runtime::fastexec`
//!     for the layout and the exactness contract.
//!   * [`NativeForestExecutor`] (here) — the reference implementation:
//!     traverses the tensor-encoded forest (`ml::export` layout)
//!     node-by-node in pure rust, with chunked parallelism over
//!     `util::pool::parallel_map` and row-major batch iteration.
//!     Always available: no artifacts, no FFI.
//!   * `runtime::forest_exec::ForestExecutor` — routes batches to the
//!     AOT-compiled PJRT executables when artifacts exist.
//!
//! All must agree with `EncodedForest::predict` row-for-row; the
//! serving tests check the native path to 1e-6 over 10k-row batches and
//! the differential suite (`rust/tests/infexec.rs`) pins the flat paths
//! to the reference.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::ml::export::EncodedForest;
use crate::obs::metrics::ExecTelemetry;
use crate::util::pool::parallel_map;

use super::fastexec::{FlatForest, FlatForestExecutor};

/// A batched `features -> log2(speedup)` backend the service can drive.
pub trait BatchExecutor: Send {
    /// Short backend name for logs/metrics ("native", "pjrt", ...).
    fn backend(&self) -> &'static str;

    /// Largest batch the backend serves in one call; the service clamps
    /// its batching window to this.
    fn max_batch(&self) -> usize;

    /// Predict log2(speedup) for each row, preserving order. A malformed
    /// batch (e.g. wrong feature width) must return `Err`, not panic —
    /// the service turns that into typed per-request error replies.
    fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>>;

    /// The auto-tuning decisions for a batch.
    fn decide(&self, rows: &[Vec<f64>]) -> Result<Vec<bool>> {
        Ok(self.predict(rows)?.into_iter().map(|p| p > 0.0).collect())
    }

    /// Outputs per prediction row (1 = verdict only, 3 = joint verdict
    /// + workgroup shape). Backends without joint planes keep the
    /// default.
    fn num_outputs(&self) -> usize {
        1
    }

    /// All `num_outputs()` predictions per row, row-major
    /// (`rows.len() * num_outputs()` values). The default covers
    /// single-output backends by delegating to [`Self::predict`];
    /// joint-capable backends override it so every plane comes from one
    /// traversal.
    fn predict_outputs(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.predict(rows)
    }
}

/// Pure-rust batched executor over the tensor-encoded forest. The forest
/// tables are behind an `Arc`, so N sharded executors share one copy.
pub struct NativeForestExecutor {
    forest: Arc<EncodedForest>,
    threads: usize,
    /// Rows per parallel work item; small batches stay single-threaded.
    chunk_rows: usize,
    /// Optional shared sink for rows/sec + batch-size distributions;
    /// `None` (the default) costs one branch per batch.
    telemetry: Option<Arc<ExecTelemetry>>,
}

impl NativeForestExecutor {
    /// Executor sized to the host (all cores, 64-row chunks).
    pub fn new(forest: EncodedForest) -> Self {
        Self::from_shared(Arc::new(forest))
    }

    /// Share one forest across several executors (one per service shard).
    pub fn from_shared(forest: Arc<EncodedForest>) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self {
            forest,
            threads: threads.max(1),
            chunk_rows: 64,
            telemetry: None,
        }
    }

    pub fn with_parallelism(
        forest: EncodedForest,
        threads: usize,
        chunk_rows: usize,
    ) -> Self {
        NativeForestExecutor {
            forest: Arc::new(forest),
            threads: threads.max(1),
            chunk_rows: chunk_rows.max(1),
            telemetry: None,
        }
    }

    /// Cap this executor's parallelism (e.g. divide the host's cores
    /// across service shards so concurrent batches don't oversubscribe).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Record every successful batch (rows, wall time) into `sink`;
    /// share one sink across shards to see the whole backend's rows/sec
    /// and batch-size distribution.
    pub fn with_telemetry(mut self, sink: Arc<ExecTelemetry>) -> Self {
        self.telemetry = Some(sink);
        self
    }

    pub fn forest(&self) -> &EncodedForest {
        &self.forest
    }

    /// Outputs per prediction of the encoded forest (1 = verdict only,
    /// 3 = joint verdict + workgroup shape).
    pub fn num_outputs(&self) -> usize {
        self.forest.num_outputs()
    }

    /// Batched joint prediction: (log2 wg_w, log2 wg_h) per row. `Err`
    /// for single-output models (the caller should gate on
    /// [`Self::num_outputs`]) or malformed rows; same chunked
    /// parallelism policy as `predict`.
    pub fn predict_wg_logs(&self, rows: &[Vec<f64>]) -> Result<Vec<(f64, f64)>> {
        if self.forest.num_outputs() < 3 {
            return Err(anyhow!(
                "model has {} output(s); workgroup prediction needs a joint \
                 (schema v2) model",
                self.forest.num_outputs()
            ));
        }
        let nf = self.forest.contract.num_features;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != nf {
                return Err(anyhow!(
                    "row {i}: feature vector has {} dims, expected {nf}",
                    r.len()
                ));
            }
        }
        // Arity was checked above, so per-row `unwrap` cannot fire.
        if self.threads <= 1 || rows.len() < 2 * self.chunk_rows {
            return Ok(rows
                .iter()
                .map(|r| self.forest.predict_wg_logs(r).unwrap())
                .collect());
        }
        let chunks: Vec<&[Vec<f64>]> = rows.chunks(self.chunk_rows).collect();
        let nested = parallel_map(&chunks, self.threads, |chunk| {
            chunk
                .iter()
                .map(|r| self.forest.predict_wg_logs(r).unwrap())
                .collect::<Vec<(f64, f64)>>()
        });
        Ok(nested.into_iter().flatten().collect())
    }
}

/// Per-device registry of models: one serving process holds a model per
/// simulated device, each stored both tensor-encoded (the reference
/// layout) and flat-compiled (the hot-path tables), and builds executors
/// that share them via `Arc`, so routing a batch by device never copies
/// a forest. Keys are `gpu::registry` device slugs; iteration order is
/// sorted (BTreeMap), so shard layouts are deterministic.
#[derive(Default)]
pub struct ForestRegistry {
    map: std::collections::BTreeMap<String, RegistryEntry>,
}

struct RegistryEntry {
    enc: Arc<EncodedForest>,
    flat: Arc<FlatForest>,
}

impl ForestRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) the model serving `device`, compiling the
    /// flat hot-path tables up front — a corrupt encoding is rejected
    /// here, at load time, instead of at serve time.
    pub fn insert(
        &mut self,
        device: impl Into<String>,
        forest: EncodedForest,
    ) -> Result<()> {
        let flat = Arc::new(FlatForest::compile(&forest)?);
        self.map.insert(
            device.into(),
            RegistryEntry { enc: Arc::new(forest), flat },
        );
        Ok(())
    }

    pub fn get(&self, device: &str) -> Option<&Arc<EncodedForest>> {
        self.map.get(device).map(|e| &e.enc)
    }

    /// The compiled hot-path tables serving `device`.
    pub fn flat(&self, device: &str) -> Option<&Arc<FlatForest>> {
        self.map.get(device).map(|e| &e.flat)
    }

    /// Build the default (flat) executor over `device`'s model, sharing
    /// the compiled tables with every other executor built from this
    /// entry.
    pub fn executor_for(&self, device: &str) -> Option<FlatForestExecutor> {
        self.map
            .get(device)
            .map(|e| FlatForestExecutor::from_shared(e.flat.clone()))
    }

    /// The reference (tensor-walking) executor over `device`'s model,
    /// kept for differential checks against the flat hot path.
    pub fn reference_executor_for(&self, device: &str) -> Option<NativeForestExecutor> {
        self.map
            .get(device)
            .map(|e| NativeForestExecutor::from_shared(e.enc.clone()))
    }

    /// Registered device keys, sorted.
    pub fn devices(&self) -> Vec<&str> {
        self.map.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

impl NativeForestExecutor {
    fn check_rows(&self, rows: &[Vec<f64>]) -> Result<()> {
        let nf = self.forest.contract.num_features;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != nf {
                return Err(anyhow!(
                    "row {i}: feature vector has {} dims, expected {nf}",
                    r.len()
                ));
            }
        }
        Ok(())
    }

    fn predict_verdicts(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.check_rows(rows)?;
        // Small batches: the scoped-thread fan-out costs more than the
        // traversal itself.
        if self.threads <= 1 || rows.len() < 2 * self.chunk_rows {
            return Ok(rows.iter().map(|r| self.forest.predict(r)).collect());
        }
        let chunks: Vec<&[Vec<f64>]> = rows.chunks(self.chunk_rows).collect();
        let nested = parallel_map(&chunks, self.threads, |chunk| {
            chunk
                .iter()
                .map(|r| self.forest.predict(r))
                .collect::<Vec<f64>>()
        });
        Ok(nested.into_iter().flatten().collect())
    }

    fn predict_planes(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.check_rows(rows)?;
        if self.threads <= 1 || rows.len() < 2 * self.chunk_rows {
            return Ok(rows
                .iter()
                .flat_map(|r| self.forest.predict_outputs(r))
                .collect());
        }
        let chunks: Vec<&[Vec<f64>]> = rows.chunks(self.chunk_rows).collect();
        let nested = parallel_map(&chunks, self.threads, |chunk| {
            chunk
                .iter()
                .flat_map(|r| self.forest.predict_outputs(r))
                .collect::<Vec<f64>>()
        });
        Ok(nested.into_iter().flatten().collect())
    }

    fn observe<T>(&self, rows: usize, f: impl FnOnce() -> Result<T>) -> Result<T> {
        match &self.telemetry {
            None => f(),
            Some(sink) => {
                let started = Instant::now();
                let out = f();
                if out.is_ok() {
                    sink.record_batch(rows, started.elapsed());
                }
                out
            }
        }
    }
}

impl BatchExecutor for NativeForestExecutor {
    fn backend(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.observe(rows.len(), || self.predict_verdicts(rows))
    }

    fn num_outputs(&self) -> usize {
        self.forest.num_outputs()
    }

    fn predict_outputs(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.observe(rows.len(), || self.predict_planes(rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;
    use crate::ml::export::{encode, ExportContract};
    use crate::ml::forest::{Forest, ForestConfig};
    use crate::util::prng::Rng;

    fn toy_encoded(seed: u64) -> EncodedForest {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
            .map(|_| (0..250).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..250).map(|i| if x[1][i] > 0.0 { 1.0 } else { -1.0 }).collect();
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig { num_trees: 8, threads: 2, ..Default::default() },
        );
        encode(&f, ExportContract::default())
    }

    fn random_rows(n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..NUM_FEATURES).map(|_| rng.range_f64(-2.0, 2.0)).collect())
            .collect()
    }

    #[test]
    fn batched_equals_scalar_reference() {
        let enc = toy_encoded(11);
        let exec = NativeForestExecutor::with_parallelism(enc.clone(), 4, 16);
        let rows = random_rows(500, 12);
        let got = exec.predict(&rows).unwrap();
        assert_eq!(got.len(), rows.len());
        for (r, g) in rows.iter().zip(&got) {
            assert_eq!(*g, enc.predict(r), "batched path diverged");
        }
    }

    #[test]
    fn single_thread_and_tiny_batches_work() {
        let enc = toy_encoded(13);
        let exec = NativeForestExecutor::with_parallelism(enc.clone(), 1, 64);
        let rows = random_rows(3, 14);
        let got = exec.predict(&rows).unwrap();
        assert_eq!(got[1], enc.predict(&rows[1]));
        assert!(exec.predict(&[]).unwrap().is_empty());
    }

    #[test]
    fn malformed_row_is_a_typed_error_not_a_panic() {
        let enc = toy_encoded(15);
        let exec = NativeForestExecutor::new(enc);
        let err = exec.predict(&[vec![0.0; NUM_FEATURES - 1]]).unwrap_err();
        assert!(format!("{err}").contains("expected"));
    }

    #[test]
    fn registry_routes_to_the_right_model_and_shares_tables() {
        let enc_a = toy_encoded(31);
        let enc_b = toy_encoded(37);
        let mut reg = ForestRegistry::new();
        reg.insert("m2090", enc_a.clone()).unwrap();
        reg.insert("k20", enc_b.clone()).unwrap();
        assert_eq!(reg.devices(), vec!["k20", "m2090"]); // sorted
        assert_eq!(reg.len(), 2);

        let rows = random_rows(32, 41);
        let ea = reg.executor_for("m2090").unwrap();
        let eb = reg.executor_for("k20").unwrap();
        for r in &rows {
            assert_eq!(ea.predict(&[r.clone()]).unwrap()[0], enc_a.predict(r));
            assert_eq!(eb.predict(&[r.clone()]).unwrap()[0], enc_b.predict(r));
        }
        // distinct models actually disagree somewhere
        assert!(
            rows.iter().any(|r| enc_a.predict(r) != enc_b.predict(r)),
            "toy forests were identical; routing untestable"
        );
        // unknown device -> None, not a panic
        assert!(reg.executor_for("gtx9000").is_none());
        // flat executors share one copy of the compiled tables...
        let again = reg.executor_for("m2090").unwrap();
        assert!(Arc::ptr_eq(again.flat(), reg.flat("m2090").unwrap()));
        // ...and the reference executor shares the encoded tables
        let refr = reg.reference_executor_for("m2090").unwrap();
        assert!(Arc::ptr_eq(&refr.forest, reg.get("m2090").unwrap()));
        // a corrupt encoding is rejected at insert time
        let mut bad = enc_a.clone();
        let split = (0..bad.left.len())
            .find(|&i| bad.left[i] as usize != i % bad.contract.max_nodes)
            .unwrap();
        bad.feat_idx[split] = -7;
        assert!(reg.insert("broken", bad).is_err());
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn joint_wg_prediction_matches_scalar_and_gates_on_arity() {
        let mut rng = Rng::new(23);
        let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
            .map(|_| (0..250).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..250).map(|i| if x[1][i] > 0.0 { 1.0 } else { -1.0 }).collect();
        let lw: Vec<f64> =
            (0..250).map(|i| if x[0][i] > 0.0 { 5.0 } else { 2.0 }).collect();
        let lh: Vec<f64> = vec![3.0; 250];
        let f = Forest::fit_multi(
            &x,
            &y,
            &[lw, lh],
            &ForestConfig { num_trees: 8, threads: 2, ..Default::default() },
        );
        let enc = encode(&f, ExportContract::default());
        let exec = NativeForestExecutor::with_parallelism(enc.clone(), 4, 16);
        assert_eq!(exec.num_outputs(), 3);
        let rows = random_rows(200, 24);
        let got = exec.predict_wg_logs(&rows).unwrap();
        assert_eq!(got.len(), rows.len());
        for (r, g) in rows.iter().zip(&got) {
            assert_eq!(*g, enc.predict_wg_logs(r).unwrap());
        }
        // width check still applies
        assert!(exec.predict_wg_logs(&[vec![0.0; NUM_FEATURES - 1]]).is_err());
        // single-output model -> typed error, not a panic
        let single = NativeForestExecutor::new(toy_encoded(11));
        assert_eq!(single.num_outputs(), 1);
        let err = single.predict_wg_logs(&rows[..1]).unwrap_err();
        assert!(format!("{err}").contains("joint"), "{err}");
    }

    #[test]
    fn telemetry_records_successful_batches_only() {
        let enc = toy_encoded(19);
        let sink = Arc::new(ExecTelemetry::new());
        let exec = NativeForestExecutor::new(enc).with_telemetry(sink.clone());
        exec.predict(&random_rows(32, 20)).unwrap();
        exec.predict_outputs(&random_rows(16, 21)).unwrap();
        assert!(exec.predict(&[vec![0.0; NUM_FEATURES - 1]]).is_err());
        assert_eq!(sink.rows(), 48, "failed batch must not count rows");
        assert_eq!(sink.batches(), 2);
        assert!(sink.rows_per_second() > 0.0);
    }

    #[test]
    fn decide_thresholds_at_zero() {
        let enc = toy_encoded(17);
        let exec = NativeForestExecutor::new(enc.clone());
        let rows = random_rows(64, 18);
        let decisions = exec.decide(&rows).unwrap();
        for (r, d) in rows.iter().zip(&decisions) {
            assert_eq!(*d, enc.predict(r) > 0.0);
        }
    }
}
