//! Inference runtime: the backend-agnostic [`executor::BatchExecutor`]
//! contract with its pure-rust implementations — the flattened
//! QuickScorer-style hot path ([`fastexec`], the default serving
//! backend) and the tensor-walking reference ([`executor`]) — plus the
//! PJRT path that loads the AOT HLO-text artifacts (L2 jax graphs
//! wrapping the L1 Pallas kernels) and executes them from the rust hot
//! path.
pub mod executor;
pub mod fastexec;
pub mod forest_exec;
pub mod pjrt;
pub mod stencil_exec;
