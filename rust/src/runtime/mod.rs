//! Inference runtime: the backend-agnostic [`executor::BatchExecutor`]
//! contract with its pure-rust implementation, plus the PJRT path that
//! loads the AOT HLO-text artifacts (L2 jax graphs wrapping the L1
//! Pallas kernels) and executes them from the rust hot path.
pub mod executor;
pub mod forest_exec;
pub mod pjrt;
pub mod stencil_exec;
