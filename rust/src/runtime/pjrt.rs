//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the crate touches the `xla` FFI. The compile
//! path (python/compile/aot.py) writes HLO *text* — the interchange
//! format that survives the jax>=0.5 / xla_extension 0.5.1 proto-id
//! mismatch — plus manifest.json describing shapes. Python never runs on
//! the request path: everything here is rust calling the PJRT C API.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape contract parsed from artifacts/manifest.json. Must agree with
/// `ml::export::ExportContract` before a forest can be served.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub num_trees: usize,
    pub max_nodes: usize,
    pub num_features: usize,
    pub max_depth: usize,
    pub forest_batch_sizes: Vec<usize>,
    pub artifacts: Vec<String>,
    pub stencil_img: usize,
    pub stencil_radius: usize,
    pub stencil_patterns: BTreeMap<String, usize>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!(e))?;
        let get = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing {k}"))
        };
        let stencil = j.get("stencil").context("manifest missing stencil")?;
        let patterns = match stencil.get("patterns") {
            Some(Json::Obj(m)) => m
                .iter()
                .filter_map(|(k, v)| v.as_usize().map(|n| (k.clone(), n)))
                .collect(),
            _ => BTreeMap::new(),
        };
        Ok(Manifest {
            num_trees: get("num_trees")?,
            max_nodes: get("max_nodes")?,
            num_features: get("num_features")?,
            max_depth: get("max_depth")?,
            forest_batch_sizes: j
                .get("forest_batch_sizes")
                .and_then(Json::as_arr)
                .context("manifest missing forest_batch_sizes")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            artifacts: j
                .get("artifacts")
                .and_then(Json::as_arr)
                .context("manifest missing artifacts")?
                .iter()
                .filter_map(|a| a.as_str().map(String::from))
                .collect(),
            stencil_img: stencil.get("img").and_then(Json::as_usize).unwrap_or(0),
            stencil_radius: stencil
                .get("radius")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            stencil_patterns: patterns,
        })
    }
}

/// A compiled executable + its human name.
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT engine: one CPU client, a cache of compiled executables keyed by
/// artifact file name.
pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, std::sync::Arc<LoadedExe>>>,
}

// The xla handles are opaque C++ objects behind pointers; the PJRT CPU
// client serializes execution internally. We gate compile/execute through
// &self with internal locking where needed.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifact_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact by file name.
    fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedExe>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(name);
        if !path.exists() {
            bail!("artifact {} not found (run `make artifacts`)", path.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        let arc = std::sync::Arc::new(LoadedExe { exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Eagerly compile every artifact (warm start for serving).
    pub fn warmup(&self) -> Result<usize> {
        let names = self.manifest.artifacts.clone();
        for n in &names {
            self.load(n)?;
        }
        Ok(names.len())
    }

    /// Execute an artifact with literal inputs; returns the tuple fields
    /// of the (return_tuple=True) result.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.load(name)?;
        let result = exe
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {name}"))?[0][0]
            .to_literal_sync()?;
        let tuple = result.to_tuple()?;
        Ok(tuple)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.num_features, crate::kernelmodel::features::NUM_FEATURES);
        assert!(!m.forest_batch_sizes.is_empty());
        assert!(!m.artifacts.is_empty());
    }

    #[test]
    fn engine_compiles_and_runs_forest_artifact() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let eng = Engine::new(&artifacts_dir()).unwrap();
        let m = &eng.manifest;
        let b = m.forest_batch_sizes[0];
        let t = m.num_trees;
        let n = m.max_nodes;
        // Trivial forest: every tree is a single self-looping leaf 0.5.
        let feats = xla::Literal::vec1(&vec![0f32; b * m.num_features])
            .reshape(&[b as i64, m.num_features as i64])
            .unwrap();
        let fi = xla::Literal::vec1(&vec![0i32; t * n])
            .reshape(&[t as i64, n as i64])
            .unwrap();
        let th = xla::Literal::vec1(&vec![0f32; t * n])
            .reshape(&[t as i64, n as i64])
            .unwrap();
        let self_loop: Vec<i32> =
            (0..t).flat_map(|_| (0..n as i32).collect::<Vec<_>>()).collect();
        let lt = xla::Literal::vec1(&self_loop)
            .reshape(&[t as i64, n as i64])
            .unwrap();
        let rt = xla::Literal::vec1(&self_loop)
            .reshape(&[t as i64, n as i64])
            .unwrap();
        let lf = xla::Literal::vec1(&vec![0.5f32; t * n])
            .reshape(&[t as i64, n as i64])
            .unwrap();
        let out = eng
            .execute(&format!("forest_b{b}.hlo.txt"), &[feats, fi, th, lt, rt, lf])
            .unwrap();
        let preds = out[0].to_vec::<f32>().unwrap();
        assert_eq!(preds.len(), b);
        for p in preds {
            assert!((p - 0.5).abs() < 1e-6, "{p}");
        }
    }
}
