//! Batched forest inference through PJRT: the artifact-backed hot path.
//!
//! Holds the tensor-encoded forest as pre-built XLA literals (built once;
//! ~6 MB reused across calls) and routes each batch to the smallest
//! compiled batch-size variant that fits, padding with zeros. Owns an
//! `Arc<Engine>` so service workers can hold one executor per shard.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::ml::export::EncodedForest;

use super::executor::BatchExecutor;
use super::pjrt::Engine;

pub struct ForestExecutor {
    engine: Arc<Engine>,
    feats_dim: usize,
    batch_sizes: Vec<usize>,
    // Pre-built forest literals, reused every call.
    fi: xla::Literal,
    th: xla::Literal,
    lt: xla::Literal,
    rt: xla::Literal,
    lf: xla::Literal,
}

impl ForestExecutor {
    pub fn new(engine: Arc<Engine>, forest: &EncodedForest) -> Result<Self> {
        let m = &engine.manifest;
        ensure!(
            forest.contract.num_trees == m.num_trees
                && forest.contract.max_nodes == m.max_nodes
                && forest.contract.num_features == m.num_features
                && forest.contract.max_depth <= m.max_depth,
            "forest contract {:?} does not match artifact manifest \
             (trees={}, nodes={}, features={}, depth={})",
            forest.contract,
            m.num_trees,
            m.max_nodes,
            m.num_features,
            m.max_depth
        );
        let t = m.num_trees as i64;
        let n = m.max_nodes as i64;
        let shape = [t, n];
        let mut sizes = m.forest_batch_sizes.clone();
        sizes.sort_unstable();
        ensure!(!sizes.is_empty(), "manifest lists no forest batch sizes");
        let feats_dim = m.num_features;
        let fi = xla::Literal::vec1(&forest.feat_idx).reshape(&shape)?;
        let th = xla::Literal::vec1(&forest.thresh).reshape(&shape)?;
        let lt = xla::Literal::vec1(&forest.left).reshape(&shape)?;
        let rt = xla::Literal::vec1(&forest.right).reshape(&shape)?;
        let lf = xla::Literal::vec1(&forest.leaf).reshape(&shape)?;
        Ok(ForestExecutor {
            engine,
            feats_dim,
            batch_sizes: sizes,
            fi,
            th,
            lt,
            rt,
            lf,
        })
    }

    /// Largest compiled batch variant.
    pub fn max_batch(&self) -> usize {
        *self.batch_sizes.last().unwrap()
    }

    /// Pick the smallest variant that holds `n` rows.
    pub fn route(&self, n: usize) -> usize {
        for &b in &self.batch_sizes {
            if n <= b {
                return b;
            }
        }
        self.max_batch()
    }

    /// Predict log2(speedup) for a batch of feature vectors. Batches
    /// larger than the biggest variant are chunked.
    pub fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(rows.len());
        let maxb = self.max_batch();
        for chunk in rows.chunks(maxb) {
            out.extend(self.predict_chunk(chunk)?);
        }
        Ok(out)
    }

    fn predict_chunk(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let b = self.route(rows.len());
        let mut flat = vec![0f32; b * self.feats_dim];
        for (i, r) in rows.iter().enumerate() {
            ensure!(
                r.len() == self.feats_dim,
                "feature vector has {} dims, expected {}",
                r.len(),
                self.feats_dim
            );
            for (j, &x) in r.iter().enumerate() {
                flat[i * self.feats_dim + j] = x as f32;
            }
        }
        let feats = xla::Literal::vec1(&flat)
            .reshape(&[b as i64, self.feats_dim as i64])
            .context("reshape features")?;
        let outs = self.engine.execute(
            &format!("forest_b{b}.hlo.txt"),
            &[
                feats,
                self.fi.clone(),
                self.th.clone(),
                self.lt.clone(),
                self.rt.clone(),
                self.lf.clone(),
            ],
        )?;
        let preds = outs[0].to_vec::<f32>()?;
        Ok(preds[..rows.len()].iter().map(|&x| x as f64).collect())
    }

    /// The auto-tuning decisions for a batch.
    pub fn decide(&self, rows: &[Vec<f64>]) -> Result<Vec<bool>> {
        Ok(self.predict(rows)?.into_iter().map(|p| p > 0.0).collect())
    }
}

impl BatchExecutor for ForestExecutor {
    fn backend(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        ForestExecutor::max_batch(self)
    }

    fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        ForestExecutor::predict(self, rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::export::{encode, ExportContract};
    use crate::ml::forest::{Forest, ForestConfig};
    use crate::util::prng::Rng;

    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn pjrt_matches_native_encoded_predictions() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
        // Train a small real forest on random data.
        let nf = crate::kernelmodel::features::NUM_FEATURES;
        let mut rng = Rng::new(44);
        let x: Vec<Vec<f64>> = (0..nf)
            .map(|_| (0..500).map(|_| rng.range_f64(-2.0, 2.0)).collect())
            .collect();
        let y: Vec<f64> = (0..500)
            .map(|i| if x[0][i] * x[5][i] > 0.0 { 1.0 } else { -1.0 })
            .collect();
        let forest = Forest::fit(
            &x,
            &y,
            &ForestConfig { num_trees: 20, threads: 1, ..Default::default() },
        );
        let contract = ExportContract {
            num_trees: engine.manifest.num_trees,
            max_nodes: engine.manifest.max_nodes,
            max_depth: engine.manifest.max_depth,
            num_features: nf,
        };
        let enc = encode(&forest, contract);
        let exec = ForestExecutor::new(engine, &enc).unwrap();

        let rows: Vec<Vec<f64>> = (0..100)
            .map(|_| (0..nf).map(|_| rng.range_f64(-2.0, 2.0)).collect())
            .collect();
        let got = exec.predict(&rows).unwrap();
        for (r, g) in rows.iter().zip(&got) {
            let want = enc.predict(r);
            assert!((g - want).abs() < 1e-4, "{g} vs {want}");
        }
    }

    #[test]
    fn routing_picks_smallest_fit() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Arc::new(Engine::new(&artifacts_dir()).unwrap());
        let contract = ExportContract {
            num_trees: engine.manifest.num_trees,
            max_nodes: engine.manifest.max_nodes,
            max_depth: engine.manifest.max_depth,
            num_features: engine.manifest.num_features,
        };
        // single-leaf forest
        let forest = Forest {
            trees: vec![
                crate::ml::tree::Tree {
                    nodes: vec![crate::ml::tree::Node::Leaf { value: 0.0 }]
                };
                contract.num_trees
            ],
            config_summary: String::new(),
        };
        let enc = encode(&forest, contract);
        let exec = ForestExecutor::new(engine, &enc).unwrap();
        assert_eq!(exec.route(1), 64);
        assert_eq!(exec.route(64), 64);
        assert_eq!(exec.route(65), 256);
        assert_eq!(exec.route(5000), exec.max_batch());
    }
}
