//! QuickScorer-style flattened forest inference — the serving hot path.
//!
//! [`FlatForest`] is compiled once from the tensor-encoded forest
//! (`ml::export::EncodedForest`) into a cache-friendly SoA layout:
//!
//!   * the padded `[T, max_nodes]` self-looping node tables are
//!     **compacted to live nodes** (reachable from each root), so the
//!     whole forest sits in a few contiguous arrays of `u16`/`u32`/`f32`
//!     instead of megabytes of mostly-padding tensors;
//!   * padded all-zero trees are dropped entirely (they contribute
//!     exactly 0.0 to every output sum — the `num_trees` divisor keeps
//!     the padded-tree scale correction intact);
//!   * all K output planes are stored **leaf-major with stride K**
//!     (`leaf[node*K + k]`), so one traversal gathers the verdict AND
//!     the workgroup planes of a joint (schema v2) model;
//!   * traversal is branchless (`kids[n][go_right as usize]`) and walks
//!     a fixed per-tree depth — leaves self-loop, so over-walking is
//!     exact — with trees processed in lockstep groups of four so the
//!     data-dependent loads of independent walks pipeline;
//!   * the batch loops iterate rows over contiguous per-row feature
//!     blocks (each row is converted/binned once into a flat scratch
//!     buffer), which keeps the prologue autovectorizable and the walk
//!     loop free of `f64 -> f32` conversions.
//!
//! # The quantized path and its exactness contract
//!
//! The QuickScorer / histogram-GBM trick: reuse `ml::binning`'s ≤256-cut
//! machinery to turn every node comparison into a `u8` compare. Per
//! feature, the distinct (f32) split thresholds of the forest form a cut
//! table ([`crate::ml::binning::FeatureBins`]); a row is binned once per
//! feature (`code_of`, NaN → last bin) and each split stores the bin
//! index of its threshold, so `x_f32 <= thresh` becomes
//! `code[feat] <= qthresh[node]` (the `FeatureBins` invariant
//! `code(x) <= b  iff  x <= cuts[b]`).
//!
//! * **Bit-equivalent** to the float path whenever every threshold is
//!   representable in its feature's cut table — i.e. each feature has at
//!   most 255 distinct thresholds ([`FlatForest::quantized_exact`]).
//!   Forests trained with the default binned split engine satisfy this
//!   by construction: their candidate thresholds are drawn from ≤256
//!   quantile bins per feature. Equivalence covers NaN (right, like the
//!   reference's `NaN <= t == false`) and ±inf rows.
//! * **Decision-equivalent otherwise**: a feature with more than 255
//!   distinct thresholds gets a quantile-reduced table
//!   (`FeatureBins::from_column` over the threshold set) and each
//!   threshold snaps to the nearest representable cut at or below it.
//!   Rows route identically unless a feature value falls between a
//!   snapped cut and its true threshold; every row still routes
//!   deterministically to a real leaf and never panics. Because of that
//!   residual drift, [`FlatMode::Auto`] (the executor default) only
//!   takes the quantized path when the tables are exact.
//!
//! The float path replicates the reference traversal semantics exactly:
//! features are rounded `f64 -> f32` before the `<=` compare (NaN routes
//! right), per-plane sums accumulate in tree order as `f64`, and the
//! final division is by the contract's `num_trees`.
//!
//! [`FlatForestExecutor`] wraps the compiled forest behind the
//! [`BatchExecutor`] trait (chunked `parallel_map` parallelism, typed
//! errors on malformed batches) and is the default serving backend; the
//! original [`super::executor::NativeForestExecutor`] remains as the
//! reference implementation the differential suite
//! (`rust/tests/infexec.rs`) checks against.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::ml::binning::{FeatureBins, MAX_BINS};
use crate::ml::export::EncodedForest;
use crate::util::pool::parallel_map;

use super::executor::BatchExecutor;

/// Cut-table capacity per feature: codes must fit a `u8` with the NaN
/// bin (`code == cuts.len()`) still representable, so at most 255 cuts.
const MAX_QUANT_CUTS: usize = 255;

/// Trees walked in lockstep per group (hides node-load latency).
const TREE_GROUP: usize = 4;

/// Which traversal kernel [`FlatForestExecutor`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlatMode {
    /// Quantized when the cut tables are exact, float otherwise. The
    /// default: never trades accuracy for speed.
    Auto,
    /// Always the f32-compare path (bit-equal to the reference).
    Float,
    /// Always the u8-compare path — approximate when
    /// [`FlatForest::quantized_exact`] is false.
    Quantized,
}

/// One tree of the compacted forest: its root node and the fixed walk
/// depth (max leaf depth; self-looping leaves make over-walking exact).
#[derive(Clone, Copy, Debug)]
struct FlatTree {
    root: u32,
    depth: u32,
}

/// The compiled forest: compacted SoA node tables + quantization tables.
/// Build once with [`FlatForest::compile`], share via `Arc` across
/// service shards.
#[derive(Clone, Debug)]
pub struct FlatForest {
    num_features: usize,
    /// Outputs per prediction (1 + extra planes).
    num_outputs: usize,
    /// The contract's tree count — the mean's divisor, which may exceed
    /// `trees.len()` when padded zero trees were dropped.
    num_trees: usize,
    trees: Vec<FlatTree>,
    /// Per-node split feature (leaves: 0, never routing anywhere).
    feat: Vec<u16>,
    /// Per-node split threshold, f32 exactly as encoded.
    thresh: Vec<f32>,
    /// Per-node `[left, right]`; leaves self-loop.
    kids: Vec<[u32; 2]>,
    /// Leaf-major output planes, stride `num_outputs`; split nodes 0.
    leaf: Vec<f32>,
    /// Per-feature cut tables for the quantized path.
    bins: Vec<FeatureBins>,
    /// Per-node threshold bin index (`x <= thresh  iff  code <= qthresh`
    /// when the table is exact).
    qthresh: Vec<u8>,
    /// True iff every threshold is representable in its cut table.
    quant_exact: bool,
}

impl FlatForest {
    /// Compile the encoded forest. Validates the encoding first, so a
    /// corrupt model (out-of-range feature index, non-finite threshold,
    /// malformed children) is a typed error here instead of a panic or
    /// a misprediction on the hot path.
    pub fn compile(enc: &EncodedForest) -> Result<FlatForest> {
        enc.validate().map_err(|e| anyhow!("invalid encoded forest: {e}"))?;
        let contract = enc.contract;
        anyhow::ensure!(
            contract.num_features > 0 && contract.num_features <= u16::MAX as usize,
            "contract num_features {} not in 1..={}",
            contract.num_features,
            u16::MAX
        );
        let n = contract.max_nodes;
        let k = 1 + enc.extra.len();

        let mut flat = FlatForest {
            num_features: contract.num_features,
            num_outputs: k,
            num_trees: contract.num_trees,
            trees: Vec::new(),
            feat: Vec::new(),
            thresh: Vec::new(),
            kids: Vec::new(),
            leaf: Vec::new(),
            bins: Vec::new(),
            qthresh: Vec::new(),
            quant_exact: true,
        };

        // Compact each tree: DFS from the root, keeping only reachable
        // nodes. `validate` bounded every reachable path by max_depth,
        // so the walk terminates.
        let mut slot = vec![u32::MAX; n]; // encoded index -> flat index, per tree
        for t in 0..contract.num_trees {
            let base = t * n;
            // Padded (or genuinely zero) single-leaf trees contribute
            // exactly 0.0 to every output sum: drop them. The divisor
            // stays `contract.num_trees`, preserving the scale
            // correction baked into the remaining leaves.
            let root_is_leaf =
                enc.left[base] as usize == 0 && enc.right[base] as usize == 0;
            if root_is_leaf {
                let all_zero = enc.leaf[base] == 0.0
                    && enc.extra.iter().all(|p| p[base] == 0.0);
                if all_zero {
                    continue;
                }
            }
            for s in slot.iter_mut() {
                *s = u32::MAX;
            }
            let root = flat.kids.len() as u32;
            let mut depth = 0u32;
            // (encoded index, depth); allocate flat slots in DFS order.
            let mut stack = vec![(0usize, 0u32)];
            slot[0] = root;
            flat.push_node(enc, base, 0, k);
            while let Some((i, d)) = stack.pop() {
                depth = depth.max(d);
                let (l, r) = (enc.left[base + i] as usize, enc.right[base + i] as usize);
                if l == i && r == i {
                    continue; // leaf (already pushed, self-loops below)
                }
                for &c in &[l, r] {
                    if slot[c] == u32::MAX {
                        slot[c] = flat.kids.len() as u32;
                        flat.push_node(enc, base, c, k);
                        stack.push((c, d + 1));
                    }
                }
                let fi = slot[i] as usize;
                flat.kids[fi] = [slot[l], slot[r]];
            }
            flat.trees.push(FlatTree { root, depth });
        }
        anyhow::ensure!(
            flat.kids.len() <= u32::MAX as usize,
            "forest too large for u32 node indices"
        );

        flat.build_quant_tables();
        Ok(flat)
    }

    /// Append one node with self-looping children (splits get their real
    /// children patched in by the caller).
    fn push_node(&mut self, enc: &EncodedForest, base: usize, i: usize, k: usize) {
        let id = self.kids.len() as u32;
        let is_leaf = enc.left[base + i] as usize == i && enc.right[base + i] as usize == i;
        // Leaves keep feat 0 / thresh 0.0: the fixed-depth walk still
        // "compares" at them, but both children are the node itself.
        self.feat.push(if is_leaf { 0 } else { enc.feat_idx[base + i] as u16 });
        self.thresh.push(if is_leaf { 0.0 } else { enc.thresh[base + i] });
        self.kids.push([id, id]);
        self.leaf.push(if is_leaf { enc.leaf[base + i] } else { 0.0 });
        for plane in &enc.extra {
            self.leaf.push(if is_leaf { plane[base + i] } else { 0.0 });
        }
        debug_assert_eq!(self.leaf.len(), (id as usize + 1) * k);
    }

    /// Per-feature cut tables from the forest's own thresholds: exact
    /// (the distinct f32 thresholds, as f64) when they fit 255 cuts,
    /// quantile-reduced via `ml::binning` otherwise.
    fn build_quant_tables(&mut self) {
        let mut per_feat: Vec<Vec<f64>> = vec![Vec::new(); self.num_features];
        for i in 0..self.kids.len() {
            if self.kids[i][0] as usize != i {
                per_feat[self.feat[i] as usize].push(self.thresh[i] as f64);
            }
        }
        self.quant_exact = true;
        let mut exact_feat = vec![true; self.num_features];
        self.bins = per_feat
            .iter()
            .enumerate()
            .map(|(f, vals)| {
                let mut distinct = vals.clone();
                distinct.sort_unstable_by(f64::total_cmp);
                distinct.dedup();
                if distinct.len() <= MAX_QUANT_CUTS {
                    FeatureBins { cuts: distinct }
                } else {
                    self.quant_exact = false;
                    exact_feat[f] = false;
                    FeatureBins::from_column(&distinct, MAX_BINS)
                }
            })
            .collect();
        self.qthresh = (0..self.kids.len())
            .map(|i| {
                if self.kids[i][0] as usize == i {
                    return 0; // leaf: compared but never routes away
                }
                let f = self.feat[i] as usize;
                let t = self.thresh[i] as f64;
                let cuts = &self.bins[f].cuts;
                let b = if exact_feat[f] {
                    // Index of the threshold itself (total_cmp dedup may
                    // keep -0.0 for a 0.0 threshold; `c < t` is false
                    // across the ±0.0 pair, so the lookup still lands on
                    // the equal cut).
                    cuts.partition_point(|&c| c < t)
                } else {
                    // Nearest representable cut at or below t (clamped):
                    // rows with a feature value between cuts[b] and t
                    // may route differently — the documented
                    // decision-drift of the lossy path.
                    cuts.partition_point(|&c| c <= t).saturating_sub(1)
                };
                debug_assert!(b < cuts.len().max(1));
                b as u8
            })
            .collect();
    }

    pub fn num_features(&self) -> usize {
        self.num_features
    }

    /// Outputs per prediction (1 = verdict only, 3 = joint).
    pub fn num_outputs(&self) -> usize {
        self.num_outputs
    }

    /// Live (compacted) node count across all trees.
    pub fn num_nodes(&self) -> usize {
        self.kids.len()
    }

    /// Trees actually walked (padded zero trees are dropped).
    pub fn num_live_trees(&self) -> usize {
        self.trees.len()
    }

    /// True iff the quantized path is bit-equivalent to the float path
    /// (every threshold representable in its feature's cut table).
    pub fn quantized_exact(&self) -> bool {
        self.quant_exact
    }

    /// Resolve [`FlatMode::Auto`] against the exactness of the tables.
    pub fn resolve_mode(&self, mode: FlatMode) -> FlatMode {
        match mode {
            FlatMode::Auto if self.quant_exact => FlatMode::Quantized,
            FlatMode::Auto => FlatMode::Float,
            m => m,
        }
    }

    /// Accumulate all K outputs of one row into `out` (float path).
    /// `xf` is the row pre-rounded to f32 — the reference traversal's
    /// `features[fi] as f32` done once per row instead of per node.
    fn row_outputs_float(&self, xf: &[f32], out: &mut [f64]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut ti = 0;
        while ti + TREE_GROUP <= self.trees.len() {
            let g: [FlatTree; TREE_GROUP] =
                [self.trees[ti], self.trees[ti + 1], self.trees[ti + 2], self.trees[ti + 3]];
            let mut n = [
                g[0].root as usize,
                g[1].root as usize,
                g[2].root as usize,
                g[3].root as usize,
            ];
            let d = g.iter().map(|t| t.depth).max().unwrap_or(0);
            for _ in 0..d {
                for nj in n.iter_mut() {
                    let f = self.feat[*nj] as usize;
                    // NaN: `<=` is false -> right, like the reference.
                    let go_right = !(xf[f] <= self.thresh[*nj]);
                    *nj = self.kids[*nj][go_right as usize] as usize;
                }
            }
            for &nj in &n {
                self.gather(nj, out);
            }
            ti += TREE_GROUP;
        }
        while ti < self.trees.len() {
            let t = self.trees[ti];
            let mut nj = t.root as usize;
            for _ in 0..t.depth {
                let f = self.feat[nj] as usize;
                let go_right = !(xf[f] <= self.thresh[nj]);
                nj = self.kids[nj][go_right as usize] as usize;
            }
            self.gather(nj, out);
            ti += 1;
        }
        let trees = self.num_trees as f64;
        out.iter_mut().for_each(|v| *v /= trees);
    }

    /// Accumulate all K outputs of one row into `out` (quantized path).
    /// `codes` is the row binned once per feature.
    fn row_outputs_quant(&self, codes: &[u8], out: &mut [f64]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        let mut ti = 0;
        while ti + TREE_GROUP <= self.trees.len() {
            let g: [FlatTree; TREE_GROUP] =
                [self.trees[ti], self.trees[ti + 1], self.trees[ti + 2], self.trees[ti + 3]];
            let mut n = [
                g[0].root as usize,
                g[1].root as usize,
                g[2].root as usize,
                g[3].root as usize,
            ];
            let d = g.iter().map(|t| t.depth).max().unwrap_or(0);
            for _ in 0..d {
                for nj in n.iter_mut() {
                    let f = self.feat[*nj] as usize;
                    let go_right = codes[f] > self.qthresh[*nj];
                    *nj = self.kids[*nj][go_right as usize] as usize;
                }
            }
            for &nj in &n {
                self.gather(nj, out);
            }
            ti += TREE_GROUP;
        }
        while ti < self.trees.len() {
            let t = self.trees[ti];
            let mut nj = t.root as usize;
            for _ in 0..t.depth {
                let f = self.feat[nj] as usize;
                let go_right = codes[f] > self.qthresh[nj];
                nj = self.kids[nj][go_right as usize] as usize;
            }
            self.gather(nj, out);
            ti += 1;
        }
        let trees = self.num_trees as f64;
        out.iter_mut().for_each(|v| *v /= trees);
    }

    #[inline]
    fn gather(&self, node: usize, out: &mut [f64]) {
        let base = node * self.num_outputs;
        for (o, v) in out.iter_mut().enumerate() {
            *v += self.leaf[base + o] as f64;
        }
    }

    /// All K outputs for every row, row-major (`rows.len() * K`). Rows
    /// must already be width-checked (the executor's job); `mode` is
    /// resolved against the table exactness.
    pub fn predict_outputs_batch(&self, rows: &[Vec<f64>], mode: FlatMode) -> Vec<f64> {
        let k = self.num_outputs;
        let mut out = vec![0.0f64; rows.len() * k];
        match self.resolve_mode(mode) {
            FlatMode::Quantized => {
                let mut codes = vec![0u8; self.num_features];
                for (row, slot) in rows.iter().zip(out.chunks_mut(k)) {
                    for (c, (&x, fb)) in
                        codes.iter_mut().zip(row.iter().zip(&self.bins))
                    {
                        *c = fb.code_of((x as f32) as f64);
                    }
                    self.row_outputs_quant(&codes, slot);
                }
            }
            _ => {
                let mut xf = vec![0.0f32; self.num_features];
                for (row, slot) in rows.iter().zip(out.chunks_mut(k)) {
                    for (v, &x) in xf.iter_mut().zip(row.iter()) {
                        *v = x as f32;
                    }
                    self.row_outputs_float(&xf, slot);
                }
            }
        }
        out
    }

    /// Scalar convenience (eval/analyze): all K outputs of one row in
    /// Auto mode.
    pub fn predict_row(&self, row: &[f64]) -> Vec<f64> {
        self.predict_outputs_batch(&[row.to_vec()], FlatMode::Auto)
    }

    /// Scalar verdict: predicted log2(speedup) > 0.
    pub fn decide_row(&self, row: &[f64]) -> bool {
        self.predict_row(row)[0] > 0.0
    }
}

/// The default [`BatchExecutor`] backend: a compiled [`FlatForest`]
/// behind an `Arc` (service shards share one copy), chunked parallelism
/// over `util::pool::parallel_map`, typed errors on malformed batches —
/// the same contract (and error text) as the reference
/// `NativeForestExecutor`.
pub struct FlatForestExecutor {
    flat: Arc<FlatForest>,
    threads: usize,
    /// Rows per parallel work item; small batches stay single-threaded.
    chunk_rows: usize,
    mode: FlatMode,
    /// Optional shared sink for rows/sec + batch-size distributions;
    /// `None` (the default) costs one branch per batch, which is what
    /// keeps the `perf_inference` telemetry-overhead section <= 3%.
    telemetry: Option<Arc<crate::obs::metrics::ExecTelemetry>>,
}

impl FlatForestExecutor {
    /// Compile and wrap, sized to the host. Fails (typed) on a corrupt
    /// encoding.
    pub fn new(enc: &EncodedForest) -> Result<Self> {
        Ok(Self::from_shared(Arc::new(FlatForest::compile(enc)?)))
    }

    /// Share one compiled forest across several executors (one per
    /// service shard).
    pub fn from_shared(flat: Arc<FlatForest>) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        FlatForestExecutor {
            flat,
            threads: threads.max(1),
            chunk_rows: 256,
            mode: FlatMode::Auto,
            telemetry: None,
        }
    }

    pub fn with_parallelism(flat: Arc<FlatForest>, threads: usize, chunk_rows: usize) -> Self {
        FlatForestExecutor {
            flat,
            threads: threads.max(1),
            chunk_rows: chunk_rows.max(1),
            mode: FlatMode::Auto,
            telemetry: None,
        }
    }

    /// Record every successful batch (rows, wall time) into `sink`;
    /// share one sink across shards to see the whole backend's rows/sec
    /// and batch-size distribution.
    pub fn with_telemetry(mut self, sink: Arc<crate::obs::metrics::ExecTelemetry>) -> Self {
        self.telemetry = Some(sink);
        self
    }

    /// Cap this executor's parallelism (e.g. divide the host's cores
    /// across service shards).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Force a traversal kernel (benches/differential tests); the
    /// default `Auto` never runs an inexact quantized table.
    pub fn mode(mut self, mode: FlatMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn flat(&self) -> &Arc<FlatForest> {
        &self.flat
    }

    fn check_rows(&self, rows: &[Vec<f64>]) -> Result<()> {
        let nf = self.flat.num_features;
        for (i, r) in rows.iter().enumerate() {
            if r.len() != nf {
                return Err(anyhow!(
                    "row {i}: feature vector has {} dims, expected {nf}",
                    r.len()
                ));
            }
        }
        Ok(())
    }

    /// All outputs row-major, chunk-parallel. The one traversal per row
    /// feeds every plane, so joint serving never re-walks the forest.
    /// Every public prediction entry point funnels through here, so this
    /// is also where the optional telemetry sink observes batches.
    fn outputs(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let started = self.telemetry.as_ref().map(|_| std::time::Instant::now());
        let out = self.outputs_inner(rows);
        if let (Some(sink), Some(t0), Ok(_)) = (&self.telemetry, started, &out) {
            sink.record_batch(rows.len(), t0.elapsed());
        }
        out
    }

    fn outputs_inner(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.check_rows(rows)?;
        if self.threads <= 1 || rows.len() < 2 * self.chunk_rows {
            return Ok(self.flat.predict_outputs_batch(rows, self.mode));
        }
        let chunks: Vec<&[Vec<f64>]> = rows.chunks(self.chunk_rows).collect();
        let nested = parallel_map(&chunks, self.threads, |chunk| {
            self.flat.predict_outputs_batch(chunk, self.mode)
        });
        Ok(nested.into_iter().flatten().collect())
    }

    /// Batched joint prediction: (log2 wg_w, log2 wg_h) per row; typed
    /// `Err` for single-output models or malformed rows (same contract
    /// as the reference executor).
    pub fn predict_wg_logs(&self, rows: &[Vec<f64>]) -> Result<Vec<(f64, f64)>> {
        if self.flat.num_outputs() < 3 {
            return Err(anyhow!(
                "model has {} output(s); workgroup prediction needs a joint \
                 (schema v2) model",
                self.flat.num_outputs()
            ));
        }
        let k = self.flat.num_outputs();
        let out = self.outputs(rows)?;
        Ok(out.chunks(k).map(|c| (c[1], c[2])).collect())
    }
}

impl BatchExecutor for FlatForestExecutor {
    fn backend(&self) -> &'static str {
        match self.flat.resolve_mode(self.mode) {
            FlatMode::Quantized => "flat-q",
            _ => "flat",
        }
    }

    fn max_batch(&self) -> usize {
        usize::MAX
    }

    fn predict(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        let k = self.flat.num_outputs();
        let out = self.outputs(rows)?;
        Ok(out.chunks(k).map(|c| c[0]).collect())
    }

    fn num_outputs(&self) -> usize {
        self.flat.num_outputs()
    }

    fn predict_outputs(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
        self.outputs(rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;
    use crate::ml::export::{encode, ExportContract};
    use crate::ml::forest::{Forest, ForestConfig};
    use crate::util::prng::Rng;

    fn toy_encoded(seed: u64, trees: usize, contract: ExportContract) -> EncodedForest {
        let mut rng = Rng::new(seed);
        let x: Vec<Vec<f64>> = (0..NUM_FEATURES)
            .map(|_| (0..300).map(|_| rng.range_f64(-1.0, 1.0)).collect())
            .collect();
        let y: Vec<f64> =
            (0..300).map(|i| if x[1][i] + x[4][i] > 0.0 { 1.0 } else { -1.0 }).collect();
        let f = Forest::fit(
            &x,
            &y,
            &ForestConfig { num_trees: trees, threads: 2, ..Default::default() },
        );
        encode(&f, contract)
    }

    #[test]
    fn compaction_drops_padding_and_matches_reference() {
        // 5 real trees under a 20-tree contract: 15 padded zero trees
        // must be dropped, the rest compacted to live nodes only.
        let enc = toy_encoded(3, 5, ExportContract::default());
        let flat = FlatForest::compile(&enc).unwrap();
        assert_eq!(flat.num_live_trees(), 5);
        assert!(flat.num_nodes() < enc.contract.max_nodes); // vs 20*8192 slots
        assert_eq!(flat.num_outputs(), 1);
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let row: Vec<f64> =
                (0..NUM_FEATURES).map(|_| rng.range_f64(-2.0, 2.0)).collect();
            let out = flat.predict_outputs_batch(&[row.clone()], FlatMode::Float);
            assert_eq!(out[0], enc.predict(&row), "float path diverged");
        }
    }

    #[test]
    fn quantized_tables_are_exact_for_binned_forests_and_bit_equal() {
        // Default ForestConfig trains with the binned engine: thresholds
        // come from <=256 cuts per feature, so the tables must be exact
        // and the quantized path bit-equal to the float path.
        let enc = toy_encoded(7, 8, ExportContract::default());
        let flat = FlatForest::compile(&enc).unwrap();
        assert!(flat.quantized_exact());
        assert_eq!(flat.resolve_mode(FlatMode::Auto), FlatMode::Quantized);
        let mut rng = Rng::new(8);
        let rows: Vec<Vec<f64>> = (0..500)
            .map(|_| (0..NUM_FEATURES).map(|_| rng.range_f64(-3.0, 3.0)).collect())
            .collect();
        let fl = flat.predict_outputs_batch(&rows, FlatMode::Float);
        let qu = flat.predict_outputs_batch(&rows, FlatMode::Quantized);
        assert_eq!(fl, qu, "exact quantized path must be bit-equal");
    }

    #[test]
    fn compile_rejects_corrupt_encodings() {
        let mut enc = toy_encoded(9, 4, ExportContract::default());
        let split = (0..enc.left.len())
            .find(|&i| enc.left[i] as usize != i % enc.contract.max_nodes)
            .unwrap();
        enc.feat_idx[split] = NUM_FEATURES as i32 + 3;
        let err = FlatForestExecutor::new(&enc).err().expect("must reject");
        assert!(format!("{err}").contains("feature index"), "{err}");
    }

    #[test]
    fn executor_error_parity_and_backend_names() {
        let enc = toy_encoded(11, 4, ExportContract::default());
        let exec = FlatForestExecutor::new(&enc).unwrap();
        assert_eq!(exec.backend(), "flat-q"); // exact tables -> quantized
        assert_eq!(exec.mode(FlatMode::Float).backend(), "flat");
        let exec = FlatForestExecutor::new(&enc).unwrap();
        assert!(exec.predict(&[]).unwrap().is_empty());
        let err = exec.predict(&[vec![0.0; NUM_FEATURES - 1]]).unwrap_err();
        assert!(format!("{err}").contains("expected"), "{err}");
        let err = exec.predict_wg_logs(&[vec![0.0; NUM_FEATURES]]).unwrap_err();
        assert!(format!("{err}").contains("joint"), "{err}");
    }
}
