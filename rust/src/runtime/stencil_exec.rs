//! Execute the synthetic-template stencil compute (L1 Pallas kernel,
//! AOT-lowered) through PJRT — proving template instances are real
//! computations, and giving the examples a functional cross-language
//! numerics check against a rust-native reference.

use anyhow::{ensure, Context, Result};

use crate::kernelmodel::stencil::StencilPattern;

use super::pjrt::Engine;

pub struct StencilExecutor<'e> {
    engine: &'e Engine,
    pub img: usize,
    pub radius: usize,
}

#[derive(Debug)]
pub struct StencilRun {
    pub output: Vec<f32>,
    pub checksum: f32,
}

impl<'e> StencilExecutor<'e> {
    pub fn new(engine: &'e Engine) -> Result<Self> {
        let m = &engine.manifest;
        ensure!(m.stencil_img > 0, "manifest has no stencil artifacts");
        Ok(StencilExecutor {
            engine,
            img: m.stencil_img,
            radius: m.stencil_radius,
        })
    }

    pub fn taps(&self, pattern: StencilPattern) -> usize {
        pattern.taps(self.radius as u32) as usize
    }

    /// Run one pattern over a pre-padded input of (img + 2r)^2 f32s.
    pub fn run(
        &self,
        pattern: StencilPattern,
        padded: &[f32],
        weights: &[f32],
    ) -> Result<StencilRun> {
        let side = self.img + 2 * self.radius;
        ensure!(padded.len() == side * side, "bad input size");
        ensure!(weights.len() == self.taps(pattern), "bad weights size");
        let inp = xla::Literal::vec1(padded)
            .reshape(&[side as i64, side as i64])
            .context("reshape input")?;
        let w = xla::Literal::vec1(weights);
        let name = format!("stencil_{pattern}_r{}.hlo.txt", self.radius);
        let outs = self.engine.execute(&name, &[inp, w])?;
        Ok(StencilRun {
            output: outs[0].to_vec::<f32>()?,
            checksum: outs[1].to_vec::<f32>()?[0],
        })
    }

    /// Pure-rust oracle of the same computation (mirrors kernels/ref.py).
    pub fn reference(
        &self,
        pattern: StencilPattern,
        padded: &[f32],
        weights: &[f32],
    ) -> Vec<f32> {
        let r = self.radius;
        let side = self.img + 2 * r;
        let offs = pattern.offsets(r as u32);
        let mut out = vec![0f32; self.img * self.img];
        for y in 0..self.img {
            for x in 0..self.img {
                let mut acc = 0f32;
                for (k, (dy, dx)) in offs.iter().enumerate() {
                    let yy = (y + r) as i64 + *dy as i64;
                    let xx = (x + r) as i64 + *dx as i64;
                    acc += weights[k] * padded[yy as usize * side + xx as usize];
                }
                for _ in 0..4 {
                    // epilogue chain; constants match kernels/stencil.py
                    acc = acc * 1.000_976_562_5 + 0.031_25;
                }
                out[y * self.img + x] = acc;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn stencil_artifact_matches_rust_reference() {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = Engine::new(&artifacts_dir()).unwrap();
        let exec = StencilExecutor::new(&engine).unwrap();
        let side = exec.img + 2 * exec.radius;
        let mut rng = Rng::new(123);
        let padded: Vec<f32> =
            (0..side * side).map(|_| rng.next_f32() - 0.5).collect();
        for pattern in StencilPattern::ALL {
            let weights: Vec<f32> = (0..exec.taps(pattern))
                .map(|_| rng.next_f32() - 0.5)
                .collect();
            let run = exec.run(pattern, &padded, &weights).unwrap();
            let want = exec.reference(pattern, &padded, &weights);
            assert_eq!(run.output.len(), want.len());
            let mut max_err = 0f32;
            for (a, b) in run.output.iter().zip(&want) {
                max_err = max_err.max((a - b).abs());
            }
            assert!(max_err < 1e-3, "{pattern}: max err {max_err}");
            let sum: f32 = run.output.iter().sum();
            assert!((sum - run.checksum).abs() < run.checksum.abs() * 1e-3 + 1.0);
        }
    }
}
