//! The device portfolio: every simulated testbed the system knows,
//! addressable by a stable key.
//!
//! The registry is what turns the single-card reproduction into a
//! portfolio of hardware scenarios: `lmtuner generate/train --device
//! <key>` selects the simulated testbed, datasets are stamped with the
//! key they were measured on, the serving layer routes prediction
//! batches by it, and `lmtuner crossdev` trains on one device and tests
//! on another. Keys are lowercase slugs (`m2090`, `gtx480`, `gtx680`,
//! `k20`); lookup is case-insensitive.

use anyhow::{bail, Result};

use super::spec::DeviceSpec;

/// Key of the default device — the paper's testbed.
pub const DEFAULT_DEVICE: &str = "m2090";

/// Every registered device, in canonical order (the paper's testbed
/// first, then the rest alphabetically). The order is stable: the
/// cross-device matrix and `lmtuner info` both present devices this way.
pub fn all() -> Vec<DeviceSpec> {
    vec![
        DeviceSpec::m2090(),
        DeviceSpec::gtx480(),
        DeviceSpec::gtx680(),
        DeviceSpec::k20(),
    ]
}

/// Registered device keys, in canonical order.
pub fn keys() -> Vec<&'static str> {
    all().into_iter().map(|d| d.key).collect()
}

/// Look a device up by key (case-insensitive). Unknown keys report the
/// available portfolio.
pub fn get(key: &str) -> Result<DeviceSpec> {
    let want = key.trim().to_ascii_lowercase();
    for d in all() {
        if d.key == want {
            return Ok(d);
        }
    }
    bail!(
        "unknown device '{key}' (registered: {})",
        keys().join(", ")
    )
}

/// The default simulated testbed (the paper's Tesla M2090).
pub fn default_device() -> DeviceSpec {
    DeviceSpec::m2090()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn at_least_four_devices_registered() {
        assert!(all().len() >= 4, "{:?}", keys());
    }

    #[test]
    fn keys_are_unique_slugs() {
        let ks = keys();
        let mut sorted = ks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ks.len(), "duplicate keys in {ks:?}");
        for k in ks {
            assert!(!k.is_empty());
            assert!(
                k.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit()),
                "key '{k}' is not a lowercase slug"
            );
        }
    }

    #[test]
    fn lookup_is_case_insensitive_and_roundtrips() {
        for d in all() {
            assert_eq!(get(d.key).unwrap().name, d.name);
            assert_eq!(get(&d.key.to_ascii_uppercase()).unwrap().key, d.key);
        }
        assert_eq!(get(" m2090 ").unwrap().key, "m2090");
    }

    #[test]
    fn unknown_device_lists_the_portfolio() {
        let err = get("gtx9000").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("gtx9000"), "{msg}");
        assert!(msg.contains("m2090"), "{msg}");
    }

    #[test]
    fn default_is_the_paper_testbed() {
        assert_eq!(default_device().key, DEFAULT_DEVICE);
        assert_eq!(keys()[0], DEFAULT_DEVICE);
    }
}
