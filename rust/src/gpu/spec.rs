//! GPU device model.
//!
//! The paper measures on an NVIDIA Tesla M2090 (Fermi GF110, compute
//! capability 2.0). The spec is data, so the same simulator runs a whole
//! portfolio of devices: [`super::registry`] names every card the system
//! knows (two Fermi and two Kepler parts), each with the per-CC occupancy
//! constants of the CUDA occupancy calculator.

/// Static hardware description of a Fermi/Kepler-class GPU.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Short slug used by the CLI (`--device`), dataset metadata and the
    /// serving model registry. Lowercase, no spaces.
    pub key: &'static str,
    /// Compute capability (major, minor) — determines the occupancy
    /// constant set below.
    pub compute_capability: (u32, u32),
    /// Streaming multiprocessors.
    pub num_sms: u32,
    /// Threads per warp.
    pub warp_size: u32,
    /// Core clock in Hz (shader clock for issue-rate purposes).
    pub clock_hz: f64,
    /// Max resident threads per SM.
    pub max_threads_per_sm: u32,
    /// Max resident blocks (workgroups) per SM.
    pub max_blocks_per_sm: u32,
    /// Max resident warps per SM.
    pub max_warps_per_sm: u32,
    /// 32-bit registers per SM.
    pub regs_per_sm: u32,
    /// Max registers addressable per thread (CC 2.x/3.0: 63, CC 3.5: 255).
    pub max_regs_per_thread: u32,
    /// Register allocation granularity (per-warp; CC 2.x: 64, CC 3.x: 256).
    pub reg_alloc_unit: u32,
    /// Shared ("local" in OpenCL terms) memory per SM, bytes.
    pub shared_mem_per_sm: u32,
    /// Shared-memory allocation granularity, bytes (CC 2.x: 128, 3.x: 256).
    pub shared_alloc_unit: u32,
    /// Max threads per block.
    pub max_threads_per_block: u32,
    /// DRAM transaction size in bytes (128 B line on Fermi/Kepler).
    pub transaction_bytes: u32,
    /// Aggregate DRAM bandwidth, bytes/s.
    pub mem_bandwidth: f64,
    /// Average DRAM access latency, cycles.
    pub mem_latency: f64,
    /// Shared-memory access latency, cycles (mostly pipelined/hidden).
    pub smem_latency: f64,
    /// Issue cost of a barrier, cycles (fixed part).
    pub barrier_base_cost: f64,
    /// L1 cache per SM, bytes (16 KB with the 48 KB shared config).
    pub l1_bytes: u32,
    /// L2 slice per SM, bytes (total L2 / SM count).
    pub l2_bytes_per_sm: u32,
    /// Latency of an L1/L2 hit, cycles.
    pub cache_hit_latency: f64,
}

impl DeviceSpec {
    /// NVIDIA Tesla M2090 — the paper's testbed (Table/Section 5).
    /// Fermi GF110, CC 2.0.
    pub fn m2090() -> Self {
        DeviceSpec {
            name: "Tesla M2090",
            key: "m2090",
            compute_capability: (2, 0),
            num_sms: 16,
            warp_size: 32,
            clock_hz: 1.3e9,
            max_threads_per_sm: 1536,
            max_blocks_per_sm: 8,
            max_warps_per_sm: 48,
            regs_per_sm: 32768,
            max_regs_per_thread: 63,
            reg_alloc_unit: 64,
            shared_mem_per_sm: 48 * 1024,
            shared_alloc_unit: 128,
            max_threads_per_block: 1024,
            transaction_bytes: 128,
            mem_bandwidth: 177.0e9,
            mem_latency: 600.0,
            smem_latency: 24.0,
            barrier_base_cost: 32.0,
            l1_bytes: 16 * 1024,
            l2_bytes_per_sm: 48 * 1024,
            cache_hit_latency: 80.0,
        }
    }

    /// GeForce GTX 480 — a second Fermi part (GF100, CC 2.0): one SM
    /// fewer, higher shader clock, 768 KB of L2 over 15 SMs.
    pub fn gtx480() -> Self {
        DeviceSpec {
            name: "GeForce GTX 480",
            key: "gtx480",
            num_sms: 15,
            clock_hz: 1.401e9,
            mem_bandwidth: 177.4e9,
            l2_bytes_per_sm: 768 * 1024 / 15,
            ..Self::m2090()
        }
    }

    /// GeForce GTX 680 — Kepler GK104, CC 3.0: 8 wide SMXs, 2048
    /// threads / 64 warps / 16 blocks per SMX, a 64K register file with
    /// 256-register per-warp allocation granularity, and no hot clock.
    pub fn gtx680() -> Self {
        DeviceSpec {
            name: "GeForce GTX 680",
            key: "gtx680",
            compute_capability: (3, 0),
            num_sms: 8,
            warp_size: 32,
            clock_hz: 1.006e9,
            max_threads_per_sm: 2048,
            max_blocks_per_sm: 16,
            max_warps_per_sm: 64,
            regs_per_sm: 65536,
            max_regs_per_thread: 63,
            reg_alloc_unit: 256,
            shared_mem_per_sm: 48 * 1024,
            shared_alloc_unit: 256,
            max_threads_per_block: 1024,
            transaction_bytes: 128,
            mem_bandwidth: 192.3e9,
            mem_latency: 500.0,
            smem_latency: 28.0,
            barrier_base_cost: 32.0,
            l1_bytes: 16 * 1024,
            l2_bytes_per_sm: 512 * 1024 / 8,
            cache_hit_latency: 80.0,
        }
    }

    /// Tesla K20 — Kepler GK110, CC 3.5: 13 SMXs, the CC 3.0 occupancy
    /// constants plus the raised 255-register per-thread cap, and a
    /// 1.5 MB L2.
    pub fn k20() -> Self {
        DeviceSpec {
            name: "Tesla K20",
            key: "k20",
            compute_capability: (3, 5),
            num_sms: 13,
            clock_hz: 0.706e9,
            max_regs_per_thread: 255,
            mem_bandwidth: 208.0e9,
            l2_bytes_per_sm: 1536 * 1024 / 13,
            ..Self::gtx680()
        }
    }

    /// DRAM transaction departure delay per SM, in core cycles: how many
    /// cycles of exclusive bandwidth one 128 B transaction costs one SM's
    /// fair share of the memory system.
    pub fn tx_departure_cycles(&self) -> f64 {
        let bw_per_sm_per_cycle =
            self.mem_bandwidth / self.num_sms as f64 / self.clock_hz;
        self.transaction_bytes as f64 / bw_per_sm_per_cycle
    }

    /// Warps needed to hold `threads` threads.
    pub fn warps_for_threads(&self, threads: u32) -> u32 {
        threads.div_ceil(self.warp_size)
    }

    /// Local-memory budget one workgroup's staged region must fit: the
    /// per-SM shared memory, capped at the 48 KB per-block limit of
    /// every CC 2.x/3.x part. The staging-safety certificate
    /// (`frontend::sema::certify`) checks regions against this.
    pub fn lmem_budget_per_wg(&self) -> u32 {
        self.shared_mem_per_sm.min(48 * 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2090_basics() {
        let d = DeviceSpec::m2090();
        assert_eq!(d.num_sms, 16);
        assert_eq!(d.max_warps_per_sm * d.warp_size, d.max_threads_per_sm);
    }

    #[test]
    fn warp_and_thread_caps_are_consistent_on_every_device() {
        for d in [
            DeviceSpec::m2090(),
            DeviceSpec::gtx480(),
            DeviceSpec::gtx680(),
            DeviceSpec::k20(),
        ] {
            assert_eq!(
                d.max_warps_per_sm * d.warp_size,
                d.max_threads_per_sm,
                "{}",
                d.key
            );
            assert!(d.max_threads_per_block <= d.max_threads_per_sm);
            assert!(d.regs_per_sm % d.reg_alloc_unit == 0, "{}", d.key);
        }
    }

    #[test]
    fn cc_occupancy_constants_match_the_calculator() {
        // The per-CC constant sets of the CUDA occupancy calculator.
        let m = DeviceSpec::m2090();
        assert_eq!((m.max_warps_per_sm, m.max_blocks_per_sm), (48, 8));
        assert_eq!((m.regs_per_sm, m.reg_alloc_unit, m.max_regs_per_thread), (32768, 64, 63));
        assert_eq!(m.shared_alloc_unit, 128);
        let g = DeviceSpec::gtx680();
        assert_eq!((g.max_warps_per_sm, g.max_blocks_per_sm), (64, 16));
        assert_eq!((g.regs_per_sm, g.reg_alloc_unit, g.max_regs_per_thread), (65536, 256, 63));
        assert_eq!(g.shared_alloc_unit, 256);
        let k = DeviceSpec::k20();
        assert_eq!(k.max_regs_per_thread, 255);
        assert_eq!(k.reg_alloc_unit, 256);
    }

    #[test]
    fn departure_delay_is_plausible() {
        // 177 GB/s over 16 SMs at 1.3 GHz => ~8.5 B/cycle/SM => ~15 cycles
        // per 128 B transaction.
        let d = DeviceSpec::m2090();
        let delta = d.tx_departure_cycles();
        assert!((10.0..25.0).contains(&delta), "delta {delta}");
        // Kepler parts have more bandwidth per SM-cycle, so the departure
        // delay shrinks but stays positive.
        for d in [DeviceSpec::gtx680(), DeviceSpec::k20()] {
            let delta = d.tx_departure_cycles();
            assert!((1.0..25.0).contains(&delta), "{}: delta {delta}", d.key);
        }
    }

    #[test]
    fn lmem_budget_is_48k_on_every_registered_device() {
        for d in [
            DeviceSpec::m2090(),
            DeviceSpec::gtx480(),
            DeviceSpec::gtx680(),
            DeviceSpec::k20(),
        ] {
            assert_eq!(d.lmem_budget_per_wg(), 48 * 1024, "{}", d.key);
            assert!(d.lmem_budget_per_wg() <= d.shared_mem_per_sm);
        }
    }

    #[test]
    fn warps_for_threads_rounds_up() {
        let d = DeviceSpec::m2090();
        assert_eq!(d.warps_for_threads(1), 1);
        assert_eq!(d.warps_for_threads(32), 1);
        assert_eq!(d.warps_for_threads(33), 2);
        assert_eq!(d.warps_for_threads(1024), 32);
    }
}
