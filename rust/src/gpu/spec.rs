//! GPU device model.
//!
//! The paper measures on an NVIDIA Tesla M2090 (Fermi GF110, compute
//! capability 2.0). We model that card; the spec is data, so other devices
//! can be described for ablations (`DeviceSpec::gtx480()` etc.).

/// Static hardware description of a Fermi-class GPU.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Streaming multiprocessors.
    pub num_sms: u32,
    /// Threads per warp.
    pub warp_size: u32,
    /// Core clock in Hz (shader clock for issue-rate purposes).
    pub clock_hz: f64,
    /// Max resident threads per SM.
    pub max_threads_per_sm: u32,
    /// Max resident blocks (workgroups) per SM.
    pub max_blocks_per_sm: u32,
    /// Max resident warps per SM.
    pub max_warps_per_sm: u32,
    /// 32-bit registers per SM.
    pub regs_per_sm: u32,
    /// Max registers addressable per thread (CC 2.0: 63).
    pub max_regs_per_thread: u32,
    /// Register allocation granularity (per-warp, CC 2.0: 64 registers).
    pub reg_alloc_unit: u32,
    /// Shared ("local" in OpenCL terms) memory per SM, bytes.
    pub shared_mem_per_sm: u32,
    /// Shared-memory allocation granularity, bytes.
    pub shared_alloc_unit: u32,
    /// Max threads per block.
    pub max_threads_per_block: u32,
    /// DRAM transaction size in bytes (128 B on Fermi).
    pub transaction_bytes: u32,
    /// Aggregate DRAM bandwidth, bytes/s.
    pub mem_bandwidth: f64,
    /// Average DRAM access latency, cycles.
    pub mem_latency: f64,
    /// Shared-memory access latency, cycles (mostly pipelined/hidden).
    pub smem_latency: f64,
    /// Issue cost of a barrier, cycles (fixed part).
    pub barrier_base_cost: f64,
    /// L1 cache per SM, bytes (Fermi: 16 KB with 48 KB shared config).
    pub l1_bytes: u32,
    /// L2 slice per SM, bytes (768 KB total / 16 SMs on GF110).
    pub l2_bytes_per_sm: u32,
    /// Latency of an L1/L2 hit, cycles.
    pub cache_hit_latency: f64,
}

impl DeviceSpec {
    /// NVIDIA Tesla M2090 — the paper's testbed (Table/Section 5).
    pub fn m2090() -> Self {
        DeviceSpec {
            name: "Tesla M2090",
            num_sms: 16,
            warp_size: 32,
            clock_hz: 1.3e9,
            max_threads_per_sm: 1536,
            max_blocks_per_sm: 8,
            max_warps_per_sm: 48,
            regs_per_sm: 32768,
            max_regs_per_thread: 63,
            reg_alloc_unit: 64,
            shared_mem_per_sm: 48 * 1024,
            shared_alloc_unit: 128,
            max_threads_per_block: 1024,
            transaction_bytes: 128,
            mem_bandwidth: 177.0e9,
            mem_latency: 600.0,
            smem_latency: 24.0,
            barrier_base_cost: 32.0,
            l1_bytes: 16 * 1024,
            l2_bytes_per_sm: 48 * 1024,
            cache_hit_latency: 80.0,
        }
    }

    /// GeForce GTX 480 — a second Fermi part for device ablations.
    pub fn gtx480() -> Self {
        DeviceSpec {
            name: "GeForce GTX 480",
            num_sms: 15,
            mem_bandwidth: 177.4e9,
            clock_hz: 1.4e9,
            ..Self::m2090()
        }
    }

    /// DRAM transaction departure delay per SM, in core cycles: how many
    /// cycles of exclusive bandwidth one 128 B transaction costs one SM's
    /// fair share of the memory system.
    pub fn tx_departure_cycles(&self) -> f64 {
        let bw_per_sm_per_cycle =
            self.mem_bandwidth / self.num_sms as f64 / self.clock_hz;
        self.transaction_bytes as f64 / bw_per_sm_per_cycle
    }

    /// Warps needed to hold `threads` threads.
    pub fn warps_for_threads(&self, threads: u32) -> u32 {
        threads.div_ceil(self.warp_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2090_basics() {
        let d = DeviceSpec::m2090();
        assert_eq!(d.num_sms, 16);
        assert_eq!(d.max_warps_per_sm * d.warp_size, d.max_threads_per_sm);
    }

    #[test]
    fn departure_delay_is_plausible() {
        // 177 GB/s over 16 SMs at 1.3 GHz => ~8.5 B/cycle/SM => ~15 cycles
        // per 128 B transaction.
        let d = DeviceSpec::m2090();
        let delta = d.tx_departure_cycles();
        assert!((10.0..25.0).contains(&delta), "delta {delta}");
    }

    #[test]
    fn warps_for_threads_rounds_up() {
        let d = DeviceSpec::m2090();
        assert_eq!(d.warps_for_threads(1), 1);
        assert_eq!(d.warps_for_threads(32), 1);
        assert_eq!(d.warps_for_threads(33), 2);
        assert_eq!(d.warps_for_threads(1024), 32);
    }
}
