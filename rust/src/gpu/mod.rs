//! Device model of the paper's testbed (Tesla M2090, Fermi CC 2.0).
pub mod occupancy;
pub mod spec;
