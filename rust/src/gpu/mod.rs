//! Device models: the paper's testbed (Tesla M2090, Fermi CC 2.0) plus
//! the rest of the simulated device portfolio ([`registry`]), and the
//! per-CC occupancy calculator.
pub mod occupancy;
pub mod registry;
pub mod spec;
