//! Occupancy calculator: resident blocks/warps per SM given per-block
//! resource usage — the CUDA occupancy-calculator logic for CC 2.0.
//!
//! The local-memory optimization consumes extra shared memory and
//! registers; the resulting *drop in parallelism* (paper §3, factor 3) is
//! exactly what this module quantifies.

use super::spec::DeviceSpec;

/// Per-block resource usage of a kernel variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockUsage {
    pub threads_per_block: u32,
    pub regs_per_thread: u32,
    pub shared_bytes_per_block: u32,
}

/// Resident-resource outcome for one SM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Occupancy {
    /// Concurrently resident blocks on one SM (0 = kernel cannot launch).
    pub blocks_per_sm: u32,
    /// Resident warps on one SM.
    pub warps_per_sm: u32,
    /// warps / max_warps, in [0, 1].
    pub fraction: f64,
    /// Which resource capped residency.
    pub limiter: Limiter,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Limiter {
    Threads,
    Blocks,
    Registers,
    SharedMem,
    /// Kernel cannot run at all (a single block exceeds some resource).
    Infeasible,
}

pub fn occupancy(dev: &DeviceSpec, u: &BlockUsage) -> Occupancy {
    let infeasible = Occupancy {
        blocks_per_sm: 0,
        warps_per_sm: 0,
        fraction: 0.0,
        limiter: Limiter::Infeasible,
    };
    if u.threads_per_block == 0
        || u.threads_per_block > dev.max_threads_per_block
        || u.regs_per_thread > dev.max_regs_per_thread
        || u.shared_bytes_per_block > dev.shared_mem_per_sm
    {
        return infeasible;
    }

    let warps_per_block = dev.warps_for_threads(u.threads_per_block);

    // Register allocation is per warp with `reg_alloc_unit` granularity.
    let regs_per_warp = (u.regs_per_thread.max(1) * dev.warp_size)
        .div_ceil(dev.reg_alloc_unit)
        * dev.reg_alloc_unit;
    let regs_per_block = regs_per_warp * warps_per_block;

    // Shared memory allocated with `shared_alloc_unit` granularity.
    let smem_per_block = if u.shared_bytes_per_block == 0 {
        0
    } else {
        u.shared_bytes_per_block.div_ceil(dev.shared_alloc_unit)
            * dev.shared_alloc_unit
    };

    let lim_threads = dev.max_threads_per_sm / u.threads_per_block;
    let lim_blocks = dev.max_blocks_per_sm;
    let lim_warps = dev.max_warps_per_sm / warps_per_block;
    let lim_regs = if regs_per_block == 0 {
        u32::MAX
    } else {
        dev.regs_per_sm / regs_per_block
    };
    let lim_smem = if smem_per_block == 0 {
        u32::MAX
    } else {
        dev.shared_mem_per_sm / smem_per_block
    };

    let blocks = lim_threads
        .min(lim_blocks)
        .min(lim_warps)
        .min(lim_regs)
        .min(lim_smem);
    if blocks == 0 {
        return infeasible;
    }

    // Attribute the binding constraint (ties: report the scarcest).
    let limiter = if blocks == lim_regs && lim_regs <= lim_smem {
        Limiter::Registers
    } else if blocks == lim_smem {
        Limiter::SharedMem
    } else if blocks == lim_threads.min(lim_warps) {
        Limiter::Threads
    } else {
        Limiter::Blocks
    };

    let warps = blocks * warps_per_block;
    Occupancy {
        blocks_per_sm: blocks,
        warps_per_sm: warps,
        fraction: warps as f64 / dev.max_warps_per_sm as f64,
        limiter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceSpec {
        DeviceSpec::m2090()
    }

    fn usage(t: u32, r: u32, s: u32) -> BlockUsage {
        BlockUsage {
            threads_per_block: t,
            regs_per_thread: r,
            shared_bytes_per_block: s,
        }
    }

    #[test]
    fn light_kernel_is_thread_limited_full_occupancy() {
        let o = occupancy(&dev(), &usage(256, 16, 0));
        assert_eq!(o.blocks_per_sm, 6); // 1536 / 256
        assert_eq!(o.warps_per_sm, 48);
        assert!((o.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn max_blocks_cap_applies() {
        let o = occupancy(&dev(), &usage(64, 10, 0));
        assert_eq!(o.blocks_per_sm, 8); // block-count cap, not 1536/64 = 24
        assert_eq!(o.limiter, Limiter::Blocks);
        assert_eq!(o.warps_per_sm, 16);
    }

    #[test]
    fn register_pressure_limits() {
        // 63 regs/thread, 512 threads: regs/warp = ceil(63*32/64)*64 = 2048;
        // per block = 16 warps * 2048 = 32768 => exactly 1 block/SM.
        let o = occupancy(&dev(), &usage(512, 63, 0));
        assert_eq!(o.blocks_per_sm, 1);
        assert_eq!(o.limiter, Limiter::Registers);
    }

    #[test]
    fn shared_memory_limits() {
        // 20 KB/block => 2 blocks fit in 48 KB.
        let o = occupancy(&dev(), &usage(128, 16, 20 * 1024));
        assert_eq!(o.blocks_per_sm, 2);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn oversized_block_is_infeasible() {
        assert_eq!(occupancy(&dev(), &usage(2048, 16, 0)).limiter, Limiter::Infeasible);
        assert_eq!(
            occupancy(&dev(), &usage(256, 16, 64 * 1024)).limiter,
            Limiter::Infeasible
        );
        assert_eq!(occupancy(&dev(), &usage(256, 100, 0)).limiter, Limiter::Infeasible);
    }

    #[test]
    fn more_smem_never_increases_occupancy() {
        let d = dev();
        let mut last = u32::MAX;
        for kb in [0u32, 4, 8, 16, 24, 32, 48] {
            let o = occupancy(&d, &usage(256, 20, kb * 1024));
            assert!(o.blocks_per_sm <= last);
            last = o.blocks_per_sm;
        }
    }

    #[test]
    fn warp_granularity_of_registers() {
        // 33 threads = 2 warps even though only just past one warp.
        let o = occupancy(&dev(), &usage(33, 20, 0));
        // 2 warps/block, warp cap 48/2 = 24, block cap 8 binds.
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.warps_per_sm, 16);
    }
}
