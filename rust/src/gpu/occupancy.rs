//! Occupancy calculator: resident blocks/warps per SM given per-block
//! resource usage — the CUDA occupancy-calculator logic for CC 2.0.
//!
//! The local-memory optimization consumes extra shared memory and
//! registers; the resulting *drop in parallelism* (paper §3, factor 3) is
//! exactly what this module quantifies.

use super::spec::DeviceSpec;

/// Per-block resource usage of a kernel variant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockUsage {
    pub threads_per_block: u32,
    pub regs_per_thread: u32,
    pub shared_bytes_per_block: u32,
}

/// Resident-resource outcome for one SM.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Occupancy {
    /// Concurrently resident blocks on one SM (0 = kernel cannot launch).
    pub blocks_per_sm: u32,
    /// Resident warps on one SM.
    pub warps_per_sm: u32,
    /// warps / max_warps, in [0, 1].
    pub fraction: f64,
    /// Which resource capped residency.
    pub limiter: Limiter,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Limiter {
    Threads,
    Blocks,
    Registers,
    SharedMem,
    /// Kernel cannot run at all (a single block exceeds some resource).
    Infeasible,
}

pub fn occupancy(dev: &DeviceSpec, u: &BlockUsage) -> Occupancy {
    let infeasible = Occupancy {
        blocks_per_sm: 0,
        warps_per_sm: 0,
        fraction: 0.0,
        limiter: Limiter::Infeasible,
    };
    if u.threads_per_block == 0
        || u.threads_per_block > dev.max_threads_per_block
        || u.regs_per_thread > dev.max_regs_per_thread
        || u.shared_bytes_per_block > dev.shared_mem_per_sm
    {
        return infeasible;
    }

    let warps_per_block = dev.warps_for_threads(u.threads_per_block);

    // Register allocation is per warp with `reg_alloc_unit` granularity.
    let regs_per_warp = (u.regs_per_thread.max(1) * dev.warp_size)
        .div_ceil(dev.reg_alloc_unit)
        * dev.reg_alloc_unit;
    let regs_per_block = regs_per_warp * warps_per_block;

    // Shared memory allocated with `shared_alloc_unit` granularity.
    let smem_per_block = if u.shared_bytes_per_block == 0 {
        0
    } else {
        u.shared_bytes_per_block.div_ceil(dev.shared_alloc_unit)
            * dev.shared_alloc_unit
    };

    let lim_threads = dev.max_threads_per_sm / u.threads_per_block;
    let lim_blocks = dev.max_blocks_per_sm;
    let lim_warps = dev.max_warps_per_sm / warps_per_block;
    let lim_regs = if regs_per_block == 0 {
        u32::MAX
    } else {
        dev.regs_per_sm / regs_per_block
    };
    let lim_smem = if smem_per_block == 0 {
        u32::MAX
    } else {
        dev.shared_mem_per_sm / smem_per_block
    };

    let blocks = lim_threads
        .min(lim_blocks)
        .min(lim_warps)
        .min(lim_regs)
        .min(lim_smem);
    if blocks == 0 {
        return infeasible;
    }

    // Attribute the binding constraint: the scarcest resource wins (every
    // candidate below equals `blocks`, the minimum). On exact ties the
    // documented order is SharedMem > Registers > Threads (which also
    // covers the warp cap — threads and warps are the same resource at
    // warp granularity) > Blocks: the resources the local-memory
    // optimization actually spends come first, the fixed hardware caps
    // last, so a tie is always attributed to the knob a tuner can move.
    let lim_occ = lim_threads.min(lim_warps);
    let limiter = if blocks == lim_smem {
        Limiter::SharedMem
    } else if blocks == lim_regs {
        Limiter::Registers
    } else if blocks == lim_occ {
        Limiter::Threads
    } else {
        Limiter::Blocks
    };

    let warps = blocks * warps_per_block;
    Occupancy {
        blocks_per_sm: blocks,
        warps_per_sm: warps,
        fraction: warps as f64 / dev.max_warps_per_sm as f64,
        limiter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceSpec {
        DeviceSpec::m2090()
    }

    fn usage(t: u32, r: u32, s: u32) -> BlockUsage {
        BlockUsage {
            threads_per_block: t,
            regs_per_thread: r,
            shared_bytes_per_block: s,
        }
    }

    #[test]
    fn light_kernel_is_thread_limited_full_occupancy() {
        let o = occupancy(&dev(), &usage(256, 16, 0));
        assert_eq!(o.blocks_per_sm, 6); // 1536 / 256
        assert_eq!(o.warps_per_sm, 48);
        assert!((o.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn max_blocks_cap_applies() {
        let o = occupancy(&dev(), &usage(64, 10, 0));
        assert_eq!(o.blocks_per_sm, 8); // block-count cap, not 1536/64 = 24
        assert_eq!(o.limiter, Limiter::Blocks);
        assert_eq!(o.warps_per_sm, 16);
    }

    #[test]
    fn register_pressure_limits() {
        // 63 regs/thread, 512 threads: regs/warp = ceil(63*32/64)*64 = 2048;
        // per block = 16 warps * 2048 = 32768 => exactly 1 block/SM.
        let o = occupancy(&dev(), &usage(512, 63, 0));
        assert_eq!(o.blocks_per_sm, 1);
        assert_eq!(o.limiter, Limiter::Registers);
    }

    #[test]
    fn shared_memory_limits() {
        // 20 KB/block => 2 blocks fit in 48 KB.
        let o = occupancy(&dev(), &usage(128, 16, 20 * 1024));
        assert_eq!(o.blocks_per_sm, 2);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn oversized_block_is_infeasible() {
        assert_eq!(occupancy(&dev(), &usage(2048, 16, 0)).limiter, Limiter::Infeasible);
        assert_eq!(
            occupancy(&dev(), &usage(256, 16, 64 * 1024)).limiter,
            Limiter::Infeasible
        );
        assert_eq!(occupancy(&dev(), &usage(256, 100, 0)).limiter, Limiter::Infeasible);
    }

    #[test]
    fn more_smem_never_increases_occupancy() {
        let d = dev();
        let mut last = u32::MAX;
        for kb in [0u32, 4, 8, 16, 24, 32, 48] {
            let o = occupancy(&d, &usage(256, 20, kb * 1024));
            assert!(o.blocks_per_sm <= last);
            last = o.blocks_per_sm;
        }
    }

    #[test]
    fn warp_granularity_of_registers() {
        // 33 threads = 2 warps even though only just past one warp.
        let o = occupancy(&dev(), &usage(33, 20, 0));
        // 2 warps/block, warp cap 48/2 = 24, block cap 8 binds.
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.warps_per_sm, 16);
    }

    // ---- tie attribution: documented order SharedMem > Registers >
    //      Threads > Blocks, one direct test per tie ----

    #[test]
    fn regs_smem_tie_reports_shared_mem() {
        // 256 threads, 63 regs: regs/warp = ceil(63*32/64)*64 = 2048,
        // 8 warps/block => 16384 regs/block => lim_regs = 2. 24 KB of
        // smem => lim_smem = 2. Both bind; SharedMem wins the tie.
        let o = occupancy(&dev(), &usage(256, 63, 24 * 1024));
        assert_eq!(o.blocks_per_sm, 2);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn regs_threads_tie_reports_registers() {
        // 256 threads, 20 regs: regs/warp = ceil(20*32/64)*64 = 640,
        // 8 warps/block => 5120 regs/block => lim_regs = 6; thread cap
        // 1536/256 = 6 and warp cap 48/8 = 6 tie with it.
        let o = occupancy(&dev(), &usage(256, 20, 0));
        assert_eq!(o.blocks_per_sm, 6);
        assert_eq!(o.limiter, Limiter::Registers);
    }

    #[test]
    fn threads_blocks_tie_reports_threads() {
        // 192 threads: thread cap 1536/192 = 8, warp cap 48/6 = 8, and
        // the block-count cap 8 all tie; Threads outranks Blocks.
        let o = occupancy(&dev(), &usage(192, 10, 0));
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.limiter, Limiter::Threads);
    }

    #[test]
    fn four_way_tie_reports_shared_mem() {
        // 192 threads (caps 8/8/8 as above), 20 regs => 6 warps * 640 =
        // 3840 regs/block => lim_regs = 8, and 6144 B smem => lim_smem =
        // 8: every resource ties at 8, SharedMem is first in the order.
        let o = occupancy(&dev(), &usage(192, 20, 6144));
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn blocks_only_still_reports_blocks() {
        let o = occupancy(&dev(), &usage(64, 10, 0));
        assert_eq!(o.limiter, Limiter::Blocks);
    }

    // ---- golden occupancy numbers per registered device, validated
    //      against the CUDA occupancy calculator's constant sets ----

    #[test]
    fn golden_k20_full_occupancy() {
        // CC 3.5: 256 threads, 32 regs => regs/warp = ceil(32*32/256)*256
        // = 1024, 8 warps/block => 8192 regs/block => lim_regs = 8;
        // thread cap 2048/256 = 8, warp cap 64/8 = 8, block cap 16.
        // 8 blocks, 64 warps: 100% occupancy.
        let d = DeviceSpec::k20();
        let o = occupancy(&d, &usage(256, 32, 0));
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.warps_per_sm, 64);
        assert!((o.fraction - 1.0).abs() < 1e-12);
    }

    #[test]
    fn golden_k20_accepts_high_register_kernels() {
        // 255 regs/thread is legal on CC 3.5 (infeasible on CC 2.x/3.0):
        // regs/warp = ceil(255*32/256)*256 = 8192 => 64 threads (2 warps)
        // => 16384 regs/block => lim_regs = 4 binds (block cap 16).
        let d = DeviceSpec::k20();
        let o = occupancy(&d, &usage(64, 255, 0));
        assert_eq!(o.blocks_per_sm, 4);
        assert_eq!(o.limiter, Limiter::Registers);
        assert_eq!(
            occupancy(&DeviceSpec::gtx680(), &usage(64, 255, 0)).limiter,
            Limiter::Infeasible
        );
    }

    #[test]
    fn golden_gtx680_register_pressure() {
        // CC 3.0: 128 threads, 63 regs => regs/warp = ceil(63*32/256)*256
        // = 2048, 4 warps/block => 8192 regs/block => lim_regs = 8 binds
        // (thread cap 16, warp cap 16, block cap 16): 32 warps, 50%.
        let d = DeviceSpec::gtx680();
        let o = occupancy(&d, &usage(128, 63, 0));
        assert_eq!(o.blocks_per_sm, 8);
        assert_eq!(o.warps_per_sm, 32);
        assert_eq!(o.limiter, Limiter::Registers);
        assert!((o.fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn golden_gtx680_smem_granularity() {
        // CC 3.0 rounds shared memory to 256 B: 6200 B/block allocates
        // 6400 B => lim_smem = 49152/6400 = 7 binds.
        let d = DeviceSpec::gtx680();
        let o = occupancy(&d, &usage(128, 16, 6200));
        assert_eq!(o.blocks_per_sm, 7);
        assert_eq!(o.limiter, Limiter::SharedMem);
    }

    #[test]
    fn golden_gtx480_matches_m2090_constants() {
        // Same CC 2.0 constant set as the M2090: identical residency for
        // identical per-block usage (the parts differ in SM count/clock,
        // not occupancy constants).
        let a = DeviceSpec::gtx480();
        let b = DeviceSpec::m2090();
        for u in [usage(256, 16, 0), usage(512, 63, 0), usage(128, 16, 20 * 1024)] {
            let oa = occupancy(&a, &u);
            let ob = occupancy(&b, &u);
            assert_eq!(oa.blocks_per_sm, ob.blocks_per_sm);
            assert_eq!(oa.limiter, ob.limiter);
        }
    }

    #[test]
    fn golden_kepler_wide_blocks() {
        // 1024 threads, 24 regs, CC 3.0: regs/warp = ceil(24*32/256)*256
        // = 768, 32 warps => 24576 regs/block => lim_regs = 65536/24576
        // = 2; thread cap 2048/1024 = 2, warp cap 64/32 = 2 tie =>
        // Registers by documented order (SharedMem unused). 64 resident
        // warps, full occupancy.
        let d = DeviceSpec::gtx680();
        let o = occupancy(&d, &usage(1024, 24, 0));
        assert_eq!(o.blocks_per_sm, 2);
        assert_eq!(o.warps_per_sm, 64);
        assert_eq!(o.limiter, Limiter::Registers);
    }
}
