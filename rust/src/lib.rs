//! lmtuner: ML-based auto-tuning of the local-memory optimization on
//! GPGPUs — a reproduction of Han & Abdelrahman (2014) grown into a
//! batched inference serving system.
//!
//! The paper's framework has two phases. **Phase 1** trains a Random
//! Forest on millions of synthetic kernel instances, each labeled with
//! the measured speedup of staging its data in local/shared memory:
//! [`synth`] generates the kernel population, [`sim`] measures it on a
//! simulated testbed drawn from the [`gpu::registry`] device portfolio
//! (the paper's Tesla M2090 by default; Fermi and Kepler parts are
//! registered), [`ml`] fits and evaluates the model, and
//! [`coordinator::train`] drives the pipeline — either fully in
//! memory or streamed through `synth::sink` record sinks so paper-scale
//! datasets shard to disk with bounded peak memory (every dataset is
//! stamped with its device; mixing devices is a typed error).
//! [`coordinator::crossdev`] grades cross-device generalization as a
//! train-on-A/test-on-B accuracy matrix. **Phase 2** serves
//! the use/don't-use decision online: [`coordinator::service`] batches
//! requests across sharded workers onto a [`runtime`] backend (native
//! tensorized traversal, or PJRT when artifacts are present), with
//! `coordinator::service::DeviceRouter` routing batches to per-device
//! models. The [`frontend`] closes the loop for real kernels: it parses
//! OpenCL C source, runs per-array access analysis, and synthesizes the
//! same descriptor/feature vector the trained forest consumes
//! (`lmtuner analyze <kernel.cl>`).
//!
//! See `README.md` for the quickstart, `DESIGN.md` for the module
//! inventory and backend contracts, and `EXPERIMENTS.md` for how each
//! paper figure/table is regenerated.
//!
//! # End-to-end example
//!
//! Generate a small synthetic population, measure it, fit a forest,
//! and evaluate the paper's two accuracy metrics:
//!
//! ```
//! use lmtuner::gpu::spec::DeviceSpec;
//! use lmtuner::ml::forest::{Forest, ForestConfig};
//! use lmtuner::ml::metrics;
//! use lmtuner::synth::{dataset, generator, sweep::LaunchSweep};
//! use lmtuner::util::prng::Rng;
//!
//! let dev = DeviceSpec::m2090();
//! let mut rng = Rng::new(7);
//! // 1 context tuple -> 112 kernel templates (paper scale is 100 tuples)
//! let templates = generator::generate_n(&mut rng, 1);
//! let sweep = LaunchSweep::new(2048, 2048);
//! let cfg = dataset::BuildConfig { configs_per_kernel: 2, ..Default::default() };
//! // Each TuneRecord carries the scalar speedup label plus the
//! // fastest measured workgroup shape (the schema-v2 joint label).
//! let records = dataset::build(&templates, &sweep, &dev, &cfg);
//! assert!(!records.is_empty() && records[0].best_wg.is_some());
//!
//! let (train, test) = dataset::split(&records, 0.5, 1);
//! // fit_tune_records grows one forest predicting all three targets;
//! // non-finite features/targets are a typed error
//! let forest = Forest::fit_tune_records(
//!     &train,
//!     &ForestConfig { num_trees: 3, ..Default::default() },
//! ).expect("simulator records are finite and labeled");
//! let test_bases: Vec<_> = test.iter().map(|r| &r.base).collect();
//! let acc = metrics::evaluate_model(&test_bases, |x| forest.decide(x));
//! assert!(acc.n > 0 && acc.penalty_weighted > 0.0);
//! assert!(forest.predict_wg_logs(&test[0].base.features).is_some());
//! ```
pub mod coordinator;
pub mod frontend;
pub mod gpu;
pub mod kernelmodel;
pub mod ml;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod util;
pub mod workloads;

pub use runtime::executor::BatchExecutor;

pub fn version() -> &'static str { env!("CARGO_PKG_VERSION") }
