//! lmtuner: ML-based auto-tuning of the local-memory optimization on
//! GPGPUs — a reproduction of Han & Abdelrahman (2014) grown into a
//! batched inference serving system.
//!
//! See DESIGN.md for the module inventory, the `BatchExecutor` backend
//! contract, and the experiment index.
pub mod coordinator;
pub mod gpu;
pub mod kernelmodel;
pub mod ml;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod synth;
pub mod util;
pub mod workloads;

pub use runtime::executor::BatchExecutor;

pub fn version() -> &'static str { env!("CARGO_PKG_VERSION") }
