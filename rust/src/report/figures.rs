//! Figure-level drivers: regenerate Fig. 1 (speedup histograms) and
//! Fig. 6 (model accuracy) from fresh simulations.

use crate::gpu::spec::DeviceSpec;
use crate::ml::metrics::Accuracy;
use crate::sim::exec::{measure, MeasureConfig, SpeedupRecord};
use crate::workloads;

use super::hist;

/// Fig. 1b-1i: per-benchmark speedup records.
pub fn real_benchmark_records(
    dev: &DeviceSpec,
    cfg: &MeasureConfig,
) -> Vec<(String, Vec<SpeedupRecord>)> {
    workloads::all()
        .into_iter()
        .map(|b| {
            let recs = (b.instances)(dev)
                .iter()
                .map(|d| measure(d, dev, cfg))
                .collect();
            (b.name.to_string(), recs)
        })
        .collect()
}

/// Render all Fig. 1 panels (a = synthetic, b-i = real benchmarks).
pub fn fig1(synth: &[SpeedupRecord], real: &[(String, Vec<SpeedupRecord>)]) -> String {
    let mut out = String::new();
    out.push_str("=== Figure 1: kernel speedup from the local memory optimization ===\n\n");
    out.push_str(&hist::render("(a) synthetic kernels", synth, 48));
    for (i, (name, recs)) in real.iter().enumerate() {
        let letter = (b'b' + i as u8) as char;
        out.push('\n');
        out.push_str(&hist::render(&format!("({letter}) {name}"), recs, 48));
    }
    out
}

/// Render Fig. 6: both accuracy metrics with min/max error bars.
pub fn fig6(synth: &Accuracy, per_benchmark: &[(String, Accuracy)]) -> String {
    let mut out = String::new();
    out.push_str("=== Figure 6: accuracy of the machine-learning model ===\n\n");
    out.push_str(&format!(
        "{:<14} {:>8} {:>10} {:>8} {:>8} {:>8}\n",
        "workload", "count", "penalty-wt", "min", "max", "n"
    ));
    let row = |name: &str, a: &Accuracy| {
        format!(
            "{:<14} {:>7.1}% {:>9.1}% {:>7.2} {:>8.2} {:>8}\n",
            name,
            100.0 * a.count_based,
            100.0 * a.penalty_weighted,
            a.min_score,
            a.max_score,
            a.n
        )
    };
    out.push_str(&row("synthetic", synth));
    for (name, a) in per_benchmark {
        out.push_str(&row(name, a));
    }
    let avg_pen: f64 = per_benchmark.iter().map(|(_, a)| a.penalty_weighted).sum::<f64>()
        / per_benchmark.len().max(1) as f64;
    out.push_str(&format!(
        "\nreal-benchmark average penalty-weighted accuracy: {:.1}%\n",
        100.0 * avg_pen
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::metrics;

    #[test]
    fn fig1_renders_all_nine_panels() {
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let real = real_benchmark_records(&dev, &cfg);
        assert_eq!(real.len(), 8);
        let synth: Vec<SpeedupRecord> = real[0].1.clone(); // stand-in
        let s = fig1(&synth, &real);
        for panel in ["(a)", "(b)", "(i)"] {
            assert!(s.contains(panel), "missing {panel}");
        }
        assert!(s.contains("transpose"));
        assert!(s.contains("MRI-GRIDDING"));
    }

    #[test]
    fn fig6_renders_error_bars() {
        let a = Accuracy {
            count_based: 0.86,
            penalty_weighted: 0.95,
            min_score: 0.30,
            max_score: 1.0,
            n: 100,
            skipped: 0,
        };
        let s = fig6(&a, &[("transpose".into(), a)]);
        assert!(s.contains("86.0%"));
        assert!(s.contains("95.0%"));
        assert!(s.contains("0.30"));
    }

    #[test]
    fn accuracy_struct_roundtrips_through_eval() {
        // smoke: metrics::evaluate on a tiny set feeds fig6 cleanly
        let dev = DeviceSpec::m2090();
        let cfg = MeasureConfig::deterministic();
        let recs: Vec<SpeedupRecord> = (crate::workloads::all()[0].instances)(&dev)
            .iter()
            .map(|d| measure(d, &dev, &cfg))
            .collect();
        let refs: Vec<&SpeedupRecord> = recs.iter().collect();
        let acc = metrics::evaluate_model(&refs, |_| true);
        let s = fig6(&acc, &[]);
        assert!(s.contains("synthetic"));
    }
}
