//! ASCII rendering of speedup histograms (paper Fig. 1).

use crate::sim::exec::SpeedupRecord;
use crate::util::stats::Histogram;

/// Build the log2-speedup histogram the Fig.-1 panels use.
pub fn speedup_histogram(records: &[SpeedupRecord]) -> Histogram {
    let mut h = Histogram::new(-7.0, 7.0, 28); // 0.008x .. 128x, half-octave bins
    for r in records {
        h.add(r.speedup.log2());
    }
    h
}

/// Render a histogram with a title line, one row per non-empty bin.
pub fn render(title: &str, records: &[SpeedupRecord], width: usize) -> String {
    let h = speedup_histogram(records);
    let beneficial =
        records.iter().filter(|r| r.beneficial()).count() as f64
            / records.len().max(1) as f64;
    let max_bin = h.bins.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    out.push_str(&format!(
        "{title}  (n={}, beneficial={:.0}%)\n",
        records.len(),
        100.0 * beneficial
    ));
    for (i, &c) in h.bins.iter().enumerate() {
        if c == 0 {
            continue;
        }
        let (lo, hi) = h.bin_edges(i);
        let bar = "#".repeat(((c as usize * width) / max_bin as usize).max(1));
        out.push_str(&format!(
            "  {:>6.2}x..{:<6.2}x {:>7} {bar}\n",
            2f64.powf(lo),
            2f64.powf(hi),
            c
        ));
    }
    if h.underflow + h.overflow > 0 {
        out.push_str(&format!(
            "  (underflow {} / overflow {})\n",
            h.underflow, h.overflow
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::features::NUM_FEATURES;

    fn rec(speedup: f64) -> SpeedupRecord {
        SpeedupRecord {
            name: "r".into(),
            features: [0.0; NUM_FEATURES],
            speedup,
            baseline_time: 1.0,
            optimized_time: 1.0 / speedup,
        }
    }

    #[test]
    fn histogram_covers_paper_range() {
        // The paper reports 0.03x .. 49.6x; both must land inside bins.
        let recs = vec![rec(0.03), rec(49.6), rec(1.0), rec(2.0)];
        let h = speedup_histogram(&recs);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
        assert_eq!(h.count, 4);
    }

    #[test]
    fn render_shows_counts_and_fraction() {
        let recs = vec![rec(0.5), rec(2.0), rec(4.0)];
        let s = render("test", &recs, 20);
        assert!(s.contains("n=3"));
        assert!(s.contains("beneficial=67%"));
        assert!(s.contains('#'));
    }
}
