//! Rendering of the paper's figures and tables as terminal output: the
//! Fig. 1 speedup histograms, the Fig. 6 accuracy chart, Tables 1-3.

pub mod figures;
pub mod hist;
pub mod tables;
