//! Typed, span-carrying diagnostics for the semantic-analysis pass.
//!
//! Every finding of [`super::sema`] flows through the [`Diagnostics`]
//! sink as a [`Diagnostic`]: a stable rule ID ([`Rule`]), a severity
//! level ([`Severity`]), the `line:col` anchor of the offending code,
//! the kernel (and, where it applies, the array) it concerns, and a
//! human-readable message. The sink renders either as text lines (the
//! default `lmtuner lint` output) or as machine-readable JSON via
//! [`crate::util::json`] (`lmtuner lint --json`).
//!
//! Severity contract (DESIGN.md §2h):
//!
//! * `Deny` — the kernel is wrong or outside the analyzable subset in a
//!   way that invalidates downstream results; `lint` exits 2 and
//!   `analyze` refuses with exit code 3.
//! * `Warn` — a performance hazard the staging transform does not fix
//!   by itself (bank-conflicted lane stride, uncoalesced access inside
//!   a loop, over-budget staged region); promoted to the deny set by
//!   `lint --deny warn`.
//! * `Note` — informational findings (staging certificates, one-off
//!   uncoalesced accesses that staging itself is the fix for).
//!
//! Rule IDs are stable across releases: tests and CI grep for them, and
//! JSON consumers key on them. Never renumber; retire IDs instead.

use std::fmt;

use super::lexer::Pos;
use crate::util::json::Json;

/// Diagnostic severity, ordered `Note < Warn < Deny`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Note,
    Warn,
    Deny,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The rule inventory (DESIGN.md §2h). IDs are stable; severity is the
/// rule's default (the emitter may demote, never promote).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// `barrier()` reachable under work-item-divergent control flow.
    BarrierDivergence,
    /// Affine bounds: tap/constant column offsets reach past the row
    /// stride, so the flattened index wraps into a different row.
    OutOfBounds,
    /// The staged region for an array exceeds the device's per-workgroup
    /// local-memory budget.
    RegionBudget,
    /// Warp lane stride is a multiple of the 32 shared-memory banks and
    /// the extractor's +1-column pad would not apply.
    BankConflict,
    /// Uncoalesced x-lane access (more than one DRAM transaction per warp).
    Uncoalesced,
    /// Staging-safety certificate result for one array.
    Stageability,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::BarrierDivergence,
        Rule::OutOfBounds,
        Rule::RegionBudget,
        Rule::BankConflict,
        Rule::Uncoalesced,
        Rule::Stageability,
    ];

    /// Stable machine-readable identifier.
    pub fn id(self) -> &'static str {
        match self {
            Rule::BarrierDivergence => "LM001",
            Rule::OutOfBounds => "LM002",
            Rule::RegionBudget => "LM003",
            Rule::BankConflict => "LM004",
            Rule::Uncoalesced => "LM005",
            Rule::Stageability => "LM006",
        }
    }

    /// Default severity of the rule.
    pub fn severity(self) -> Severity {
        match self {
            Rule::BarrierDivergence | Rule::OutOfBounds => Severity::Deny,
            Rule::RegionBudget | Rule::BankConflict | Rule::Uncoalesced => Severity::Warn,
            Rule::Stageability => Severity::Note,
        }
    }
}

/// One finding: rule, severity, source anchor, owning kernel, the array
/// it concerns (when array-specific), and the rendered message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: Rule,
    pub severity: Severity,
    pub pos: Pos,
    pub kernel: String,
    pub array: Option<String>,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: {}[{}]: {}",
            self.pos,
            self.severity,
            self.rule.id(),
            self.message
        )
    }
}

impl Diagnostic {
    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("rule", Json::Str(self.rule.id().to_string()))
            .set("severity", Json::Str(self.severity.as_str().to_string()))
            .set("line", Json::Num(self.pos.line as f64))
            .set("col", Json::Num(self.pos.col as f64))
            .set("kernel", Json::Str(self.kernel.clone()))
            .set(
                "array",
                match &self.array {
                    Some(a) => Json::Str(a.clone()),
                    None => Json::Null,
                },
            )
            .set("message", Json::Str(self.message.clone()));
        j
    }
}

/// The reusable diagnostics sink: collects findings, counts by severity,
/// sorts by source position, renders JSON.
#[derive(Debug, Default)]
pub struct Diagnostics {
    diags: Vec<Diagnostic>,
}

impl Diagnostics {
    pub fn new() -> Diagnostics {
        Diagnostics::default()
    }

    /// Report a finding at the rule's default severity.
    pub fn report(
        &mut self,
        rule: Rule,
        pos: Pos,
        kernel: &str,
        array: Option<&str>,
        message: String,
    ) {
        self.report_as(rule, rule.severity(), pos, kernel, array, message);
    }

    /// Report a finding at an explicit severity, which must not exceed
    /// the rule's default (emitters may demote, never promote).
    pub fn report_as(
        &mut self,
        rule: Rule,
        severity: Severity,
        pos: Pos,
        kernel: &str,
        array: Option<&str>,
        message: String,
    ) {
        debug_assert!(severity <= rule.severity(), "{}: severity promotion", rule.id());
        self.diags.push(Diagnostic {
            rule,
            severity,
            pos,
            kernel: kernel.to_string(),
            array: array.map(str::to_string),
            message,
        });
    }

    pub fn iter(&self) -> std::slice::Iter<'_, Diagnostic> {
        self.diags.iter()
    }

    pub fn len(&self) -> usize {
        self.diags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.diags.iter().filter(|d| d.severity == sev).count()
    }

    pub fn deny_count(&self) -> usize {
        self.count(Severity::Deny)
    }

    pub fn warn_count(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn note_count(&self) -> usize {
        self.count(Severity::Note)
    }

    /// Highest severity present, if any.
    pub fn worst(&self) -> Option<Severity> {
        self.diags.iter().map(|d| d.severity).max()
    }

    /// Order findings for presentation: by source position, then by
    /// descending severity, then by rule ID — deterministic output for
    /// the golden suite and CI greps.
    pub fn sort(&mut self) {
        self.diags.sort_by(|a, b| {
            (a.pos.line, a.pos.col)
                .cmp(&(b.pos.line, b.pos.col))
                .then(b.severity.cmp(&a.severity))
                .then(a.rule.cmp(&b.rule))
        });
    }

    /// Machine-readable rendering: a severity summary plus one object
    /// per diagnostic, parseable back by [`Json::parse`].
    pub fn to_json(&self) -> Json {
        let mut summary = Json::obj();
        summary
            .set("deny", Json::Num(self.deny_count() as f64))
            .set("warn", Json::Num(self.warn_count() as f64))
            .set("note", Json::Num(self.note_count() as f64));
        let mut j = Json::obj();
        j.set("summary", summary)
            .set("diagnostics", Json::Arr(self.diags.iter().map(Diagnostic::to_json).collect()));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn severity_orders_note_warn_deny() {
        assert!(Severity::Note < Severity::Warn);
        assert!(Severity::Warn < Severity::Deny);
        assert_eq!(Severity::Deny.as_str(), "deny");
    }

    #[test]
    fn rule_ids_are_stable_and_unique() {
        let ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        assert_eq!(ids, ["LM001", "LM002", "LM003", "LM004", "LM005", "LM006"]);
        assert_eq!(Rule::BarrierDivergence.severity(), Severity::Deny);
        assert_eq!(Rule::BankConflict.severity(), Severity::Warn);
        assert_eq!(Rule::Stageability.severity(), Severity::Note);
    }

    #[test]
    fn sink_counts_sorts_and_renders() {
        let mut d = Diagnostics::new();
        let at = |line, col| Pos { line, col };
        d.report(Rule::Uncoalesced, at(9, 5), "k", Some("a"), "slow".into());
        d.report(Rule::BarrierDivergence, at(3, 1), "k", None, "div".into());
        d.sort();
        assert_eq!(d.len(), 2);
        assert_eq!(d.deny_count(), 1);
        assert_eq!(d.warn_count(), 1);
        assert_eq!(d.worst(), Some(Severity::Deny));
        assert_eq!(d.iter().next().unwrap().rule, Rule::BarrierDivergence);
        let line = d.iter().next().unwrap().to_string();
        assert!(line.starts_with("3:1: deny[LM001]:"), "{line}");
    }

    #[test]
    fn json_round_trips() {
        let mut d = Diagnostics::new();
        d.report(
            Rule::OutOfBounds,
            Pos { line: 7, col: 13 },
            "conv",
            Some("input"),
            "column tap offsets 0..599 reach past the row".into(),
        );
        let j = d.to_json();
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back, j);
        let diag = &back.get("diagnostics").unwrap().as_arr().unwrap()[0];
        assert_eq!(diag.get("rule").unwrap().as_str(), Some("LM002"));
        assert_eq!(diag.get("line").unwrap().as_usize(), Some(7));
        assert_eq!(back.get("summary").unwrap().get("deny").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn note_demotion_is_allowed() {
        let mut d = Diagnostics::new();
        d.report_as(
            Rule::Uncoalesced,
            Severity::Note,
            Pos { line: 1, col: 1 },
            "k",
            Some("out"),
            "one-off uncoalesced store".into(),
        );
        assert_eq!(d.note_count(), 1);
        assert_eq!(d.warn_count(), 0);
    }
}
