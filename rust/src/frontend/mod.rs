//! Kernel-source frontend: from real OpenCL C to the 18 model features.
//!
//! The rest of the system consumes [`KernelDescriptor`]s — the synthetic
//! generator emits them directly and `crate::workloads` hand-maps the
//! paper's Table 3 benchmarks. This subsystem closes the loop for
//! arbitrary user kernels: it parses a practical subset of OpenCL C
//! ([`parser`]), performs per-array affine access analysis ([`access`],
//! [`extract`]), and synthesizes the descriptor + canonical feature
//! vector for a given launch configuration and device — which is what
//! `lmtuner analyze <kernel.cl>` runs end-to-end into the trained
//! forest. On top of the same AST, the semantic-analysis pass
//! ([`sema`], diagnostics sink in [`diag`]) powers `lmtuner lint`:
//! barrier-divergence and affine-bounds checks, coalescing/bank-conflict
//! lints, and the staging-safety certificate ([`sema::certify`]) the
//! future source-to-source transform depends on. `analyze` refuses to
//! proceed past Deny-level diagnostics (exit-code table in DESIGN.md
//! §2h).
//!
//! The supported subset and every modeling rule (loop classification,
//! coalescing, computation accounting, the register heuristic) are
//! specified in DESIGN.md §2d; the golden suite in
//! `rust/tests/frontend.rs` reconciles extracted descriptors against
//! the hand-mapped convolution / matrixMul / transpose workloads.
//!
//! This is the first subsystem that consumes untrusted user input:
//! every failure mode is a typed error carrying a source position
//! ([`FrontendError`]), and nothing here panics on malformed source.
//!
//! ```
//! use lmtuner::frontend::{self, AnalyzeOptions, Bindings};
//! use lmtuner::gpu::spec::DeviceSpec;
//! use lmtuner::kernelmodel::launch::{GridGeom, Launch, WgGeom};
//!
//! let src = "
//! __kernel void scale(__global const float* in, __global float* out, int w) {
//!     int x = get_global_id(0);
//!     int y = get_global_id(1);
//!     out[y * w + x] = in[y * w + x] * 2.0f;
//! }";
//! let opts = AnalyzeOptions {
//!     target: "in".into(),
//!     kernel: None,
//!     launch: Launch::new(WgGeom { w: 16, h: 8 }, GridGeom { w: 512, h: 512 }),
//!     bindings: Bindings::new().set("w", 512),
//! };
//! let d = frontend::analyze(src, &opts, &DeviceSpec::m2090()).unwrap();
//! assert_eq!(d.taps, 1);
//! let features = lmtuner::kernelmodel::features::extract(&d);
//! assert!(features.iter().all(|f| f.is_finite()));
//! ```

pub mod access;
pub mod ast;
pub mod diag;
pub mod extract;
pub mod lexer;
pub mod parser;
pub mod sema;

use std::fmt;

use crate::gpu::spec::DeviceSpec;
use crate::kernelmodel::descriptor::KernelDescriptor;

pub use diag::{Diagnostic, Diagnostics, Rule, Severity};
pub use extract::{AnalyzeOptions, Bindings, ExtractError, ExtractErrorKind, TargetProfile};
pub use lexer::{LexError, Pos};
pub use parser::ParseError;
pub use sema::{certify, lint_program, LintReport, SemaOptions, StagingCertificate};

/// Any frontend failure: lexing, parsing, or analysis. All variants are
/// positioned (line:column) and none are produced by panicking.
#[derive(Debug)]
pub enum FrontendError {
    Lex(LexError),
    Parse(ParseError),
    Extract(ExtractError),
}

impl FrontendError {
    /// The source position the error points at.
    pub fn pos(&self) -> Pos {
        match self {
            FrontendError::Lex(e) => e.pos,
            FrontendError::Parse(e) => e.pos,
            FrontendError::Extract(e) => e.pos,
        }
    }
}

impl fmt::Display for FrontendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrontendError::Lex(e) => write!(f, "{e}"),
            FrontendError::Parse(e) => write!(f, "{e}"),
            FrontendError::Extract(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for FrontendError {}

impl From<LexError> for FrontendError {
    fn from(e: LexError) -> Self {
        FrontendError::Lex(e)
    }
}

impl From<ParseError> for FrontendError {
    fn from(e: ParseError) -> Self {
        FrontendError::Parse(e)
    }
}

impl From<ExtractError> for FrontendError {
    fn from(e: ExtractError) -> Self {
        FrontendError::Extract(e)
    }
}

/// Parse a translation unit.
pub fn parse_program(src: &str) -> Result<ast::Program, FrontendError> {
    let _span = crate::span!("frontend.parse");
    Ok(parser::parse(src)?)
}

/// End-to-end: parse `src` and synthesize the kernel descriptor for the
/// target array / launch / device in `opts`. The 18 features follow via
/// `kernelmodel::features::extract`.
pub fn analyze(
    src: &str,
    opts: &AnalyzeOptions,
    dev: &DeviceSpec,
) -> Result<KernelDescriptor, FrontendError> {
    let _span = crate::span!("frontend.analyze");
    let prog = {
        let _parse = crate::span!("frontend.parse");
        parser::parse(src)?
    };
    let _extract = crate::span!("frontend.extract");
    Ok(extract::extract_descriptor(&prog, opts, dev)?)
}
