//! Affine index analysis: the algebra under the feature extractor.
//!
//! Every array subscript the frontend accepts must reduce to an *affine
//! form* — an integer-linear combination of work-item intrinsics and
//! loop variables plus a constant (scalar kernel arguments are bound to
//! concrete values first, so they fold into coefficients). This module
//! defines that form ([`Affine`]), the row/column decomposition of a
//! flattened 2D index ([`split_row_col`]), and the warp-coalescing
//! classification ([`tx_per_access`]).
//!
//! **Row/column decomposition.** Real kernels flatten 2D arrays as
//! `row * stride + col`. The stride is recovered from the coefficients
//! themselves: every |coefficient| >= [`STRIDE_MIN`] must be a multiple
//! of the smallest such coefficient S (else: typed mixed-stride error);
//! terms with |c| >= STRIDE_MIN contribute `c/S` to the row, the rest to
//! the column. The constant splits by rounding to the nearest multiple
//! of S, so small negative column offsets (stencil taps like `-radius`)
//! stay in the column. Indices with no large coefficient are 1D (row 0).
//!
//! **Coalescing.** Work items linearize row-major with x fastest
//! (`Launch::warp_lanes`), so a warp covers `dx` adjacent x-lanes. The
//! y-spread of a warp is the launch geometry's doing, not the access
//! pattern's, and is deliberately ignored (the paper's non-coalescing
//! degree measures the access's own scatter):
//!
//! * row coordinate depends on x  ->  `dx` distinct rows, one
//!   transaction each (the transposed-write shape);
//! * else column depends on x with coefficient c  ->  the warp's row
//!   segment spans `dx*|c|` elements: `ceil(dx*|c|/seg)` transactions
//!   (1 when unit-stride);
//! * else  ->  broadcast, 1 transaction.

use std::collections::BTreeMap;
use std::fmt;

use crate::kernelmodel::launch::Launch;

/// Base variables an index may depend on after constant folding.
/// Loop variables are numbered in encounter order by the extractor
/// (shadowed names get distinct ids).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Var {
    /// `get_global_id(d)`, d in {0, 1}.
    Gid(u8),
    /// `get_local_id(d)`.
    Lid(u8),
    /// `get_group_id(d)` — constant within a workgroup.
    Group(u8),
    /// Loop variable (id assigned by the extractor).
    Loop(u32),
}

/// An integer-affine expression: `sum(coeff * var) + constant`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Affine {
    pub terms: BTreeMap<Var, i64>,
    pub c: i64,
}

/// Affine arithmetic can overflow i64 only through absurd user input;
/// every operation is checked and reports this typed error.
#[derive(Clone, Debug)]
pub struct Overflow;

impl fmt::Display for Overflow {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "index arithmetic overflows i64")
    }
}

impl Affine {
    pub fn constant(c: i64) -> Affine {
        Affine { terms: BTreeMap::new(), c }
    }

    pub fn var(v: Var) -> Affine {
        let mut terms = BTreeMap::new();
        terms.insert(v, 1);
        Affine { terms, c: 0 }
    }

    pub fn is_const(&self) -> bool {
        self.terms.is_empty()
    }

    pub fn as_const(&self) -> Option<i64> {
        self.is_const().then_some(self.c)
    }

    pub fn coeff(&self, v: Var) -> i64 {
        self.terms.get(&v).copied().unwrap_or(0)
    }

    pub fn add(&self, other: &Affine) -> Result<Affine, Overflow> {
        let mut out = self.clone();
        for (v, c) in &other.terms {
            let e = out.terms.entry(*v).or_insert(0);
            *e = e.checked_add(*c).ok_or(Overflow)?;
            if *e == 0 {
                out.terms.remove(v);
            }
        }
        out.c = out.c.checked_add(other.c).ok_or(Overflow)?;
        Ok(out)
    }

    pub fn neg(&self) -> Result<Affine, Overflow> {
        self.scale(-1)
    }

    pub fn sub(&self, other: &Affine) -> Result<Affine, Overflow> {
        self.add(&other.neg()?)
    }

    pub fn scale(&self, k: i64) -> Result<Affine, Overflow> {
        if k == 0 {
            return Ok(Affine::constant(0));
        }
        let mut out = Affine::constant(self.c.checked_mul(k).ok_or(Overflow)?);
        for (v, c) in &self.terms {
            out.terms.insert(*v, c.checked_mul(k).ok_or(Overflow)?);
        }
        Ok(out)
    }

    /// Exact division by a constant: every coefficient and the constant
    /// must be divisible (used for `expr / k` in loop bounds & indices).
    /// Checked throughout — `i64::MIN / -1` yields `None`, not an abort.
    pub fn div_exact(&self, k: i64) -> Option<Affine> {
        if k == 0 {
            return None;
        }
        if self.c.checked_rem(k)? != 0 {
            return None;
        }
        let mut out = Affine::constant(self.c.checked_div(k)?);
        for (v, c) in &self.terms {
            if c.checked_rem(k)? != 0 {
                return None;
            }
            out.terms.insert(*v, c.checked_div(k)?);
        }
        Some(out)
    }

    /// Does this expression depend on any work-item coordinate?
    pub fn depends_on_wi(&self) -> bool {
        self.terms.keys().any(|v| matches!(v, Var::Gid(_) | Var::Lid(_)))
    }

    /// Coefficient of the x / y work-item coordinate (gid and lid move
    /// in lockstep within a workgroup, so their coefficients add).
    pub fn wi_coeff(&self, dim: u8) -> i64 {
        self.coeff(Var::Gid(dim)).saturating_add(self.coeff(Var::Lid(dim)))
    }
}

impl fmt::Display for Affine {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (v, c) in &self.terms {
            if !first {
                write!(f, " + ")?;
            }
            first = false;
            let name = match v {
                Var::Gid(d) => format!("gid{d}"),
                Var::Lid(d) => format!("lid{d}"),
                Var::Group(d) => format!("grp{d}"),
                Var::Loop(i) => format!("L{i}"),
            };
            if *c == 1 {
                write!(f, "{name}")?;
            } else {
                write!(f, "{c}*{name}")?;
            }
        }
        if first {
            write!(f, "{}", self.c)
        } else if self.c != 0 {
            write!(f, " + {}", self.c)
        } else {
            Ok(())
        }
    }
}

/// Smallest coefficient magnitude treated as a row stride. Column terms
/// (work-item x offsets, stencil taps, tile offsets) stay well below
/// this in the supported kernel shapes; problem-size strides sit well
/// above it.
pub const STRIDE_MIN: i64 = 64;

/// A flattened index decomposed into 2D coordinates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowCol {
    pub row: Affine,
    pub col: Affine,
    /// Elements per row; 0 means the index was 1D (row == 0).
    pub stride: i64,
}

/// Decompose `index = row * stride + col`; see the module docs for the
/// stride-recovery rule. Errors are strings; the extractor wraps them
/// with the access's source position.
pub fn split_row_col(index: &Affine) -> Result<RowCol, String> {
    let stride = index
        .terms
        .values()
        .map(|c| c.abs())
        .filter(|c| *c >= STRIDE_MIN)
        .min()
        .unwrap_or(0);
    if stride == 0 {
        return Ok(RowCol { row: Affine::constant(0), col: index.clone(), stride: 0 });
    }
    let mut row = Affine::constant(0);
    let mut col = Affine::constant(0);
    for (v, c) in &index.terms {
        if c.abs() >= STRIDE_MIN {
            if c % stride != 0 {
                return Err(format!(
                    "cannot separate rows from columns: coefficient {c} is not \
                     a multiple of the inferred row stride {stride}"
                ));
            }
            row.terms.insert(*v, c / stride);
        } else {
            col.terms.insert(*v, *c);
        }
    }
    // Constant: round to the nearest multiple of the stride so small
    // negative tap offsets stay in the column. i128 so extreme constants
    // cannot wrap (the no-panic contract covers this path too).
    let c = index.c as i128;
    let s = stride as i128;
    let half = s / 2;
    let rounded_rows = if c >= 0 {
        (c + half) / s
    } else {
        (c - half) / s
    };
    row.c = i64::try_from(rounded_rows)
        .map_err(|_| "index constant exceeds the addressable row range".to_string())?;
    // |c - rounded_rows*s| < s <= i64::MAX, so the cast is lossless.
    col.c = (c - rounded_rows * s) as i64;
    Ok(RowCol { row, col, stride })
}

/// Average DRAM transactions one warp issues for one dynamic execution
/// of this access in the unoptimized kernel (1.0 = coalesced or
/// broadcast). `seg` is the transaction width in elements.
pub fn tx_per_access(rc: &RowCol, launch: &Launch, warp_size: u32, seg: u32) -> f64 {
    let (dx, _dy) = launch.warp_lanes(warp_size);
    let dx = dx.max(1) as i64;
    let seg = seg.max(1) as i64;
    if rc.row.wi_coeff(0) != 0 {
        // Each x-lane lands in its own row.
        return dx as f64;
    }
    let cx = rc.col.wi_coeff(0).abs();
    if cx == 0 {
        return 1.0; // broadcast along x
    }
    let span = dx.saturating_mul(cx) as u64;
    (span.div_ceil(seg as u64).max(1)) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernelmodel::launch::{GridGeom, WgGeom};

    fn launch(w: u32, h: u32) -> Launch {
        Launch::new(WgGeom { w, h }, GridGeom { w: 2048, h: 2048 })
    }

    fn aff(terms: &[(Var, i64)], c: i64) -> Affine {
        let mut a = Affine::constant(c);
        for (v, k) in terms {
            a.terms.insert(*v, *k);
        }
        a
    }

    #[test]
    fn affine_algebra() {
        let x = Affine::var(Var::Gid(0));
        let y = Affine::var(Var::Gid(1));
        let e = y.scale(512).unwrap().add(&x).unwrap().add(&Affine::constant(-3)).unwrap();
        assert_eq!(e.coeff(Var::Gid(1)), 512);
        assert_eq!(e.coeff(Var::Gid(0)), 1);
        assert_eq!(e.c, -3);
        assert!(e.sub(&e).unwrap().is_const());
        assert_eq!(e.sub(&e).unwrap().as_const(), Some(0));
        assert_eq!(e.scale(2).unwrap().coeff(Var::Gid(1)), 1024);
        assert!(Affine::constant(i64::MAX).add(&Affine::constant(1)).is_err());
    }

    #[test]
    fn div_exact_requires_divisibility() {
        let e = aff(&[(Var::Gid(1), 512)], 1024);
        let d = e.div_exact(512).unwrap();
        assert_eq!(d.coeff(Var::Gid(1)), 1);
        assert_eq!(d.c, 2);
        assert!(e.div_exact(100).is_none());
        assert!(e.div_exact(0).is_none());
        // i64::MIN / -1 must not abort.
        assert!(Affine::constant(i64::MIN).div_exact(-1).is_none());
    }

    #[test]
    fn row_col_split_recovers_stride() {
        // (gy + k) * 512 + gx  with tap constant -2
        let idx = aff(&[(Var::Gid(1), 512), (Var::Loop(0), 512), (Var::Gid(0), 1)], -2);
        let rc = split_row_col(&idx).unwrap();
        assert_eq!(rc.stride, 512);
        assert_eq!(rc.row, aff(&[(Var::Gid(1), 1), (Var::Loop(0), 1)], 0));
        assert_eq!(rc.col, aff(&[(Var::Gid(0), 1)], -2));
    }

    #[test]
    fn one_dim_and_mixed_stride_cases() {
        let idx = aff(&[(Var::Gid(0), 1), (Var::Loop(0), 4)], 7);
        let rc = split_row_col(&idx).unwrap();
        assert_eq!(rc.stride, 0);
        assert_eq!(rc.row.as_const(), Some(0));
        assert_eq!(rc.col, idx);

        // 768 is not a multiple of 512 -> typed mixed-stride error.
        let bad = aff(&[(Var::Gid(1), 512), (Var::Loop(0), 768)], 0);
        assert!(split_row_col(&bad).is_err());
    }

    #[test]
    fn constant_rounds_to_nearest_stride_multiple() {
        let idx = aff(&[(Var::Gid(1), 512)], 510);
        let rc = split_row_col(&idx).unwrap();
        assert_eq!(rc.row.c, 1);
        assert_eq!(rc.col.c, -2);
        let idx = aff(&[(Var::Gid(1), 512)], -3);
        let rc = split_row_col(&idx).unwrap();
        assert_eq!(rc.row.c, 0);
        assert_eq!(rc.col.c, -3);
    }

    #[test]
    fn extreme_constants_do_not_panic() {
        // The no-panic contract: an i64::MAX index constant must round
        // without wrapping (debug builds would otherwise abort).
        let idx = aff(&[(Var::Gid(1), 64)], i64::MAX);
        let rc = split_row_col(&idx).unwrap();
        assert!(rc.col.c.abs() <= 32);
        let idx = aff(&[(Var::Gid(1), 64)], i64::MIN);
        let rc = split_row_col(&idx).unwrap();
        assert!(rc.col.c.abs() <= 32);
    }

    #[test]
    fn coalescing_classification() {
        let l = launch(16, 8);
        let seg = 32;
        // in[y*w + x]: unit-stride along x -> 1 transaction.
        let rc = split_row_col(&aff(&[(Var::Gid(1), 512), (Var::Gid(0), 1)], 0)).unwrap();
        assert_eq!(tx_per_access(&rc, &l, 32, seg), 1.0);
        // out[x*h + y]: x drives the row -> dx transactions.
        let rc = split_row_col(&aff(&[(Var::Gid(0), 512), (Var::Gid(1), 1)], 0)).unwrap();
        assert_eq!(tx_per_access(&rc, &l, 32, seg), 16.0);
        // b[k*w + x] broadcast row, coalesced col.
        let rc = split_row_col(&aff(&[(Var::Loop(0), 512), (Var::Gid(0), 1)], 0)).unwrap();
        assert_eq!(tx_per_access(&rc, &l, 32, seg), 1.0);
        // a[y*w + k]: no x anywhere -> broadcast.
        let rc = split_row_col(&aff(&[(Var::Gid(1), 512), (Var::Loop(0), 1)], 0)).unwrap();
        assert_eq!(tx_per_access(&rc, &l, 32, seg), 1.0);
        // stride-2 column access: 32 lanes span 64 elements -> 2 segments.
        let rc = split_row_col(&aff(&[(Var::Gid(1), 512), (Var::Gid(0), 2)], 0)).unwrap();
        assert_eq!(tx_per_access(&rc, &launch(32, 4), 32, seg), 2.0);
    }

    #[test]
    fn wi_coeff_sums_gid_and_lid() {
        let e = aff(&[(Var::Gid(0), 2), (Var::Lid(0), 3), (Var::Gid(1), 5)], 0);
        assert_eq!(e.wi_coeff(0), 5);
        assert_eq!(e.wi_coeff(1), 5);
        assert!(e.depends_on_wi());
        assert!(!aff(&[(Var::Group(0), 4)], 1).depends_on_wi());
    }
}
