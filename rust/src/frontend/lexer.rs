//! Tokenizer for the OpenCL C subset the frontend understands.
//!
//! Every token carries its source position (1-based line/column), and
//! every failure is a typed, positioned [`LexError`] — this layer is the
//! first to touch untrusted user input and must never panic. Supported
//! lexemes: identifiers (including the `__kernel`/`__global`/... address
//! qualifiers, which are plain identifiers at this level), decimal
//! integer and float literals (optional exponent, optional `f`/`u`/`l`
//! suffix), the C operator/punctuation set the parser consumes, and
//! `//` / `/* */` comments. Out of scope (typed errors, documented in
//! DESIGN.md §2d): preprocessor directives, string/char literals, hex
//! literals.

use std::fmt;

/// 1-based source position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pos {
    pub line: u32,
    pub col: u32,
}

impl Pos {
    pub fn start() -> Pos {
        Pos { line: 1, col: 1 }
    }
}

impl fmt::Display for Pos {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// A lexeme. Punctuation/operators are interned static strings so the
/// parser can match on `&str`.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Int(i64),
    Float(f64),
    Punct(&'static str),
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "`{s}`"),
            Tok::Int(v) => write!(f, "integer `{v}`"),
            Tok::Float(v) => write!(f, "float `{v}`"),
            Tok::Punct(p) => write!(f, "`{p}`"),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub pos: Pos,
}

/// Typed, positioned lexer error.
#[derive(Clone, Debug)]
pub struct LexError {
    pub pos: Pos,
    pub msg: String,
}

impl fmt::Display for LexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "lex error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for LexError {}

/// Multi-character operators first (longest match wins), then singles.
const PUNCT2: [&str; 12] = ["+=", "-=", "*=", "/=", "<=", ">=", "==", "!=", "&&", "||", "++", "--"];
const PUNCT1: [&str; 17] = [
    "+", "-", "*", "/", "%", "<", ">", "=", "(", ")", "[", "]", "{", "}", ";", ",", "!",
];

struct Scanner<'a> {
    src: &'a [u8],
    i: usize,
    pos: Pos,
}

impl<'a> Scanner<'a> {
    fn peek(&self) -> Option<u8> {
        self.src.get(self.i).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.i + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.i += 1;
        if c == b'\n' {
            self.pos.line += 1;
            self.pos.col = 1;
        } else {
            self.pos.col += 1;
        }
        Some(c)
    }

    fn err(&self, pos: Pos, msg: impl Into<String>) -> LexError {
        LexError { pos, msg: msg.into() }
    }

    fn skip_trivia(&mut self) -> Result<(), LexError> {
        loop {
            match self.peek() {
                Some(c) if c.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'/') if self.peek2() == Some(b'/') => {
                    while let Some(c) = self.peek() {
                        if c == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                Some(b'/') if self.peek2() == Some(b'*') => {
                    let open = self.pos;
                    self.bump();
                    self.bump();
                    loop {
                        match self.peek() {
                            None => {
                                return Err(self.err(open, "unterminated block comment"));
                            }
                            Some(b'*') if self.peek2() == Some(b'/') => {
                                self.bump();
                                self.bump();
                                break;
                            }
                            _ => {
                                self.bump();
                            }
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn number(&mut self) -> Result<Tok, LexError> {
        let start_pos = self.pos;
        let start = self.i;
        let mut is_float = false;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            is_float = true;
            self.bump();
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            // Exponent only if followed by a (signed) digit — otherwise the
            // `e` belongs to an identifier-like suffix and is an error below.
            let after_sign = match self.peek2() {
                Some(b'+') | Some(b'-') => self.src.get(self.i + 2).copied(),
                other => other,
            };
            if matches!(after_sign, Some(c) if c.is_ascii_digit()) {
                is_float = true;
                self.bump();
                if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                    self.bump();
                }
                while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                    self.bump();
                }
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.i])
            .map_err(|_| self.err(start_pos, "non-utf8 number"))?
            .to_string();
        // Single trailing type suffix (f/F on floats, u/U/l/L on ints).
        match self.peek() {
            Some(b'f') | Some(b'F') => {
                is_float = true;
                self.bump();
            }
            Some(b'u') | Some(b'U') | Some(b'l') | Some(b'L') if !is_float => {
                self.bump();
            }
            _ => {}
        }
        if matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == b'_') {
            return Err(self.err(start_pos, format!("malformed numeric literal `{text}...`")));
        }
        if is_float {
            let v: f64 = text
                .parse()
                .map_err(|_| self.err(start_pos, format!("malformed float literal `{text}`")))?;
            if !v.is_finite() {
                // Rust's FromStr parses overflowing literals to +-inf;
                // the pretty-printer could not re-lex those.
                return Err(self.err(start_pos, format!("float literal `{text}` out of range")));
            }
            Ok(Tok::Float(v))
        } else {
            text.parse::<i64>()
                .map(Tok::Int)
                .map_err(|_| self.err(start_pos, format!("integer literal `{text}` out of range")))
        }
    }
}

/// Tokenize `src`. Returns the token stream (without an EOF marker) or
/// the first typed error.
pub fn lex(src: &str) -> Result<Vec<Token>, LexError> {
    let mut s = Scanner { src: src.as_bytes(), i: 0, pos: Pos::start() };
    let mut out = Vec::new();
    loop {
        s.skip_trivia()?;
        let pos = s.pos;
        let c = match s.peek() {
            None => return Ok(out),
            Some(c) => c,
        };
        let dot_number = c == b'.' && matches!(s.peek2(), Some(d) if d.is_ascii_digit());
        let tok = if c.is_ascii_digit() || dot_number {
            s.number()?
        } else if c.is_ascii_alphabetic() || c == b'_' {
            let start = s.i;
            while matches!(s.peek(), Some(c) if c.is_ascii_alphanumeric() || c == b'_') {
                s.bump();
            }
            let text = std::str::from_utf8(&s.src[start..s.i])
                .map_err(|_| s.err(pos, "non-utf8 identifier"))?;
            Tok::Ident(text.to_string())
        } else if c == b'#' {
            return Err(s.err(
                pos,
                "preprocessor directives are not supported — bind constants \
                 via `--set name=value` instead",
            ));
        } else {
            let rest = &s.src[s.i..];
            let two = PUNCT2.iter().copied().find(|p| rest.starts_with(p.as_bytes()));
            let one = PUNCT1.iter().copied().find(|p| rest.starts_with(p.as_bytes()));
            if let Some(p) = two {
                s.bump();
                s.bump();
                Tok::Punct(p)
            } else if let Some(p) = one {
                s.bump();
                Tok::Punct(p)
            } else {
                return Err(s.err(pos, format!("unexpected character `{}`", c as char)));
            }
        };
        out.push(Token { tok, pos });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn idents_numbers_and_punct() {
        assert_eq!(
            kinds("int x = 42 + y2;"),
            vec![
                Tok::Ident("int".into()),
                Tok::Ident("x".into()),
                Tok::Punct("="),
                Tok::Int(42),
                Tok::Punct("+"),
                Tok::Ident("y2".into()),
                Tok::Punct(";"),
            ]
        );
    }

    #[test]
    fn float_forms_and_suffixes() {
        assert_eq!(kinds("0.0f"), vec![Tok::Float(0.0)]);
        assert_eq!(kinds("1.5"), vec![Tok::Float(1.5)]);
        assert_eq!(kinds("2e3"), vec![Tok::Float(2000.0)]);
        assert_eq!(kinds("1e-2"), vec![Tok::Float(0.01)]);
        assert_eq!(kinds("7u"), vec![Tok::Int(7)]);
    }

    #[test]
    fn multichar_operators_win() {
        assert_eq!(
            kinds("a += b <= c"),
            vec![
                Tok::Ident("a".into()),
                Tok::Punct("+="),
                Tok::Ident("b".into()),
                Tok::Punct("<="),
                Tok::Ident("c".into()),
            ]
        );
    }

    #[test]
    fn comments_are_skipped_and_positions_tracked() {
        let toks = lex("// line\n/* block\nblock */ x").unwrap();
        assert_eq!(toks.len(), 1);
        assert_eq!(toks[0].pos, Pos { line: 3, col: 10 });
    }

    #[test]
    fn errors_are_positioned_not_panics() {
        let e = lex("int x = @;").unwrap_err();
        assert_eq!(e.pos, Pos { line: 1, col: 9 });
        assert!(e.to_string().contains("1:9"));
        let e = lex("/* never closed").unwrap_err();
        assert!(e.msg.contains("unterminated"));
        let e = lex("#define R 4").unwrap_err();
        assert!(e.msg.contains("preprocessor"));
        let e = lex("int x = 12abc;").unwrap_err();
        assert!(e.msg.contains("malformed numeric"));
        assert!(lex("int big = 99999999999999999999;").is_err());
        // Overflowing float literals parse to inf in Rust; reject them so
        // every accepted Float token re-lexes from the pretty-printer.
        let e = lex("float f = 1e999;").unwrap_err();
        assert!(e.msg.contains("out of range"), "{}", e.msg);
    }
}
