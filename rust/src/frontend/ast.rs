//! AST for the OpenCL C subset, plus a canonical pretty-printer.
//!
//! The printer emits source the parser accepts (binary and unary
//! expressions are always fully parenthesized), so `parse(print(ast))`
//! reproduces the same tree shape up to redundant parentheses — the
//! frontend property suite asserts the round trip yields an *identical
//! kernel descriptor*. Every node carries the source [`Pos`] it came
//! from for positioned analysis errors.

use std::fmt;

use super::lexer::Pos;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    Ne,
    And,
    Or,
}

impl BinOp {
    pub fn as_str(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::EqEq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        }
    }

    /// Arithmetic (value-producing) as opposed to comparison/logical.
    pub fn is_arith(self) -> bool {
        matches!(self, BinOp::Add | BinOp::Sub | BinOp::Mul | BinOp::Div | BinOp::Rem)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(i64, Pos),
    Float(f64, Pos),
    Var(String, Pos),
    Call { name: String, args: Vec<Expr>, pos: Pos },
    /// `base[index]` — `base` must resolve to an array identifier; the
    /// analyzer rejects nested subscripts with a typed error.
    Index { base: Box<Expr>, index: Box<Expr>, pos: Pos },
    /// Unary minus / logical not.
    Unary { op: char, expr: Box<Expr>, pos: Pos },
    Bin { op: BinOp, lhs: Box<Expr>, rhs: Box<Expr>, pos: Pos },
}

impl Expr {
    pub fn pos(&self) -> Pos {
        match self {
            Expr::Int(_, p)
            | Expr::Float(_, p)
            | Expr::Var(_, p)
            | Expr::Call { pos: p, .. }
            | Expr::Index { pos: p, .. }
            | Expr::Unary { pos: p, .. }
            | Expr::Bin { pos: p, .. } => *p,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignOp {
    Set,
    Add,
    Sub,
    Mul,
    Div,
}

impl AssignOp {
    pub fn as_str(self) -> &'static str {
        match self {
            AssignOp::Set => "=",
            AssignOp::Add => "+=",
            AssignOp::Sub => "-=",
            AssignOp::Mul => "*=",
            AssignOp::Div => "/=",
        }
    }
}

/// Loop step clause: `v++`, `v--`, `v += e`, `v -= e`.
#[derive(Clone, Debug, PartialEq)]
pub enum ForStep {
    Inc,
    Dec,
    Add(Expr),
    Sub(Expr),
}

#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `int x = e;` / `float s;` — scalar local declaration.
    Decl { ty: String, name: String, init: Option<Expr>, pos: Pos },
    /// `lhs op= e;` where `lhs` is a variable or a subscript.
    Assign { target: Expr, op: AssignOp, value: Expr, pos: Pos },
    For {
        var_ty: String,
        var: String,
        init: Expr,
        /// Comparison op of the condition (`<`, `<=`, `>`, `>=`).
        cond_op: BinOp,
        bound: Expr,
        step: ForStep,
        body: Vec<Stmt>,
        pos: Pos,
    },
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt>, pos: Pos },
    /// Expression statement — in practice `barrier(...)` and friends.
    Call { name: String, args: Vec<Expr>, pos: Pos },
    Return { pos: Pos },
}

impl Stmt {
    /// Source anchor of the statement (diagnostics point here when no
    /// finer-grained expression position applies).
    pub fn pos(&self) -> Pos {
        match self {
            Stmt::Decl { pos, .. }
            | Stmt::Assign { pos, .. }
            | Stmt::For { pos, .. }
            | Stmt::If { pos, .. }
            | Stmt::Call { pos, .. }
            | Stmt::Return { pos } => *pos,
        }
    }
}

/// OpenCL address-space qualifier of a kernel parameter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AddrSpace {
    Global,
    Local,
    Constant,
    Private,
}

impl AddrSpace {
    pub fn as_str(self) -> &'static str {
        match self {
            AddrSpace::Global => "__global",
            AddrSpace::Local => "__local",
            AddrSpace::Constant => "__constant",
            AddrSpace::Private => "",
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Param {
    pub space: AddrSpace,
    pub is_const: bool,
    pub ty: String,
    pub is_ptr: bool,
    pub name: String,
    pub pos: Pos,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    pub pos: Pos,
}

#[derive(Clone, Debug, PartialEq, Default)]
pub struct Program {
    pub kernels: Vec<Kernel>,
}

// ---------------------------------------------------------------------
// Canonical pretty-printer.

fn fmt_float(v: f64) -> String {
    // `{:?}` always includes a decimal point or exponent, so the output
    // re-lexes as a float (`0.0`, `1.5e-7`), never as an int.
    format!("{v:?}f")
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Int(v, _) => write!(f, "{v}"),
            Expr::Float(v, _) => write!(f, "{}", fmt_float(*v)),
            Expr::Var(name, _) => write!(f, "{name}"),
            Expr::Call { name, args, .. } => {
                write!(f, "{name}(")?;
                for (i, a) in args.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{a}")?;
                }
                write!(f, ")")
            }
            Expr::Index { base, index, .. } => write!(f, "{base}[{index}]"),
            Expr::Unary { op, expr, .. } => write!(f, "({op}{expr})"),
            Expr::Bin { op, lhs, rhs, .. } => {
                write!(f, "({lhs} {} {rhs})", op.as_str())
            }
        }
    }
}

fn write_block(f: &mut fmt::Formatter<'_>, body: &[Stmt], indent: usize) -> fmt::Result {
    writeln!(f, "{{")?;
    for s in body {
        write_stmt(f, s, indent + 1)?;
    }
    write!(f, "{:indent$}}}", "", indent = indent * 4)
}

fn write_stmt(f: &mut fmt::Formatter<'_>, s: &Stmt, indent: usize) -> fmt::Result {
    write!(f, "{:indent$}", "", indent = indent * 4)?;
    match s {
        Stmt::Decl { ty, name, init, .. } => match init {
            Some(e) => writeln!(f, "{ty} {name} = {e};"),
            None => writeln!(f, "{ty} {name};"),
        },
        Stmt::Assign { target, op, value, .. } => {
            writeln!(f, "{target} {} {value};", op.as_str())
        }
        Stmt::For { var_ty, var, init, cond_op, bound, step, body, .. } => {
            write!(f, "for ({var_ty} {var} = {init}; {var} {} {bound}; ", cond_op.as_str())?;
            match step {
                ForStep::Inc => write!(f, "{var}++)")?,
                ForStep::Dec => write!(f, "{var}--)")?,
                ForStep::Add(e) => write!(f, "{var} += {e})")?,
                ForStep::Sub(e) => write!(f, "{var} -= {e})")?,
            }
            write!(f, " ")?;
            write_block(f, body, indent)?;
            writeln!(f)
        }
        Stmt::If { cond, then_body, else_body, .. } => {
            write!(f, "if ({cond}) ")?;
            write_block(f, then_body, indent)?;
            if !else_body.is_empty() {
                write!(f, " else ")?;
                write_block(f, else_body, indent)?;
            }
            writeln!(f)
        }
        Stmt::Call { name, args, .. } => {
            write!(f, "{name}(")?;
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    write!(f, ", ")?;
                }
                write!(f, "{a}")?;
            }
            writeln!(f, ");")
        }
        Stmt::Return { .. } => writeln!(f, "return;"),
    }
}

impl fmt::Display for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "__kernel void {}(", self.name)?;
        for (i, p) in self.params.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            if p.space != AddrSpace::Private {
                write!(f, "{} ", p.space.as_str())?;
            }
            if p.is_const {
                write!(f, "const ")?;
            }
            write!(f, "{}{} {}", p.ty, if p.is_ptr { "*" } else { "" }, p.name)?;
        }
        write!(f, ") ")?;
        write_block(f, &self.body, 0)?;
        writeln!(f)
    }
}

impl fmt::Display for Program {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, k) in self.kernels.iter().enumerate() {
            if i > 0 {
                writeln!(f)?;
            }
            write!(f, "{k}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> Pos {
        Pos { line: 1, col: 1 }
    }

    #[test]
    fn exprs_print_fully_parenthesized() {
        let e = Expr::Bin {
            op: BinOp::Add,
            lhs: Box::new(Expr::Bin {
                op: BinOp::Mul,
                lhs: Box::new(Expr::Var("y".into(), p())),
                rhs: Box::new(Expr::Var("w".into(), p())),
                pos: p(),
            }),
            rhs: Box::new(Expr::Var("x".into(), p())),
            pos: p(),
        };
        assert_eq!(e.to_string(), "((y * w) + x)");
    }

    #[test]
    fn floats_relex_as_floats() {
        assert_eq!(fmt_float(0.0), "0.0f");
        assert_eq!(fmt_float(1.5), "1.5f");
        let tiny = fmt_float(1e-9);
        assert!(tiny.contains('e') || tiny.contains('.'), "{tiny}");
    }

    #[test]
    fn kernel_prints_params_and_body() {
        let k = Kernel {
            name: "t".into(),
            params: vec![Param {
                space: AddrSpace::Global,
                is_const: true,
                ty: "float".into(),
                is_ptr: true,
                name: "in".into(),
                pos: p(),
            }],
            body: vec![Stmt::Return { pos: p() }],
            pos: p(),
        };
        let s = k.to_string();
        assert!(s.starts_with("__kernel void t(__global const float* in) {"));
        assert!(s.contains("return;"));
    }
}
