//! Recursive-descent parser for the OpenCL C subset.
//!
//! Grammar (see DESIGN.md §2d for the prose version):
//!
//! ```text
//! program  := kernel*
//! kernel   := "__kernel" "void" IDENT "(" params? ")" block
//! param    := qual* IDENT "*"? IDENT          qual := __global | __local
//!                                                   | __constant | const | restrict
//! block    := "{" stmt* "}"
//! stmt     := TYPE IDENT ("=" expr)? ";"                 (decl)
//!           | lvalue ("="|"+="|"-="|"*="|"/=") expr ";"  (assign)
//!           | "for" "(" TYPE IDENT "=" expr ";" IDENT relop expr ";" step ")" body
//!           | "if" "(" expr ")" body ("else" body)?
//!           | IDENT "(" args ")" ";"                     (call, e.g. barrier)
//!           | "return" ";"
//! step     := IDENT "++" | IDENT "--" | IDENT "+=" expr | IDENT "-=" expr
//! expr     := C expression over + - * / %  < <= > >= == !=  && ||, unary -/!,
//!             calls, subscripts, identifiers, int/float literals
//! ```
//!
//! All failures are typed, positioned [`ParseError`]s; the parser never
//! panics on malformed input, and expression/block nesting is depth-
//! limited so pathological input cannot overflow the stack.

use std::fmt;

use super::ast::{AddrSpace, AssignOp, BinOp, Expr, ForStep, Kernel, Param, Program, Stmt};
use super::lexer::{lex, LexError, Pos, Tok, Token};

/// Maximum expression / statement nesting depth accepted from user
/// source. Deeper input gets a typed error instead of a stack overflow.
pub const MAX_DEPTH: usize = 200;

#[derive(Clone, Debug)]
pub struct ParseError {
    pub pos: Pos,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl From<LexError> for ParseError {
    fn from(e: LexError) -> ParseError {
        ParseError { pos: e.pos, msg: e.msg }
    }
}

/// Scalar type names accepted in declarations and parameters.
const SCALAR_TYPES: [&str; 9] =
    ["int", "uint", "float", "double", "long", "ulong", "short", "size_t", "char"];

struct Parser {
    toks: Vec<Token>,
    i: usize,
    eof: Pos,
}

type PResult<T> = Result<T, ParseError>;

impl Parser {
    fn pos(&self) -> Pos {
        self.toks.get(self.i).map(|t| t.pos).unwrap_or(self.eof)
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.i).map(|t| &t.tok)
    }

    fn peek_at(&self, off: usize) -> Option<&Tok> {
        self.toks.get(self.i + off).map(|t| &t.tok)
    }

    fn bump(&mut self) -> Option<Token> {
        let t = self.toks.get(self.i).cloned();
        if t.is_some() {
            self.i += 1;
        }
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> PResult<T> {
        Err(ParseError { pos: self.pos(), msg: msg.into() })
    }

    fn describe_here(&self) -> String {
        match self.peek() {
            Some(t) => t.to_string(),
            None => "end of input".to_string(),
        }
    }

    fn is_punct(&self, p: &str) -> bool {
        matches!(self.peek(), Some(Tok::Punct(q)) if *q == p)
    }

    fn eat_punct(&mut self, p: &str) -> bool {
        if self.is_punct(p) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect_punct(&mut self, p: &str) -> PResult<()> {
        if self.eat_punct(p) {
            Ok(())
        } else {
            self.err(format!("expected `{p}`, found {}", self.describe_here()))
        }
    }

    fn is_ident(&self, name: &str) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s == name)
    }

    fn eat_ident(&mut self, name: &str) -> bool {
        if self.is_ident(name) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    fn expect_any_ident(&mut self, what: &str) -> PResult<(String, Pos)> {
        let pos = self.pos();
        match self.bump().map(|t| t.tok) {
            Some(Tok::Ident(s)) => Ok((s, pos)),
            other => Err(ParseError {
                pos,
                msg: format!(
                    "expected {what}, found {}",
                    other.map(|t| t.to_string()).unwrap_or_else(|| "end of input".into())
                ),
            }),
        }
    }

    // -- program level -------------------------------------------------

    fn program(&mut self) -> PResult<Program> {
        let mut kernels = Vec::new();
        while self.peek().is_some() {
            kernels.push(self.kernel()?);
        }
        Ok(Program { kernels })
    }

    fn kernel(&mut self) -> PResult<Kernel> {
        let pos = self.pos();
        if !(self.eat_ident("__kernel") || self.eat_ident("kernel")) {
            return self.err(format!(
                "expected `__kernel`, found {} (only kernel definitions are \
                 supported at top level)",
                self.describe_here()
            ));
        }
        if !self.eat_ident("void") {
            return self.err(format!("expected `void`, found {}", self.describe_here()));
        }
        let (name, _) = self.expect_any_ident("kernel name")?;
        self.expect_punct("(")?;
        let mut params = Vec::new();
        if !self.is_punct(")") {
            loop {
                params.push(self.param()?);
                if !self.eat_punct(",") {
                    break;
                }
            }
        }
        self.expect_punct(")")?;
        let body = self.block(0)?;
        Ok(Kernel { name, params, body, pos })
    }

    fn param(&mut self) -> PResult<Param> {
        let pos = self.pos();
        let mut space = AddrSpace::Private;
        let mut is_const = false;
        loop {
            if self.eat_ident("__global") || self.eat_ident("global") {
                space = AddrSpace::Global;
            } else if self.eat_ident("__local") || self.eat_ident("local") {
                space = AddrSpace::Local;
            } else if self.eat_ident("__constant") || self.eat_ident("constant") {
                space = AddrSpace::Constant;
            } else if self.eat_ident("const") || self.eat_ident("restrict") {
                is_const = true;
            } else {
                break;
            }
        }
        let (ty, ty_pos) = self.expect_any_ident("parameter type")?;
        if !SCALAR_TYPES.contains(&ty.as_str()) {
            return Err(ParseError {
                pos: ty_pos,
                msg: format!("unsupported parameter type `{ty}`"),
            });
        }
        let mut is_ptr = false;
        while self.eat_punct("*") {
            if is_ptr {
                return self.err("multiple levels of indirection are not supported");
            }
            is_ptr = true;
        }
        // `restrict`/`const` may also follow the `*`.
        while self.eat_ident("restrict") || self.eat_ident("const") {}
        let (name, _) = self.expect_any_ident("parameter name")?;
        Ok(Param { space, is_const, ty, is_ptr, name, pos })
    }

    // -- statements ----------------------------------------------------

    fn block(&mut self, depth: usize) -> PResult<Vec<Stmt>> {
        if depth > MAX_DEPTH {
            return self.err("statement nesting too deep");
        }
        self.expect_punct("{")?;
        let mut body = Vec::new();
        while !self.is_punct("}") {
            if self.peek().is_none() {
                return self.err("unterminated block: expected `}`");
            }
            body.push(self.stmt(depth)?);
        }
        self.expect_punct("}")?;
        Ok(body)
    }

    /// A statement body: either a `{...}` block or a single statement.
    fn body(&mut self, depth: usize) -> PResult<Vec<Stmt>> {
        if self.is_punct("{") {
            self.block(depth)
        } else {
            Ok(vec![self.stmt(depth)?])
        }
    }

    fn stmt(&mut self, depth: usize) -> PResult<Stmt> {
        if depth > MAX_DEPTH {
            return self.err("statement nesting too deep");
        }
        let pos = self.pos();
        if self.is_ident("__local") || self.is_ident("local") {
            return self.err(
                "__local declarations are not supported — analyze the \
                 unoptimized kernel (the tool decides whether staging pays off)",
            );
        }
        if self.eat_ident("for") {
            return self.for_stmt(pos, depth);
        }
        if self.eat_ident("if") {
            self.expect_punct("(")?;
            let cond = self.expr(0)?;
            self.expect_punct(")")?;
            let then_body = self.body(depth + 1)?;
            let else_body = if self.eat_ident("else") {
                self.body(depth + 1)?
            } else {
                Vec::new()
            };
            return Ok(Stmt::If { cond, then_body, else_body, pos });
        }
        if self.eat_ident("return") {
            self.expect_punct(";")?;
            return Ok(Stmt::Return { pos });
        }
        // Declaration: (const)? TYPE IDENT (= expr)? ;
        let save = self.i;
        let _ = self.eat_ident("const");
        if let Some(Tok::Ident(ty)) = self.peek().cloned() {
            if SCALAR_TYPES.contains(&ty.as_str()) {
                self.i += 1;
                let (name, _) = self.expect_any_ident("variable name")?;
                let init = if self.eat_punct("=") {
                    Some(self.expr(0)?)
                } else {
                    None
                };
                self.expect_punct(";")?;
                return Ok(Stmt::Decl { ty, name, init, pos });
            }
        }
        self.i = save;
        // Call statement: IDENT ( args ) ;
        if let (Some(Tok::Ident(name)), Some(Tok::Punct("("))) =
            (self.peek().cloned(), self.peek_at(1))
        {
            // Distinguish `foo(...)  ;` from an assignment whose LHS merely
            // starts with an identifier: a call statement ends right after
            // the closing paren.
            if self.call_is_statement() {
                self.i += 2;
                let args = self.call_args()?;
                self.expect_punct(";")?;
                return Ok(Stmt::Call { name, args, pos });
            }
        }
        // Assignment.
        let target = self.unary(0)?;
        match &target {
            Expr::Var(..) | Expr::Index { .. } => {}
            _ => {
                return Err(ParseError {
                    pos: target.pos(),
                    msg: "assignment target must be a variable or subscript".into(),
                })
            }
        }
        let op = if self.eat_punct("=") {
            AssignOp::Set
        } else if self.eat_punct("+=") {
            AssignOp::Add
        } else if self.eat_punct("-=") {
            AssignOp::Sub
        } else if self.eat_punct("*=") {
            AssignOp::Mul
        } else if self.eat_punct("/=") {
            AssignOp::Div
        } else {
            return self.err(format!(
                "expected an assignment operator, found {}",
                self.describe_here()
            ));
        };
        let value = self.expr(0)?;
        self.expect_punct(";")?;
        Ok(Stmt::Assign { target, op, value, pos })
    }

    /// Lookahead: does the `IDENT (`-headed phrase close its paren and hit
    /// `;` immediately (a call statement) rather than continuing as an
    /// assignment LHS?
    fn call_is_statement(&self) -> bool {
        let mut depth = 0usize;
        let mut j = self.i + 1; // at the `(`
        while let Some(t) = self.toks.get(j) {
            match &t.tok {
                Tok::Punct("(") => depth += 1,
                Tok::Punct(")") => {
                    depth -= 1;
                    if depth == 0 {
                        return matches!(
                            self.toks.get(j + 1).map(|t| &t.tok),
                            Some(Tok::Punct(";"))
                        );
                    }
                }
                _ => {}
            }
            j += 1;
        }
        false
    }

    fn for_stmt(&mut self, pos: Pos, depth: usize) -> PResult<Stmt> {
        self.expect_punct("(")?;
        let (var_ty, ty_pos) = self.expect_any_ident("loop variable type")?;
        if !SCALAR_TYPES.contains(&var_ty.as_str()) {
            return Err(ParseError {
                pos: ty_pos,
                msg: format!(
                    "loop variable must be declared in the for header \
                     (`for (int i = ...`), found `{var_ty}`"
                ),
            });
        }
        let (var, _) = self.expect_any_ident("loop variable")?;
        self.expect_punct("=")?;
        let init = self.expr(0)?;
        self.expect_punct(";")?;
        let (cond_var, cv_pos) = self.expect_any_ident("loop condition variable")?;
        if cond_var != var {
            return Err(ParseError {
                pos: cv_pos,
                msg: format!("loop condition must test `{var}`, found `{cond_var}`"),
            });
        }
        let cond_op = if self.eat_punct("<") {
            BinOp::Lt
        } else if self.eat_punct("<=") {
            BinOp::Le
        } else if self.eat_punct(">") {
            BinOp::Gt
        } else if self.eat_punct(">=") {
            BinOp::Ge
        } else {
            return self.err(format!(
                "expected `<`, `<=`, `>` or `>=` in loop condition, found {}",
                self.describe_here()
            ));
        };
        let bound = self.expr(0)?;
        self.expect_punct(";")?;
        let (step_var, sv_pos) = self.expect_any_ident("loop step variable")?;
        if step_var != var {
            return Err(ParseError {
                pos: sv_pos,
                msg: format!("loop step must update `{var}`, found `{step_var}`"),
            });
        }
        let step = if self.eat_punct("++") {
            ForStep::Inc
        } else if self.eat_punct("--") {
            ForStep::Dec
        } else if self.eat_punct("+=") {
            ForStep::Add(self.expr(0)?)
        } else if self.eat_punct("-=") {
            ForStep::Sub(self.expr(0)?)
        } else {
            return self.err(format!(
                "expected `++`, `--`, `+=` or `-=` in loop step, found {}",
                self.describe_here()
            ));
        };
        self.expect_punct(")")?;
        let body = self.body(depth + 1)?;
        Ok(Stmt::For { var_ty, var, init, cond_op, bound, step, body, pos })
    }

    // -- expressions (precedence climbing) -----------------------------

    fn expr(&mut self, depth: usize) -> PResult<Expr> {
        self.or_expr(depth)
    }

    fn bin_level(
        &mut self,
        depth: usize,
        ops: &[(&str, BinOp)],
        next: fn(&mut Self, usize) -> PResult<Expr>,
    ) -> PResult<Expr> {
        if depth > MAX_DEPTH {
            return self.err("expression too deeply nested");
        }
        let mut lhs = next(self, depth + 1)?;
        'outer: loop {
            for (p, op) in ops {
                if self.is_punct(p) {
                    let pos = self.pos();
                    self.i += 1;
                    let rhs = next(self, depth + 1)?;
                    lhs = Expr::Bin { op: *op, lhs: Box::new(lhs), rhs: Box::new(rhs), pos };
                    continue 'outer;
                }
            }
            return Ok(lhs);
        }
    }

    fn or_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(depth, &[("||", BinOp::Or)], Self::and_expr)
    }

    fn and_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(depth, &[("&&", BinOp::And)], Self::eq_expr)
    }

    fn eq_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(depth, &[("==", BinOp::EqEq), ("!=", BinOp::Ne)], Self::rel_expr)
    }

    fn rel_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(
            depth,
            &[("<=", BinOp::Le), (">=", BinOp::Ge), ("<", BinOp::Lt), (">", BinOp::Gt)],
            Self::add_expr,
        )
    }

    fn add_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(depth, &[("+", BinOp::Add), ("-", BinOp::Sub)], Self::mul_expr)
    }

    fn mul_expr(&mut self, depth: usize) -> PResult<Expr> {
        self.bin_level(
            depth,
            &[("*", BinOp::Mul), ("/", BinOp::Div), ("%", BinOp::Rem)],
            Self::unary,
        )
    }

    fn unary(&mut self, depth: usize) -> PResult<Expr> {
        if depth > MAX_DEPTH {
            return self.err("expression too deeply nested");
        }
        let pos = self.pos();
        if self.eat_punct("-") {
            let e = self.unary(depth + 1)?;
            return Ok(Expr::Unary { op: '-', expr: Box::new(e), pos });
        }
        if self.eat_punct("!") {
            let e = self.unary(depth + 1)?;
            return Ok(Expr::Unary { op: '!', expr: Box::new(e), pos });
        }
        self.postfix(depth + 1)
    }

    fn postfix(&mut self, depth: usize) -> PResult<Expr> {
        let mut e = self.primary(depth)?;
        loop {
            if self.is_punct("[") {
                let pos = self.pos();
                self.i += 1;
                let idx = self.expr(depth + 1)?;
                self.expect_punct("]")?;
                e = Expr::Index { base: Box::new(e), index: Box::new(idx), pos };
            } else {
                return Ok(e);
            }
        }
    }

    fn call_args(&mut self) -> PResult<Vec<Expr>> {
        let mut args = Vec::new();
        if !self.is_punct(")") {
            loop {
                args.push(self.expr(0)?);
                if !self.eat_punct(",") {
                    break;
                }
            }
        }
        self.expect_punct(")")?;
        Ok(args)
    }

    fn primary(&mut self, depth: usize) -> PResult<Expr> {
        if depth > MAX_DEPTH {
            return self.err("expression too deeply nested");
        }
        let pos = self.pos();
        match self.peek().cloned() {
            Some(Tok::Int(v)) => {
                self.i += 1;
                Ok(Expr::Int(v, pos))
            }
            Some(Tok::Float(v)) => {
                self.i += 1;
                Ok(Expr::Float(v, pos))
            }
            Some(Tok::Punct("(")) => {
                self.i += 1;
                // Tolerate C-style scalar casts like `(float)x`.
                if let (Some(Tok::Ident(ty)), Some(Tok::Punct(")"))) =
                    (self.peek().cloned(), self.peek_at(1))
                {
                    if SCALAR_TYPES.contains(&ty.as_str()) {
                        self.i += 2;
                        return self.unary(depth + 1);
                    }
                }
                let e = self.expr(depth + 1)?;
                self.expect_punct(")")?;
                Ok(e)
            }
            Some(Tok::Ident(name)) => {
                self.i += 1;
                if self.eat_punct("(") {
                    let args = self.call_args()?;
                    Ok(Expr::Call { name, args, pos })
                } else {
                    Ok(Expr::Var(name, pos))
                }
            }
            Some(other) => {
                Err(ParseError { pos, msg: format!("expected an expression, found {other}") })
            }
            None => Err(ParseError {
                pos,
                msg: "expected an expression, found end of input".into(),
            }),
        }
    }
}

/// Parse a whole translation unit (kernels only).
pub fn parse(src: &str) -> PResult<Program> {
    let toks = lex(src)?;
    let eof = toks
        .last()
        .map(|t| Pos { line: t.pos.line, col: t.pos.col + 1 })
        .unwrap_or_else(Pos::start);
    let mut p = Parser { toks, i: 0, eof };
    p.program()
}

#[cfg(test)]
mod tests {
    use super::*;

    const TOY: &str = "
__kernel void toy(__global const float* in, __global float* out, int w) {
    int x = get_global_id(0);
    int y = get_global_id(1);
    float s = 0.0f;
    for (int k = -1; k <= 1; k++) {
        s += in[(y * w) + (x + k)];
    }
    out[(y * w) + x] = s;
}
";

    #[test]
    fn toy_kernel_parses() {
        let prog = parse(TOY).unwrap();
        assert_eq!(prog.kernels.len(), 1);
        let k = &prog.kernels[0];
        assert_eq!(k.name, "toy");
        assert_eq!(k.params.len(), 3);
        assert_eq!(k.params[0].space, AddrSpace::Global);
        assert!(k.params[0].is_ptr && k.params[0].is_const);
        assert!(!k.params[2].is_ptr);
        assert_eq!(k.body.len(), 5);
    }

    #[test]
    fn pretty_print_reparses_to_same_ast() {
        let prog = parse(TOY).unwrap();
        let printed = prog.to_string();
        let again = parse(&printed).unwrap_or_else(|e| panic!("reparse failed: {e}\n{printed}"));
        // Token positions differ; compare the canonical text instead.
        assert_eq!(printed, again.to_string());
    }

    #[test]
    fn errors_are_positioned() {
        let e = parse("__kernel void f(int x) { int y = ; }").unwrap_err();
        assert!(e.to_string().contains("expected an expression"), "{e}");
        assert_eq!(e.pos.line, 1);
        let e = parse("void helper() {}").unwrap_err();
        assert!(e.msg.contains("__kernel"), "{e}");
        let e = parse("__kernel void f(struct S s) {}").unwrap_err();
        assert!(e.msg.contains("unsupported parameter type"), "{e}");
        let e = parse("__kernel void f(int n) { for (int i = 0; j < n; i++) {} }").unwrap_err();
        assert!(e.msg.contains("loop condition"), "{e}");
    }

    #[test]
    fn deep_nesting_is_a_typed_error_not_a_stack_overflow() {
        let mut src = String::from("__kernel void f(int x) { int y = ");
        for _ in 0..10_000 {
            src.push('(');
        }
        src.push('1');
        for _ in 0..10_000 {
            src.push(')');
        }
        src.push_str("; }");
        let e = parse(&src).unwrap_err();
        assert!(e.msg.contains("deeply nested"), "{e}");
    }

    #[test]
    fn call_statement_vs_assignment_lookahead() {
        let src = "__kernel void f(__global float* a) {
            barrier(1);
            a[get_global_id(0)] = 2.0f;
        }";
        let prog = parse(src).unwrap();
        assert!(matches!(prog.kernels[0].body[0], Stmt::Call { .. }));
        assert!(matches!(prog.kernels[0].body[1], Stmt::Assign { .. }));
    }
}
